"""Doc-partitioned sharded serving: qps and probe-bytes vs shard count K.

The serving question behind the ROADMAP's "sharding" item: what does
splitting the document space into K partitions (serve/shard.py, planned by
serve/planner.py, fanned out by the BooleanEngine facade) cost or buy on the
Zipf conjunctive workload?  Each K builds a full engine over the same trained
learned-Bloom model; K=1 is the unsharded engine and every K must return
bit-identical `query_batch` results to it (asserted, along with exactness
against brute force).

The bench also exercises the persistent shard-store round trip
(index/store.py): the K=4 index is saved, reloaded (mmap-lazy), and must
serve identical results — with the reload measured against the in-memory
build that re-runs codec selection.

Emits BENCH_sharded_serve.json:
  k.<K>.qps / seconds      verified query throughput at K shards
  k.<K>.probe_bytes        guided-probe + fallback stream bytes touched
  k.<K>.cache_*            aggregated per-shard decode-cache counters
  latency_ratio            min over K>1 of seconds(K) / seconds(K=1) —
                           machine-normalized, gated by check_regression.py
                           (sharding overhead must never blow up serving)
  store.load_vs_build      reload seconds / re-encode-build seconds
"""
from __future__ import annotations

import json
import tempfile
import time

import numpy as np

BENCH_PATH = "BENCH_sharded_serve.json"

N_DOCS = 4096
N_TERMS = 5000
AVG_DOC_LEN = 60
N_QUERIES = 48
TRAIN_STEPS = 120
REPS = 3  # timing passes per K (min taken; first warms caches/jit)
K_SWEEP = (1, 2, 4, 8)
SEED = 17


def _system():
    import jax
    import jax.numpy as jnp

    from repro.common.config import CorpusConfig, LearnedIndexConfig, OptimizerConfig
    from repro.core import fit_thresholds, init_membership, membership_loss
    from repro.data.corpus import synthesize_corpus
    from repro.data.loader import membership_batches
    from repro.index.build import build_inverted_index
    from repro.train import init_train_state, make_train_step

    corpus = synthesize_corpus(
        CorpusConfig(n_docs=N_DOCS, n_terms=N_TERMS, avg_doc_len=AVG_DOC_LEN, seed=SEED)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=32, truncation_k=32, block_size=128)
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    ocfg = OptimizerConfig(lr=0.05, warmup_steps=10, total_steps=TRAIN_STEPS,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b), ocfg))
    st = init_train_state(params, ocfg)
    for _, batch in zip(range(TRAIN_STEPS), membership_batches(corpus, batch_size=2048)):
        params, st, _ = step(params, st, {k: jnp.asarray(v) for k, v in batch.items()})
    lb = fit_thresholds(params, inv)
    return corpus, inv, li_cfg, lb


def sharded_rows(write_json: bool = True):
    from repro.data.queries import brute_force_answers, zipf_conjunctions
    from repro.serve import BooleanEngine, ServeConfig

    corpus, inv, li_cfg, lb = _system()
    queries = zipf_conjunctions(inv.dfs, N_QUERIES, seed=SEED + 1)
    exact = brute_force_answers(corpus, queries)

    per_k: dict[str, dict] = {}
    seconds: dict[int, float] = {}
    ref_results = None
    engines: dict[int, "BooleanEngine"] = {}
    for k in K_SWEEP:
        t0 = time.time()
        eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=k))
        # force tier-2 builds out of the timed region (codec selection is
        # startup cost, amortized or eliminated by the persistent store)
        for sh in eng.shards:
            sh.tier2
        build_s = time.time() - t0
        engines[k] = eng
        best = np.inf
        results = None
        for _ in range(REPS):
            t0 = time.time()
            results = eng.query_batch(queries)
            best = min(best, time.time() - t0)
        seconds[k] = best
        if k == 1:
            ref_results = results
            for r, e in zip(results, exact):
                assert np.array_equal(r, e), "K=1 engine must be exact"
        else:
            for r, e in zip(results, ref_results):
                assert np.array_equal(r, e), f"K={k} differs from K=1 (bit-identity)"
        eng.reset_stats()
        eng.query_batch(queries)  # byte accounting for exactly one pass
        s = eng.metrics.snapshot()["summary"]
        per_k[str(k)] = {
            "seconds": best,
            "qps": N_QUERIES / best,
            "build_seconds": build_s,
            "active_shards": len(eng.shards),
            "probe_bytes": s["probe_bytes"],
            "bytes_ratio": s["bytes_ratio"],
            "cache_hits": s["cache_hits"],
            "cache_misses": s["cache_misses"],
            "cache_evictions": s["cache_evictions"],
        }

    # ---- persistent shard-store round trip (K=4): reload beats re-encode
    with tempfile.TemporaryDirectory() as d:
        engines[4].save(d)
        t0 = time.time()
        loaded = BooleanEngine.from_store(lb, li_cfg, ServeConfig(n_shards=4), d)
        load_s = time.time() - t0
        for r, e in zip(loaded.query_batch(queries), ref_results):
            assert np.array_equal(r, e), "store round trip must serve identical results"
        t0 = time.time()
        rebuilt = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=4))
        for sh in rebuilt.shards:
            sh.tier2
        build_s = time.time() - t0

    latency_ratio = min(seconds[k] for k in K_SWEEP if k > 1) / seconds[1]
    traj = {
        "workload": {
            "n_docs": N_DOCS,
            "n_terms": N_TERMS,
            "n_postings": int(inv.n_postings),
            "n_queries": N_QUERIES,
            "train_steps": TRAIN_STEPS,
        },
        "k": per_k,
        # machine-normalized gate metric: the best sharded configuration's
        # serving time relative to K=1 on the same run — fan-out overhead
        # (threads, planning, merge) must never blow up serving latency
        "latency_ratio": latency_ratio,
        "store": {
            "load_seconds": load_s,
            "build_seconds": build_s,
            "load_vs_build": load_s / build_s,
            "roundtrip_exact": True,
        },
    }
    rows = [
        (f"sharded/k{k}", 1e6 * per_k[str(k)]["seconds"] / N_QUERIES,
         f"qps={per_k[str(k)]['qps']:.1f}_probe_bytes={per_k[str(k)]['probe_bytes']}")
        for k in K_SWEEP
    ]
    rows.append(("sharded/latency_ratio", 0.0, f"best_k_vs_k1={latency_ratio:.3f}"))
    rows.append(("sharded/store_load", 1e6 * load_s,
                 f"load_vs_build={traj['store']['load_vs_build']:.3f}"))
    if write_json:
        with open(BENCH_PATH, "w") as f:
            json.dump(traj, f, indent=2)
        rows.append(("sharded/json", 0.0, f"wrote {BENCH_PATH}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in sharded_rows():
        print(f"{name},{us:.1f},{derived}")
