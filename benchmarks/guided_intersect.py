"""Guided vs full-decode conjunctive verification on a Zipf workload.

The serving question: given Bloom-filtered candidates for an AND query, how
many compressed-stream bytes must verification touch?  The full-decode path
decompresses every query term's posting list; the model-guided path
(repro.postings.search) answers contains() probes from PLM/RMI stream
metadata plus ±ε correction windows, reading only the bytes the error bound
proves necessary.

Collection: Zipf-distributed document frequencies over three id regimes —
mostly *smooth* lists (near-linear id growth with bounded jitter: the
URL-sorted / crawl-ordered case where rank models win and correction bodies
dominate stream bytes), plus arithmetic runs (degenerate width-0 lists) and
rough uniform-random lists (where classical codecs win and probes fall back
to full decode).  Workload: 2-5-term conjunctions with Zipf term draws
(data/queries.zipf_conjunctions).  Candidates per query: the exact
conjunction plus uniform false positives at FP_RATE of the universe — the
shape a learned-Bloom tier-1 emits.

Emits BENCH_guided_intersect.json:
  guided.ns_per_probe   wall-clock per (term, candidate) contains() probe
  guided/full.qps       verification throughput of each path
  bytes_ratio           guided bytes touched / full-decode bytes touched
                        (acceptance: < 0.10 on this workload)
  bytes_ratio_unique    same numerator over each unique stream counted once
                        (a full path with unbounded decoded-list cache)
Both paths must return identical results (asserted against store decode).

Accounting regime: `bytes_ratio` charges the full-decode path per access
(decode-on-access — the memory-constrained setting tier-2 compression exists
for, where decoded lists cannot all stay resident), while the guided path's
fallback decodes are charged once per term because they are cached.
`bytes_ratio_unique` is the other extreme: an unbounded decoded-list cache
on the full side, where the guided path's remaining win is not holding any
decoded list resident.  Real deployments sit between the two depending on
the decode-cache budget.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.data.queries import zipf_conjunctions
from repro.index.intersect import membership_mask
from repro.postings import GuidedPostings, HybridPostings

BENCH_PATH = "BENCH_guided_intersect.json"

UNIVERSE = 8_000_000
N_TERMS = 250
DF_MAX = 50_000
N_QUERIES = 240
FP_RATE = 2e-4  # tier-1 false-positive mass relative to the universe
REPS = 4  # interleaved timing passes per path (min taken; first warms caches)
SEED = 23


def _smooth_list(rng, df: int, universe: int) -> np.ndarray:
    """Near-linear ids with bounded jitter: slope ≫ noise keeps them sorted;
    corrections span ~slope/4 so the stream is correction-body dominated."""
    max_slope = max(2, (universe - 1) // (df + 1) - 1)
    slope = int(rng.integers(min(16, max_slope), min(256, max_slope) + 1))
    noise_hi = max(1, slope // 4)
    start = int(rng.integers(0, universe - df * slope - noise_hi))
    ids = start + np.arange(df, dtype=np.int64) * slope + rng.integers(0, noise_hi, df)
    return ids.astype(np.int32)


def _run_list(rng, df: int, universe: int) -> np.ndarray:
    """Arithmetic runs (step 1-3): the width-0 regime, near-pure model."""
    step = int(rng.integers(1, 4))
    start = int(rng.integers(0, universe - df * step - 1))
    return np.arange(start, start + df * step, step, dtype=np.int64).astype(np.int32)


def _rough_list(rng, df: int, universe: int) -> np.ndarray:
    """Uniform-random sparse ids: classical codecs win, probes fall back."""
    return np.sort(rng.choice(universe, size=df, replace=False)).astype(np.int32)


def _synth_index(
    rng, n_terms: int = N_TERMS, universe: int = UNIVERSE, df_max: int = DF_MAX
) -> tuple[np.ndarray, np.ndarray]:
    """Zipf-df lists over the three id regimes -> (term_offsets, doc_ids)."""
    lists = []
    for r in range(n_terms):
        df = max(40, int(df_max * (r + 1) ** -0.9))
        u = rng.random()
        if u < 0.70:
            ids = _smooth_list(rng, df, universe)
        elif u < 0.85:
            ids = _run_list(rng, df, universe)
        else:
            ids = _rough_list(rng, min(df, 4000), universe)
        lists.append(np.unique(ids))
    offsets = np.zeros(len(lists) + 1, np.int64)
    np.cumsum([len(x) for x in lists], out=offsets[1:])
    return offsets, np.concatenate(lists).astype(np.int32)


def _candidates(result: np.ndarray, rng, universe: int = UNIVERSE) -> np.ndarray:
    """Bloom-like candidate set: exact result ∪ uniform false positives."""
    n_fp = max(16, int(FP_RATE * universe))
    fps = rng.integers(0, universe, size=n_fp)
    return np.union1d(result.astype(np.int64), fps).astype(np.int64)


def _exact(store: HybridPostings, terms: list[int]) -> np.ndarray:
    cur = store.postings(terms[0]).astype(np.int64)
    for t in terms[1:]:
        cur = np.intersect1d(cur, store.postings(t).astype(np.int64), assume_unique=True)
        if cur.size == 0:
            break
    return cur


def guided_rows(write_json: bool = True):
    rng = np.random.default_rng(SEED)
    offsets, doc_ids = _synth_index(rng)
    t0 = time.time()
    store = HybridPostings.build(offsets, doc_ids, UNIVERSE)
    build_us = (time.time() - t0) * 1e6
    n_postings = len(doc_ids)
    dfs = np.diff(offsets)

    queries = zipf_conjunctions(dfs, N_QUERIES, seed=SEED + 1)
    qterms = [sorted((int(t) for t in q if t >= 0), key=lambda t: int(dfs[t]))
              for q in queries]
    exact = [_exact(store, ts) for ts in qterms]
    cands = [_candidates(e, rng) for e in exact]

    # ---- guided path: ε-window probes, smallest list first (engine order)
    def run_guided(gp):
        res = []
        for ts, c in zip(qterms, cands):
            out = c
            for t in ts:
                if out.size == 0:
                    break
                out = out[gp.contains(t, out)]
            res.append(out)
        return res

    # ---- full-decode path: decompress every query term's stream, binary search
    def run_full(count_bytes: list):
        res = []
        for ts, c in zip(qterms, cands):
            out = c
            for t in ts:
                if out.size == 0:
                    break
                count_bytes[0] += 4 * int(store.streams[t].size)
                out = out[membership_mask(store.postings(t).astype(np.int64), out)]
            res.append(out)
        return res

    # interleave the timing reps (guided, full, guided, full, ...) so both
    # paths sample the same CPU-frequency/cache conditions — the
    # latency_ratio the CI gate compares is then stable run-to-run, where
    # two sequential timing blocks drift apart
    guided_s = full_s = np.inf
    guided_res = full_res = None
    counter = [0]
    gp_warm = GuidedPostings(store)  # one engine across reps: model parsing
    for _ in range(REPS):           # and fallback decodes amortize, as served
        t0 = time.time()
        guided_res = run_guided(gp_warm)
        guided_s = min(guided_s, time.time() - t0)
        t0 = time.time()
        full_res = run_full(counter)
        full_s = min(full_s, time.time() - t0)
    full_bytes = counter[0] // REPS
    gp = GuidedPostings(store)
    run_guided(gp)  # byte accounting for exactly one workload pass
    gstats = gp.stats.as_dict()

    for g, f, e in zip(guided_res, full_res, exact):
        assert np.array_equal(g, f), "guided and full-decode verification disagree"
        assert np.array_equal(np.sort(g), e), "verification disagrees with exact AND"

    probes = gstats["probes"]
    bytes_ratio = gstats["guided_bytes"] / full_bytes
    # alternative accounting: a full-decode path with an unbounded decoded-
    # list cache touches each unique stream once; the guided path then trades
    # bytes for not having to keep decoded lists resident at all
    unique_terms = sorted({t for ts in qterms for t in ts})
    full_unique_bytes = sum(4 * int(store.streams[t].size) for t in unique_terms)
    traj = {
        "workload": {
            "universe": UNIVERSE,
            "n_terms": N_TERMS,
            "n_postings": int(n_postings),
            "n_queries": N_QUERIES,
            "avg_query_terms": float(np.mean([len(t) for t in qterms])),
            "avg_candidates": float(np.mean([len(c) for c in cands])),
            "fp_rate": FP_RATE,
        },
        "store": {
            "bits_per_posting": store.size_bits() / n_postings,
            "codec_histogram": store.codec_histogram(),
        },
        "guided": {
            "seconds": guided_s,
            "ns_per_probe": 1e9 * guided_s / max(probes, 1),
            "qps": N_QUERIES / guided_s,
            "bytes_touched": gstats["guided_bytes"],
            "probes": probes,
            "window_bytes": gstats["window_bytes"],
            "metadata_bytes": gstats["metadata_bytes"],
            "fallback_bytes": gstats["fallback_bytes"],
            "routed_terms": gstats["routed_terms"],
        },
        "full": {
            "seconds": full_s,
            "qps": N_QUERIES / full_s,
            "bytes_touched": full_bytes,
            "unique_stream_bytes": full_unique_bytes,
        },
        "bytes_ratio": bytes_ratio,
        "bytes_ratio_unique": gstats["guided_bytes"] / full_unique_bytes,
        # machine-normalized latency metric for the CI regression gate:
        # guided verification time as a fraction of full-decode time on the
        # same run (absolute ns/probe is not comparable across machines)
        "latency_ratio": guided_s / full_s,
    }
    rows = [
        ("guided/build_store", build_us, f"bits_per_posting={traj['store']['bits_per_posting']:.3f}"),
        ("guided/probe", 1e-3 * traj["guided"]["ns_per_probe"],
         f"qps={traj['guided']['qps']:.1f}"),
        ("guided/full_decode", 1e6 * full_s / N_QUERIES, f"qps={traj['full']['qps']:.1f}"),
        ("guided/bytes_ratio", 0.0, f"guided_touches={bytes_ratio:.4f}_of_full"),
    ]
    if write_json:
        with open(BENCH_PATH, "w") as f:
            json.dump(traj, f, indent=2)
        rows.append(("guided/json", 0.0, f"wrote {BENCH_PATH}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in guided_rows():
        print(f"{name},{us:.1f},{derived}")
