"""Ranked top-k serving: MaxScore pruning vs exhaustive scoring, K shards.

The ranked-workload question the ROADMAP north-star asks: what does BM25
top-k cost over the learned postings store, and how much work does MaxScore
dynamic pruning (rank/topk.py) + segment-granularity score bounds actually
skip?  Every configuration must return *bit-identical* (ids and integer
scores) results to the brute-force quantized-BM25 oracle over decoded
postings — pruning and sharding are pure work-skippers, asserted as such
(K=1 vs K=4 equality included).

Emits BENCH_ranked_topk.json:
  k.<K>.qps / seconds       verified top-10 throughput at K shards
  k.<K>.scored_fraction     (decoded + probed postings) / exhaustive postings
  scored_fraction           the K=1 pruned fraction — the paper-facing number
                            (MaxScore must touch < 0.5x of exhaustive on the
                            Zipf disjunctive workload; gated)
  latency_ratio             pruned seconds / exhaustive seconds on the same
                            run — machine-normalized, gated by
                            check_regression.py (pruning must never cost
                            more than it saves)
  fused.latency_ratio       fused one-dispatch seconds / multi-phase seconds
                            on the *kernel-enabled* multi-phase configuration
                            (guided_kernel + score_kernel — the hundreds of
                            small host<->device hops the fused kernel
                            replaces), same run; machine-normalized and
                            gated < 1.0
  fused.latency_ratio_host  fused seconds / the default all-numpy multi-phase
                            seconds — gated < 1.0: with the device-resident
                            arena the dense one-dispatch path must beat the
                            host path outright, not just the dispatch count
  fused.roofline            inverted-index cost model (benchmarks/roofline
                            index_roofline): index bytes the dispatch lanes
                            read, dispatch device bytes, achieved bytes/s vs
                            the HBM roof — timed against fused_kernel_ns
                            (device-blocked time), with the host bridge
                            reported separately as bridge_seconds
                            (fraction_of_hbm_roof gated as a floor in
                            check_regression.py)

Every fused result is asserted bit-identical to the multi-phase results and
the brute-force oracle, for K=1 and K=4 sharding.  The fused pass also
writes a Chrome-trace of one traced batch (kernel.fused_query spans) to
artifacts/ranked_topk.fused.trace.json for the CI artifact.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

BENCH_PATH = "BENCH_ranked_topk.json"
FUSED_TRACE_PATH = os.path.join("artifacts", "ranked_topk.fused.trace.json")

N_DOCS = 4096
N_TERMS = 5000
AVG_DOC_LEN = 60
N_QUERIES = 64
TOP_K = 10
REPS = 3
K_SWEEP = (1, 4)
SEED = 23


def _system():
    import jax

    from repro.common.config import CorpusConfig, LearnedIndexConfig
    from repro.core import fit_thresholds, init_membership
    from repro.data.corpus import synthesize_corpus
    from repro.index.build import build_inverted_index

    corpus = synthesize_corpus(
        CorpusConfig(n_docs=N_DOCS, n_terms=N_TERMS, avg_doc_len=AVG_DOC_LEN, seed=SEED)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=32, truncation_k=32, block_size=128)
    # the ranked path never consults the membership model, so thresholds are
    # fitted on untrained params — engine construction cost only
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)
    return inv, li_cfg, lb


def ranked_rows(write_json: bool = True):
    from repro.data.queries import zipf_disjunctions
    from repro.rank.score import ImpactModel, brute_force_topk
    from repro.serve import BooleanEngine, ServeConfig

    inv, li_cfg, lb = _system()
    queries, _ = zipf_disjunctions(inv.dfs, N_QUERIES, seed=SEED + 1)
    im = ImpactModel.build(inv)
    oracle = brute_force_topk(inv, im, queries, TOP_K)

    def run(eng):
        best, results = np.inf, None
        for _ in range(REPS):
            t0 = time.time()
            results = eng.query_topk(queries, TOP_K)
            best = min(best, time.time() - t0)
        return best, results

    per_k: dict[str, dict] = {}
    pruned_seconds = None
    multiphase_results = None
    for k in K_SWEEP:
        eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=k))
        for sh in eng.shards:
            sh.ensure_payloads()  # quantize+pack is startup cost, not timed
        best, results = run(eng)
        if k == 1:
            multiphase_results = results
        for r, e in zip(results, oracle):
            assert np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores), (
                f"K={k} must be bit-identical to brute-force BM25"
            )
        eng.reset_stats()
        eng.query_topk(queries, TOP_K)  # accounting for exactly one pass
        s = eng.metrics.snapshot()["ranked"]
        per_k[str(k)] = {
            "seconds": best,
            "qps": N_QUERIES / best,
            "scored_fraction": s["scored_fraction"],
            "touched_postings": s["touched_postings"],
            "exhaustive_postings": s["exhaustive_postings"],
        }
        if k == 1:
            pruned_seconds = best

    # exhaustive baseline on the same build: cutoff swallows every query
    exh = BooleanEngine(
        lb, inv, li_cfg, ServeConfig(n_shards=1, ranked=dict(topk_exhaustive_cutoff=1 << 30))
    )
    for sh in exh.shards:
        sh.ensure_payloads()
    exh_seconds, exh_results = run(exh)
    for r, e in zip(exh_results, oracle):
        assert np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores)

    # ---- fused one-dispatch kernel: exactness at K=1/K=4, then the ratios
    from repro.obs import Tracer

    fused_secs = {}
    fused_stats = None
    for k in K_SWEEP:
        eng_f = BooleanEngine(
            lb, inv, li_cfg, ServeConfig(n_shards=k, ranked=dict(fused_kernel=True))
        )
        for sh in eng_f.shards:
            sh.ensure_payloads()
        best_f, results_f = run(eng_f)
        fused_secs[k] = best_f
        for r, e, m in zip(results_f, oracle, multiphase_results):
            assert np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores), (
                f"fused K={k} must be bit-identical to brute-force BM25"
            )
            assert np.array_equal(r.ids, m.ids) and np.array_equal(r.scores, m.scores), (
                f"fused K={k} must be bit-identical to the multi-phase path"
            )
        if k == 1:
            eng_f.reset_stats()
            t0 = time.time()
            eng_f.query_topk(queries, TOP_K)  # accounting pass for the roofline
            fused_acct_seconds = time.time() - t0
            fused_stats = eng_f.metrics.snapshot()["ranked"]
            tracer = Tracer()  # one traced batch -> the CI fused-trace artifact
            eng_f.cfg.trace = tracer
            eng_f.query_topk(queries, TOP_K)
            eng_f.cfg.trace = None
            os.makedirs(os.path.dirname(FUSED_TRACE_PATH), exist_ok=True)
            tracer.save(FUSED_TRACE_PATH)

    # the configuration the fused kernel replaces: multi-phase with its probe
    # and scoring stages already on (interpret-mode) Pallas — hundreds of
    # small dispatches per batch vs one fused dispatch
    dev = BooleanEngine(
        lb, inv, li_cfg,
        ServeConfig(n_shards=1, guided_kernel=True, ranked=dict(score_kernel=True)),
    )
    for sh in dev.shards:
        sh.ensure_payloads()
    dev_seconds, dev_results = run(dev)
    for r, e in zip(dev_results, oracle):
        assert np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores)

    try:
        from benchmarks.roofline import index_roofline
    except ImportError:  # script mode: benchmarks/ itself is sys.path[0]
        from roofline import index_roofline

    fused_roof = index_roofline(
        fused_stats["fused_stream_bytes"],
        fused_stats["fused_device_bytes"],
        fused_stats["fused_lanes"],
        fused_acct_seconds,
        N_QUERIES,
        # device-timed roofline: the bridge's perf-counter split charges the
        # roof fraction to time actually blocked on device execution
        kernel_seconds=fused_stats["fused_kernel_ns"] / 1e9,
        bridge_seconds=fused_stats["fused_bridge_ns"] / 1e9,
    )
    fused = {
        "seconds": fused_secs[1],
        "qps": N_QUERIES / fused_secs[1],
        "per_k_seconds": {str(k): fused_secs[k] for k in K_SWEEP},
        # gated: one dispatch must beat the many-dispatch kernel pipeline
        "latency_ratio": fused_secs[1] / dev_seconds,
        "kernel_multiphase_seconds": dev_seconds,
        # gated: the arena-resident dense path must also beat the all-numpy
        # multi-phase host path outright
        "latency_ratio_host": fused_secs[1] / pruned_seconds,
        "fused_queries": fused_stats["fused_queries"],
        "fused_lanes": fused_stats["fused_lanes"],
        "kernel_seconds": fused_stats["fused_kernel_ns"] / 1e9,
        "bridge_seconds": fused_stats["fused_bridge_ns"] / 1e9,
        "roofline": fused_roof,
    }
    assert fused["latency_ratio"] < 1.0, (
        f"fused dispatch must beat the kernel multi-phase pipeline, got "
        f"{fused['latency_ratio']:.3f}"
    )
    assert fused["latency_ratio_host"] < 1.0, (
        f"arena-resident fused path must beat the numpy multi-phase path, "
        f"got {fused['latency_ratio_host']:.3f}"
    )

    scored_fraction = per_k["1"]["scored_fraction"]
    latency_ratio = pruned_seconds / exh_seconds
    traj = {
        "workload": {
            "n_docs": N_DOCS,
            "n_terms": N_TERMS,
            "n_postings": int(inv.n_postings),
            "n_queries": N_QUERIES,
            "top_k": TOP_K,
        },
        "k": per_k,
        # MaxScore + segment bounds vs exhaustive scoring, same run: the
        # fraction is deterministic (seeded corpus), the ratio machine-
        # normalized; both lower-is-better and gated
        "scored_fraction": scored_fraction,
        "latency_ratio": latency_ratio,
        "exhaustive": {"seconds": exh_seconds, "qps": N_QUERIES / exh_seconds},
        "fused": fused,
    }
    assert scored_fraction < 0.5, (
        f"MaxScore pruning must score < 0.5x of exhaustive, got {scored_fraction:.3f}"
    )
    rows = [
        (f"ranked/k{k}", 1e6 * per_k[str(k)]["seconds"] / N_QUERIES,
         f"qps={per_k[str(k)]['qps']:.1f}_scored_frac={per_k[str(k)]['scored_fraction']:.3f}")
        for k in K_SWEEP
    ]
    rows.append(("ranked/exhaustive", 1e6 * exh_seconds / N_QUERIES,
                 f"qps={N_QUERIES / exh_seconds:.1f}"))
    rows.append(("ranked/latency_ratio", 0.0, f"pruned_vs_exhaustive={latency_ratio:.3f}"))
    rows.append(("ranked/fused", 1e6 * fused_secs[1] / N_QUERIES,
                 f"qps={fused['qps']:.1f}_vs_kernel_multiphase={fused['latency_ratio']:.3f}"
                 f"_vs_host={fused['latency_ratio_host']:.3f}"))
    rows.append(("ranked/fused_roofline", 1e6 * fused_roof["roofline_s"],
                 f"dominant={fused_roof['dominant']}"
                 f"_hbm_frac={fused_roof['fraction_of_hbm_roof']:.2e}"
                 f"_stream_bytes={fused_roof['stream_bytes']}"))
    if write_json:
        with open(BENCH_PATH, "w") as f:
            json.dump(traj, f, indent=2)
        rows.append(("ranked/json", 0.0, f"wrote {BENCH_PATH}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in ranked_rows():
        print(f"{name},{us:.1f},{derived}")
