"""Paper-figure benchmarks: Fig 1 (df distribution + storage fraction),
Fig 2 (Eq.2 gain bounds vs truncation k), Fig 3 (% guaranteed-correct).

Each returns rows of (name, value, derived-notes); run.py prints CSV.
Collections are the calibrated synthetic stand-ins (DESIGN.md §5) at
CI scale (--scale to grow them)."""
from __future__ import annotations

import time

import numpy as np

from repro.common.config import PAPER_COLLECTIONS, scaled_collection
from repro.core.gain import gain_curve, storage_fraction_curve
from repro.core.algorithms import two_tier_guaranteed
from repro.data.corpus import document_frequencies, synthesize_corpus
from repro.data.queries import sample_queries
from repro.index.build import build_inverted_index

import jax.numpy as jnp

SCALE = 0.02  # 1/50 of the 1/100-scaled targets by default (CI-fast)
KS = (125, 250, 500, 1000, 2000, 4000)


def _collections(scale=SCALE):
    out = {}
    for name, base in PAPER_COLLECTIONS.items():
        # floor each collection at 2.5k docs so every truncation size in KS
        # is meaningful (Robust is 100x smaller than ClueWeb to begin with)
        eff = max(scale, 2500 / base.n_docs)
        cfg = scaled_collection(base, eff)
        corpus = synthesize_corpus(cfg)
        out[name] = (corpus, build_inverted_index(corpus))
    return out


def fig1_rows(colls=None):
    """df skew + min #terms at 40% of compressed storage (paper: <1%)."""
    rows = []
    colls = colls or _collections()
    for name, (corpus, inv) in colls.items():
        df = document_frequencies(corpus)
        t0 = time.time()
        cum, counts = storage_fraction_curve(inv)
        dt = (time.time() - t0) * 1e6
        n40 = int(counts[np.searchsorted(cum, 0.4)])
        frac = n40 / max(1, int((inv.dfs > 0).sum()))
        rows.append((f"fig1/{name}/terms_at_40pct_storage", dt,
                     f"n={n40} frac={frac:.4f} max_df={int(df.max())}"))
    return rows


def fig2_rows(colls=None):
    """Eq.(2) storage-gain bounds (s=0 upper, s=512 lower) vs k."""
    rows = []
    colls = colls or _collections()
    for name, (corpus, inv) in colls.items():
        ks = [k for k in KS if k < corpus.n_docs]
        t0 = time.time()
        curve = gain_curve(inv, ks)
        dt = (time.time() - t0) * 1e6 / max(1, len(ks))
        for g in curve:
            rows.append((
                f"fig2/{name}/k={g.k}", dt,
                f"gain_upper={g.gain_upper_frac:.3f} gain_lower={g.gain_lower_frac:.3f} "
                f"replaced={g.n_replaced}",
            ))
    return rows


def fig3_rows(colls=None, n_queries=2000):
    """% queries guaranteed-correct in tier-1, with vs without the model."""
    rows = []
    colls = colls or _collections()
    for name, (corpus, inv) in colls.items():
        q = sample_queries(corpus, n_queries, seed=17)
        dfs = jnp.asarray(inv.dfs.astype(np.int32))
        qj = jnp.asarray(q)
        ks = [k for k in KS if k < corpus.n_docs]
        for k in ks:
            t0 = time.time()
            w = float(np.asarray(two_tier_guaranteed(dfs, qj, k, with_model=True)).mean())
            wo = float(np.asarray(two_tier_guaranteed(dfs, qj, k, with_model=False)).mean())
            dt = (time.time() - t0) * 1e6
            rows.append((f"fig3/{name}/k={k}", dt,
                         f"guaranteed_with={w:.3f} without={wo:.3f} uplift={w-wo:.3f}"))
    return rows
