"""Learned-vs-classical postings compression on a Zipf-distributed corpus.

Emits the bits-per-posting comparison the paper's Eq. (2) analysis needs —
plm/rmi/hybrid against OptPFD/varbyte/Elias-Fano — as benchmark CSV rows and
as a ``BENCH_learned_postings.json`` trajectory file (one entry per codec +
the per-ε learned-storage sweep), so successive PRs can track the compression
frontier.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.common.config import CorpusConfig
from repro.core.gain import learned_storage_fractions
from repro.data.corpus import synthesize_corpus
from repro.index.build import build_inverted_index
from repro.index.compress import compressed_size_bits, index_size_bits

BENCH_PATH = "BENCH_learned_postings.json"
_CODECS = ("optpfd", "varbyte", "eliasfano", "plm", "rmi", "hybrid")


def _corpus():
    # Zipf-Mandelbrot synthetic collection (same generator the paper-fig
    # benchmarks use) — big enough for long smooth lists where models win.
    return synthesize_corpus(
        CorpusConfig(n_docs=4000, n_terms=30000, avg_doc_len=120, seed=7)
    )


def learned_rows(write_json: bool = True):
    inv = build_inverted_index(_corpus())
    rows, traj = [], {"n_docs": inv.n_docs, "n_postings": inv.n_postings, "codecs": {}}
    for codec in _CODECS:
        t0 = time.time()
        sizes = index_size_bits(inv.term_offsets, inv.doc_ids, inv.n_docs, codec)
        dt = (time.time() - t0) * 1e6
        bpp = float(sizes.sum() / inv.n_postings)
        traj["codecs"][codec] = {"bits_per_posting": bpp, "total_bits": int(sizes.sum())}
        rows.append((f"learned/{codec}", dt, f"bits_per_posting={bpp:.3f}"))
    traj["eps_sweep"] = [
        {
            "eps": r.eps,
            "frac_terms_learned": r.frac_terms_learned,
            "frac_bits_saved": r.frac_bits_saved,
            "hybrid_bits": r.hybrid_bits,
        }
        for r in learned_storage_fractions(inv, (7, 15, 63, 255))
    ]
    # clustered-ids regime: real collections assign nearby ids to related
    # docs (crawl order, URL sort), which is where rank models win — the
    # uniform synthetic corpus above has no learnable structure beyond density
    rng = np.random.default_rng(3)
    cl_rows = []
    for t in range(200):
        n_runs = int(rng.integers(2, 8))
        runs = []
        pos = 0
        for _ in range(n_runs):
            pos += int(rng.integers(1000, 200_000))
            ln = int(rng.integers(200, 2000))
            runs.append(np.arange(pos, pos + ln * 2, 2))
            pos += ln * 2
        cl_rows.append(np.concatenate(runs).astype(np.int32))
    uni = int(max(r[-1] for r in cl_rows)) + 1
    for codec in ("optpfd", "plm", "hybrid"):
        t0 = time.time()
        bits = sum(int(compressed_size_bits(r, uni, codec)) for r in cl_rows)
        dt = (time.time() - t0) * 1e6
        n_post = sum(len(r) for r in cl_rows)
        bpp = bits / n_post
        traj["codecs"][f"clustered/{codec}"] = {"bits_per_posting": bpp, "total_bits": bits}
        rows.append((f"learned/clustered_{codec}", dt, f"bits_per_posting={bpp:.3f}"))
    if write_json:
        with open(BENCH_PATH, "w") as f:
            json.dump(traj, f, indent=2)
        rows.append((f"learned/json", 0.0, f"wrote {BENCH_PATH}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in learned_rows():
        print(f"{name},{us:.1f},{derived}")
