"""Codec compression-ratio table (paper §4 uses OptPFOR) + kernel micro-bench
(interpret-mode wall time is NOT a TPU number — correctness/plumbing only;
TPU perf comes from the §Roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import CorpusConfig
from repro.data.corpus import synthesize_corpus
from repro.index.build import build_inverted_index
from repro.index.compress import compressed_size_bits, index_size_bits

# classical codecs only here — the learned codecs (plm/rmi/hybrid) get their
# own benchmarks/learned_postings.py section with the per-ε sweep
_CLASSICAL = ("optpfd", "varbyte", "eliasfano", "bitvector")


def codec_rows():
    corpus = synthesize_corpus(CorpusConfig(n_docs=4000, n_terms=30000, avg_doc_len=120, seed=4))
    inv = build_inverted_index(corpus)
    raw_bits = inv.n_postings * 32
    rows = []
    for codec in _CLASSICAL:
        t0 = time.time()
        sizes = index_size_bits(inv.term_offsets, inv.doc_ids, inv.n_docs, codec)
        dt = (time.time() - t0) * 1e6
        ratio = raw_bits / max(1, int(sizes.sum()))
        bpp = sizes.sum() / inv.n_postings
        rows.append((f"codec/{codec}", dt, f"ratio_vs_raw32={ratio:.2f} bits_per_posting={bpp:.2f}"))
    return rows


def unpack_rows():
    """Windowed unpack_bits_at vs full-stream unpack on one packed stream.

    The fused ranked kernel's premise in numbers: an ε-window probe touches
    ~window*width bits per candidate, so decoding 2048 windows of 8 ranks
    each should cost a small fraction of unpacking the whole 256K-value
    stream — the host-side analogue of what compression buys the probe path.
    """
    from repro.index.compress import pack_bits, unpack_bits, unpack_bits_at

    rng = np.random.default_rng(11)
    n, width, n_windows, win = 1 << 18, 9, 2048, 8
    vals = rng.integers(0, 1 << width, size=n, dtype=np.uint32)
    words = pack_bits(vals, width)
    starts = rng.integers(0, n - win, size=n_windows, dtype=np.int64)
    idx = (starts[:, None] + np.arange(win, dtype=np.int64)[None, :]).ravel()

    def _host_us(fn, reps=5):
        fn()  # warm caches
        t0 = time.time()
        for _ in range(reps):
            fn()
        return (time.time() - t0) / reps * 1e6

    full_us = _host_us(lambda: unpack_bits(words, width, n))
    win_us = _host_us(lambda: unpack_bits_at(words, width, idx))
    got = unpack_bits_at(words, width, idx)
    assert np.array_equal(got, vals[idx]), "windowed unpack must match the stream"
    frac = len(idx) / n
    return [
        (f"codec/unpack_full_w{width}", full_us, f"{n} vals, whole stream"),
        (f"codec/unpack_window_w{width}", win_us,
         f"{n_windows}x{win} windows ({frac:.3f} of stream) "
         f"speedup_vs_full={full_us / max(win_us, 1e-9):.1f}x"),
    ]


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def kernel_rows():
    from repro.kernels.membership.kernel import membership_bitmask, Q_BLK, D_BLK
    from repro.kernels.bitset.kernel import bitset_and_popcount, W_BLK
    from repro.kernels.pfor.kernel import unpack_blocks
    from repro.kernels.pfor.ref import words_per_block

    rng = np.random.default_rng(0)
    rows = []
    q = jnp.asarray(rng.standard_normal((Q_BLK, 128)).astype(np.float32))
    d = jnp.asarray(rng.standard_normal((D_BLK * 4, 128)).astype(np.float32))
    tau = jnp.asarray(rng.standard_normal(Q_BLK).astype(np.float32))
    us = _time(lambda: membership_bitmask(q, d, tau, jnp.float32(0.0)))
    flops = 2 * Q_BLK * D_BLK * 4 * 128
    rows.append(("kernel/membership_128x2048", us, f"interpret-mode; {flops/1e6:.1f} MFLOP/call"))

    maps = jnp.asarray(rng.integers(0, 2**32, size=(8, 4, W_BLK), dtype=np.uint32))
    valid = jnp.ones((8, 4), jnp.int32)
    us = _time(lambda: bitset_and_popcount(maps, valid))
    rows.append(("kernel/bitset_8x4x1024", us, f"{8*4*W_BLK*4/1024:.0f} KiB ANDed/call"))

    width = 13
    words = jnp.asarray(rng.integers(0, 2**32, size=(64, words_per_block(width)), dtype=np.uint32))
    us = _time(lambda: unpack_blocks(words, width=width))
    rows.append((f"kernel/pfor_unpack_w{width}", us, f"{64*128} ints/call"))

    from repro.kernels.plm_decode.kernel import decode_batch
    from repro.kernels.plm_decode.ref import SENTINEL

    B, S, R = 16, 8, 512
    starts = np.full((B, S), int(SENTINEL), np.int32)
    starts[:, :4] = np.arange(4, dtype=np.int32) * (R // 4)
    bases = rng.integers(0, 2**20, size=(B, S)).astype(np.int32)
    slopes = rng.standard_normal((B, S)).astype(np.float32) * 50
    corr = rng.integers(-32, 32, size=(B, R)).astype(np.int32)
    args = tuple(jnp.asarray(a) for a in (starts, bases, slopes, corr))
    us = _time(lambda: decode_batch(*args))
    rows.append((f"kernel/plm_decode_{B}x{R}", us, f"{B*R} learned-codec ids/call"))
    return rows
