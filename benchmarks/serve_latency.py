"""Open-loop serving latency under Poisson arrivals + tracing overhead.

The ROADMAP's throughput-serving question needs tail latency, not just
mean qps: a closed loop (issue the next query when the previous returns)
hides queueing entirely, so this bench replays a Poisson arrival process
against measured per-query service times — the standard open-loop replay:
each query is executed once for its real service time, and completion
times follow the single-server queue recurrence

    start_i = max(arrival_i, completion_{i-1});  latency = completion - arrival

at an offered load of UTILIZATION x the calibrated service rate.  The
workload mixes batch-of-1 conjunctive Boolean queries with ranked top-K
disjunctions, both checked exact against brute force during warmup.

The second question this answers is what observability costs: interleaved
closed-loop passes with the span tracer off/on give trace_overhead_ratio
(best-of-N mean service time, traced / untraced — wall-clock but machine-
normalized within one run, gated by check_regression.py with a 1.05 floor:
tracing must stay within ~5% everywhere).  The probe log stays enabled for
every pass so the ratio isolates the tracer itself.  The gated ratio is
measured on the *distributed* path — the continuous-batching Session over
one process replica per shard, where tracing additionally pays TraceContext
IPC, worker span shipping, and host-side collation — because that is the
path a deployment actually runs; the in-process facade measure is kept as
trace_overhead_ratio_inline.  The traced sched passes also self-check the
distributed timeline: merged worker spans must be present (pid != 0 lanes)
and nesting_violations() must come back empty after clock alignment.

Emits BENCH_serve_latency.json:
  open_loop.p50_ms / p99_ms / qps   queue latency percentiles at UTILIZATION
  closed_loop.*_ms                  calibrated per-kind service means
  trace_overhead_ratio              traced / untraced service time through
                                    the sched/process-replica path (gated)
  trace_overhead_ratio_inline       same measure on the in-process facade
  latency_ratio                     open-loop p99/p50 — tail amplification
                                    from queueing, machine-normalized (gated)
  fused.roofline                    the ranked workload re-served through the
                                    fused kernel (ServeConfig.fused_kernel),
                                    positioned by benchmarks/roofline
                                    index_roofline against the HBM roof
plus, under the gitignored artifacts/ dir (CI uploads from there):
  serve_latency.trace.json    Chrome-trace of the final traced sched pass —
                              host + worker pid lanes on one clock-aligned
                              timeline; open in ui.perfetto.dev
  serve_latency.probes.jsonl  routed-probe records (worker records forwarded
                              to the host sink)
  serve_latency.slo.json      Session.slo_report() after the sched passes
  serve_latency.prom          the same report in Prometheus text exposition

``--sustained`` runs the sustained-load mode instead (``sustained_rows``):
the continuous-batching Session over process replicas vs the serial facade
— a closed-loop saturation pass for the gated qps_ratio (submit-all/drain,
timed exactly like the serial baseline), a real-time Poisson rate sweep
with exactness asserted for every admitted result (the latency curve), and
an overload pass with deadlines.  Emits
BENCH_serve_sustained.json (summary.qps_ratio and overload.p99_over_deadline
are gated) and artifacts/serve_sustained.curve.json (the rate->latency
curve, uploaded as a CI artifact).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

# telemetry artifacts (traces, probe logs, SLO reports, curves) land in a
# gitignored dir; only the BENCH_*.json summaries live at the repo root
ART_DIR = "artifacts"
BENCH_PATH = "BENCH_serve_latency.json"
TRACE_PATH = os.path.join(ART_DIR, "serve_latency.trace.json")
PROBE_PATH = os.path.join(ART_DIR, "serve_latency.probes.jsonl")
SLO_PATH = os.path.join(ART_DIR, "serve_latency.slo.json")
PROM_PATH = os.path.join(ART_DIR, "serve_latency.prom")

N_DOCS = 2048
N_TERMS = 4000
AVG_DOC_LEN = 60
N_BOOLEAN = 48
N_RANKED = 24
TOPK = 10
TRAIN_STEPS = 100
N_SHARDS = 2
UTILIZATION = 0.6  # offered load relative to the calibrated service rate
REPS = 3  # off/on passes per tracer state (mean service, best pass taken)
SCHED_REPLICAS = 1  # process replicas per shard for the sched-path measure
SEED = 23

# ---- sustained-load mode (scheduler vs serial fan-out)
SUSTAINED_PATH = "BENCH_serve_sustained.json"
CURVE_PATH = os.path.join(ART_DIR, "serve_sustained.curve.json")
SUS_SHARDS = 4  # the K where the retired thread fan-out convoyed
SUS_REPLICAS = 1  # process replicas per shard
SUS_MAX_BATCH = 16
SUS_REQUESTS = 160  # requests per sweep rate
RATE_MULTIPLIERS = (0.5, 1.0, 2.0, 4.0)  # offered load relative to serial qps
OVERLOAD_MULTIPLIER = 4.0
OVERLOAD_REQUESTS = 400
# deadline expiry happens at dispatch time, so an admitted request's worst
# case is ~deadline + one batch service time; the budget must dominate the
# per-batch service cost (~15-40 ms here) for p99_over_deadline to measure
# shedding rather than service jitter
OVERLOAD_DEADLINE_MS = 100.0


def _system():
    import jax
    import jax.numpy as jnp

    from repro.common.config import CorpusConfig, LearnedIndexConfig, OptimizerConfig
    from repro.core import fit_thresholds, init_membership, membership_loss
    from repro.data.corpus import synthesize_corpus
    from repro.data.loader import membership_batches
    from repro.index.build import build_inverted_index
    from repro.train import init_train_state, make_train_step

    corpus = synthesize_corpus(
        CorpusConfig(n_docs=N_DOCS, n_terms=N_TERMS, avg_doc_len=AVG_DOC_LEN, seed=SEED)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=32, truncation_k=32, block_size=128)
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    ocfg = OptimizerConfig(lr=0.05, warmup_steps=10, total_steps=TRAIN_STEPS,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b), ocfg))
    st = init_train_state(params, ocfg)
    for _, batch in zip(range(TRAIN_STEPS), membership_batches(corpus, batch_size=2048)):
        params, st, _ = step(params, st, {k: jnp.asarray(v) for k, v in batch.items()})
    lb = fit_thresholds(params, inv)
    return corpus, inv, li_cfg, lb


def _mean_service(eng, work) -> float:
    """One closed-loop pass over the mixed workload -> mean seconds/query."""
    t0 = time.perf_counter()
    for kind, q in work:
        if kind == "bool":
            eng.query_batch([q])
        else:
            eng.query_topk([q], TOPK)
    return (time.perf_counter() - t0) / len(work)


def _sched_service(session, work) -> float:
    """Closed-loop pass through the Session -> mean seconds/query.

    One request in flight at a time, so every dispatch is a batch of one and
    the per-request trace cost (context IPC + span shipping + collation) is
    maximally exposed rather than amortized over coalesced batches.
    """
    from repro.serve.sched import MODE_RANKED, QueryRequest

    t0 = time.perf_counter()
    for kind, q in work:
        req = (QueryRequest(terms=q) if kind == "bool"
               else QueryRequest(terms=q, mode=MODE_RANKED, k=TOPK))
        r = session.submit_async(req, block=True).result(timeout=60)
        assert r.ok, r
    return (time.perf_counter() - t0) / len(work)


def latency_rows(write_json: bool = True):
    from repro.data.queries import (
        brute_force_answers, zipf_conjunctions, zipf_disjunctions,
    )
    from repro.obs import ProbeLog, Tracer
    from repro.rank.score import ImpactModel, brute_force_topk
    from repro.serve import BooleanEngine, ServeConfig

    if write_json:
        os.makedirs(ART_DIR, exist_ok=True)
    corpus, inv, li_cfg, lb = _system()
    probe_log = ProbeLog(PROBE_PATH if write_json else None)
    cfg = ServeConfig(n_shards=N_SHARDS, obs=dict(probe_log=probe_log))
    eng = BooleanEngine(lb, inv, li_cfg, cfg)
    for sh in eng.shards:
        sh.tier2  # codec selection out of every timed region

    bool_q = zipf_conjunctions(inv.dfs, N_BOOLEAN, seed=SEED + 1)
    ranked_q, _ = zipf_disjunctions(inv.dfs, N_RANKED, seed=SEED + 2)
    rng = np.random.default_rng(SEED)
    work = [("bool", q) for q in bool_q] + [("topk", q) for q in ranked_q]
    work = [work[i] for i in rng.permutation(len(work))]

    # ---- warmup + exactness: the engine must stay bit-exact while observed
    res = eng.query_batch(bool_q)
    for r, e in zip(res, brute_force_answers(corpus, bool_q)):
        assert np.array_equal(r, e), "boolean serving must be exact"
    im = eng.impact_model or ImpactModel.build(inv)
    oracle = brute_force_topk(inv, im, ranked_q, TOPK)
    for r, e in zip(eng.query_topk(ranked_q, TOPK), oracle):
        assert np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores), \
            "ranked serving must match brute-force BM25"

    # ---- tracing overhead (facade): interleaved off/on closed-loop passes
    tracer = Tracer()
    off_s, on_s = [], []
    for _ in range(REPS):
        eng.cfg.trace = None
        off_s.append(_mean_service(eng, work))
        eng.cfg.trace = tracer
        tracer.reset()
        on_s.append(_mean_service(eng, work))
    eng.cfg.trace = None
    trace_overhead_inline = min(on_s) / min(off_s)

    # ---- open loop: Poisson arrivals at UTILIZATION x the service rate
    service = min(off_s)
    rate = UTILIZATION / service
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(work)))
    lat = np.zeros(len(work))
    clock = 0.0
    t_wall = time.perf_counter()
    for i, (kind, q) in enumerate(work):
        t0 = time.perf_counter()
        if kind == "bool":
            eng.query_batch([q])
        else:
            eng.query_topk([q], TOPK)
        svc = time.perf_counter() - t0
        clock = max(clock, arrivals[i]) + svc
        lat[i] = clock - arrivals[i]
    wall = time.perf_counter() - t_wall
    p50, p90, p99 = (float(np.percentile(lat, p)) for p in (50, 90, 99))

    # ---- fused ranked path: the same ranked workload through the fused
    # kernel (ServeConfig.fused_kernel), positioned against the HBM roof
    try:
        from benchmarks.roofline import index_roofline
    except ImportError:  # script mode: benchmarks/ itself is sys.path[0]
        from roofline import index_roofline

    feng = BooleanEngine(
        lb, inv, li_cfg, ServeConfig(n_shards=N_SHARDS, ranked=dict(fused_kernel=True))
    )
    for sh in feng.shards:
        sh.ensure_payloads()
    for r, e in zip(feng.query_topk(ranked_q, TOPK), oracle):
        assert np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores), \
            "fused ranked serving must match brute-force BM25"
    feng.reset_stats()
    t0 = time.perf_counter()
    feng.query_topk(ranked_q, TOPK)  # accounting pass (jit warmed above)
    fused_seconds = time.perf_counter() - t0
    fs = feng.metrics.snapshot()["ranked"]
    fused_roof = index_roofline(
        fs["fused_stream_bytes"], fs["fused_device_bytes"], fs["fused_lanes"],
        fused_seconds, N_RANKED,
        kernel_seconds=fs["fused_kernel_ns"] / 1e9,
        bridge_seconds=fs["fused_bridge_ns"] / 1e9,
    )

    # ---- tracing overhead (gated): the same interleaved off/on measure
    # through the continuous-batching Session over process replicas, where
    # tracing also pays TraceContext IPC, worker span shipping, and host-side
    # clock-aligned collation.  The probe log stays on for every pass here
    # too (worker records forward to the host sink regardless of the tracer)
    # so the ratio again isolates the tracer.
    from repro.obs import nesting_violations
    from repro.serve import Session

    sched_tracer = Tracer()
    eng.cfg.sched.n_replicas = SCHED_REPLICAS
    sched_off, sched_on = [], []
    try:
        with tempfile.TemporaryDirectory() as store_dir:
            with Session(eng, store_dir=store_dir) as session:
                session.warm()  # spawn + jit outside every timed region
                for _ in range(REPS):
                    eng.cfg.trace = None
                    sched_off.append(_sched_service(session, work))
                    eng.cfg.trace = sched_tracer
                    sched_tracer.reset()
                    sched_on.append(_sched_service(session, work))
                eng.cfg.trace = None
                slo_rep = session.slo_report()
    finally:
        eng.cfg.trace = None
        eng.cfg.sched.n_replicas = 0
    trace_overhead = min(sched_on) / min(sched_off)

    # the final traced pass must have produced a coherent distributed
    # timeline: worker spans merged into the host tracer on non-host pid
    # lanes, and every lane stack-consistent after clock alignment
    worker_spans = [s for s in sched_tracer.spans if s.pid != 0]
    assert worker_spans, "traced sched pass merged no worker spans"
    wnames = {s.name for s in worker_spans}
    assert wnames & {"probe.term", "decode.postings", "shard.verify",
                     "shard.topk_batch", "worker.bool", "worker.topk"}, wnames
    violations = nesting_violations(sched_tracer.spans, slack_us=0.5)
    assert not violations, violations[:3]

    metrics_lat = eng.metrics.snapshot().get("latency", {})
    traj = {
        "workload": {
            "n_docs": N_DOCS,
            "n_terms": N_TERMS,
            "n_postings": int(inv.n_postings),
            "n_boolean": N_BOOLEAN,
            "n_ranked": N_RANKED,
            "topk": TOPK,
            "n_shards": N_SHARDS,
            "utilization": UTILIZATION,
        },
        "closed_loop": {
            "service_ms": 1e3 * service,
            "untraced_ms": [1e3 * s for s in off_s],
            "traced_ms": [1e3 * s for s in on_s],
        },
        "sched_loop": {
            "n_replicas": SCHED_REPLICAS,
            "untraced_ms": [1e3 * s for s in sched_off],
            "traced_ms": [1e3 * s for s in sched_on],
            "worker_span_names": sorted(wnames),
            "worker_pids": sorted({s.pid for s in worker_spans}),
        },
        "open_loop": {
            "offered_qps": rate,
            "qps": len(work) / wall,
            "p50_ms": 1e3 * p50,
            "p90_ms": 1e3 * p90,
            "p99_ms": 1e3 * p99,
            "n_queries": len(work),
        },
        # traced/untraced mean service within one run — machine-normalized;
        # the span tracer must cost ~nothing when off and <5% when on.  The
        # gated ratio runs through the sched/process-replica path (context
        # IPC + span shipping + collation included); _inline is the facade.
        "trace_overhead_ratio": trace_overhead,
        "trace_overhead_ratio_inline": trace_overhead_inline,
        # open-loop tail amplification (queueing + service variance) within
        # one run; a generous floor absorbs scheduler noise on shared CI
        "latency_ratio": p99 / p50,
        "fused": {
            "seconds": fused_seconds,
            "fused_queries": fs["fused_queries"],
            "fused_lanes": fs["fused_lanes"],
            "roofline": fused_roof,
        },
        "engine_histograms": metrics_lat,
    }
    rows = [
        ("serve_latency/p50", 1e6 * p50, f"p99_ms={1e3 * p99:.2f}"),
        ("serve_latency/qps", 0.0,
         f"qps={traj['open_loop']['qps']:.1f}_offered={rate:.1f}"),
        ("serve_latency/trace_overhead", 0.0,
         f"sched={trace_overhead:.3f}_inline={trace_overhead_inline:.3f}"
         f"_worker_lanes={len(set(s.pid for s in worker_spans))}"),
        ("serve_latency/fused_roofline", 1e6 * fused_roof["roofline_s"],
         f"dominant={fused_roof['dominant']}"
         f"_hbm_frac={fused_roof['fraction_of_hbm_roof']:.2e}"),
    ]
    if write_json:
        with open(BENCH_PATH, "w") as f:
            json.dump(traj, f, indent=2)
        # the distributed trace (host + worker lanes) is the artifact worth
        # keeping — the inline tracer's spans are a strict subset of it
        sched_tracer.save(TRACE_PATH)
        probe_log.close()
        with open(SLO_PATH, "w") as f:
            json.dump(slo_rep, f, indent=2)
        from repro.obs import write_prometheus

        write_prometheus({"sched": slo_rep["sched"], "latency": metrics_lat},
                         PROM_PATH)
        rows.append(("serve_latency/json", 0.0,
                     f"wrote {BENCH_PATH}+{ART_DIR}/(trace+probes+slo+prom)"))
    return rows


def _sustained_workload(corpus, inv, eng):
    """The request mix + its exact answers (asserted at every rate)."""
    from repro.data.queries import (
        brute_force_answers, zipf_conjunctions, zipf_disjunctions,
    )
    from repro.serve.sched import MODE_RANKED, QueryRequest

    bool_q = zipf_conjunctions(inv.dfs, N_BOOLEAN, seed=SEED + 1)
    ranked_q, _ = zipf_disjunctions(inv.dfs, N_RANKED, seed=SEED + 2)
    bool_ans = eng.query_batch(bool_q)
    for r, e in zip(bool_ans, brute_force_answers(corpus, bool_q)):
        assert np.array_equal(r, e), "boolean serving must be exact"
    ranked_ans = eng.query_topk(ranked_q, TOPK)
    work = [
        (QueryRequest(terms=q), (a, None)) for q, a in zip(bool_q, bool_ans)
    ] + [
        (QueryRequest(terms=q, mode=MODE_RANKED, k=TOPK), (a.ids, a.scores))
        for q, a in zip(ranked_q, ranked_ans)
    ]
    rng = np.random.default_rng(SEED + 3)
    return [work[i] for i in rng.permutation(len(work))]


def _open_loop(session, work, rate, n_requests, rng, *, deadline_ms=None):
    """Submit ``n_requests`` at real-time Poisson arrivals; collect outcomes.

    Returns (admitted latencies seconds, shed outcomes, wall seconds).
    Every admitted result is asserted bit-identical to the engine's answer.
    """
    from repro.serve.sched import QueryRequest, Rejected

    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    submitted_at = np.zeros(n_requests)
    done_at = np.zeros(n_requests)

    def _done(i):
        def cb(_fut):
            done_at[i] = time.monotonic()
        return cb

    futs = []
    t0 = time.monotonic()
    for i in range(n_requests):
        req, _ = work[i % len(work)]
        wait = t0 + arrivals[i] - time.monotonic()
        if wait > 0:
            time.sleep(wait)
        # latency is measured from the actual submit instant: sleep()
        # overshoot at sub-ms inter-arrival gaps is pacing drift on the
        # load generator, not scheduler queueing
        submitted_at[i] = time.monotonic()
        f = session.submit_async(
            QueryRequest(terms=req.terms, mode=req.mode, k=req.k,
                         deadline_ms=deadline_ms)
        )
        f.add_done_callback(_done(i))
        futs.append(f)
    results = [f.result(timeout=60) for f in futs]
    wall = time.monotonic() - t0

    lat, shed = [], []
    for i, r in enumerate(results):
        if isinstance(r, Rejected):
            shed.append(r)
            continue
        _, (ids, scores) = work[i % len(work)]
        assert np.array_equal(r.ids, ids), "scheduler must stay bit-exact"
        if scores is not None:
            assert np.array_equal(r.scores, scores)
        lat.append(done_at[i] - submitted_at[i])
    return np.asarray(lat), shed, wall


def sustained_rows(write_json: bool = True):
    """Sustained-load mode: the scheduler vs serial fan-out at K shards."""
    from repro.serve import BooleanEngine, ServeConfig, Session

    if write_json:
        os.makedirs(ART_DIR, exist_ok=True)
    corpus, inv, li_cfg, lb = _system()
    cfg = ServeConfig(
        n_shards=SUS_SHARDS,
        sched=dict(n_replicas=SUS_REPLICAS, max_batch=SUS_MAX_BATCH),
    )
    eng = BooleanEngine(lb, inv, li_cfg, cfg)
    for sh in eng.shards:
        sh.tier2  # codec selection out of every timed region
    work = _sustained_workload(corpus, inv, eng)
    rng = np.random.default_rng(SEED + 4)

    # ---- serial baseline: the facade engine, one request at a time (what a
    # caller got before the scheduler existed: in-process serial fan-out)
    serial_qps = 0.0
    for _ in range(2):  # best of 2 (first pass absorbs any remaining warmup)
        t0 = time.perf_counter()
        for req, _ in work:
            if req.mode == "boolean":
                eng.query_batch([req.terms])
            else:
                eng.query_topk([req.terms], TOPK)
        serial_qps = max(serial_qps, len(work) / (time.perf_counter() - t0))

    sweep = []
    with tempfile.TemporaryDirectory() as store_dir:
        with Session(eng, store_dir=store_dir) as session:
            session.warm()  # spawn + engine rebuild outside every timed region

            # ---- scheduler saturation throughput, measured closed-loop
            # exactly like the serial baseline (submit everything, drain,
            # best of 2).  The gated qps_ratio compares like with like: the
            # open-loop sweep below is kept for the latency curve, but its
            # achieved qps rides on Poisson pacing from a GIL-contended
            # generator thread and is too noisy to gate on.
            sched_qps = 0.0
            for _ in range(2):
                t0 = time.perf_counter()
                futs = [session.submit_async(req, block=True)
                        for req, _ in work]
                results = [f.result(timeout=60) for f in futs]
                dt = time.perf_counter() - t0
                for r, (_, (ids, scores)) in zip(results, work):
                    assert r.ok and np.array_equal(r.ids, ids), \
                        "scheduler must stay bit-exact"
                    if scores is not None:
                        assert np.array_equal(r.scores, scores)
                sched_qps = max(sched_qps, len(work) / dt)

            for mult in RATE_MULTIPLIERS:
                rate = mult * serial_qps
                lat, shed, wall = _open_loop(
                    session, work, rate, SUS_REQUESTS, rng
                )
                assert not shed, "no deadline, queue below bound: nothing sheds"
                sweep.append({
                    "rate_x": mult,
                    "offered_qps": rate,
                    "qps": len(lat) / wall,
                    "p50_ms": 1e3 * float(np.percentile(lat, 50)),
                    "p99_ms": 1e3 * float(np.percentile(lat, 99)),
                    "admitted": len(lat),
                    "shed": 0,
                })

            # ---- overload: offered far past capacity with a deadline; the
            # admitted tail stays bounded and the rest sheds *typed*
            lat, shed, wall = _open_loop(
                session, work, OVERLOAD_MULTIPLIER * serial_qps,
                OVERLOAD_REQUESTS, rng, deadline_ms=OVERLOAD_DEADLINE_MS,
            )
            assert shed, "overload past capacity must shed"
            reasons = sorted({r.reason for r in shed})
            assert set(reasons) <= {"deadline", "queue_full"}, reasons
            overload = {
                "offered_qps": OVERLOAD_MULTIPLIER * serial_qps,
                "deadline_ms": OVERLOAD_DEADLINE_MS,
                "admitted": len(lat),
                "shed": len(shed),
                "shed_reasons": reasons,
                "p99_ms": 1e3 * float(np.percentile(lat, 99)),
                # gated: deadline shedding must keep the admitted tail near
                # the deadline budget even at 4x offered load
                "p99_over_deadline": float(np.percentile(lat, 99))
                / (OVERLOAD_DEADLINE_MS / 1e3),
            }
            sched_snapshot = eng.metrics.snapshot().get("sched", {})

    traj = {
        "workload": {
            "n_docs": N_DOCS,
            "n_terms": N_TERMS,
            "n_boolean": N_BOOLEAN,
            "n_ranked": N_RANKED,
            "topk": TOPK,
            "n_shards": SUS_SHARDS,
            "n_replicas": SUS_REPLICAS,
            "max_batch": SUS_MAX_BATCH,
            "requests_per_rate": SUS_REQUESTS,
        },
        "summary": {
            "serial_qps": serial_qps,
            "sched_qps": sched_qps,
            # gated (lower is better, floor 1.0): the process-worker
            # scheduler must at least match serial fan-out qps at K shards
            "qps_ratio": serial_qps / sched_qps,
        },
        "sweep": sweep,
        "overload": overload,
        "sched_metrics": sched_snapshot,
    }
    rows = [
        ("serve_sustained/qps", 0.0,
         f"serial={serial_qps:.1f}_sched={sched_qps:.1f}"
         f"_ratio={traj['summary']['qps_ratio']:.3f}"),
        ("serve_sustained/overload", 0.0,
         f"admitted_p99_ms={overload['p99_ms']:.1f}_shed={overload['shed']}"),
    ]
    if write_json:
        with open(SUSTAINED_PATH, "w") as f:
            json.dump(traj, f, indent=2)
        with open(CURVE_PATH, "w") as f:
            json.dump({"sweep": sweep, "overload": overload}, f, indent=2)
        rows.append(
            ("serve_sustained/json", 0.0, f"wrote {SUSTAINED_PATH}+{CURVE_PATH}")
        )
    return rows


if __name__ == "__main__":
    mode = sustained_rows if "--sustained" in sys.argv[1:] else latency_rows
    for name, us, derived in mode():
        print(f"{name},{us:.1f},{derived}")
