"""Open-loop serving latency under Poisson arrivals + tracing overhead.

The ROADMAP's throughput-serving question needs tail latency, not just
mean qps: a closed loop (issue the next query when the previous returns)
hides queueing entirely, so this bench replays a Poisson arrival process
against measured per-query service times — the standard open-loop replay:
each query is executed once for its real service time, and completion
times follow the single-server queue recurrence

    start_i = max(arrival_i, completion_{i-1});  latency = completion - arrival

at an offered load of UTILIZATION x the calibrated service rate.  The
workload mixes batch-of-1 conjunctive Boolean queries with ranked top-K
disjunctions, both checked exact against brute force during warmup.

The second question this answers is what observability costs: interleaved
closed-loop passes with the span tracer off/on give trace_overhead_ratio
(best-of-N mean service time, traced / untraced — wall-clock but machine-
normalized within one run, gated by check_regression.py with a 1.05 floor:
tracing must stay within ~5% everywhere).  The probe log stays enabled for
every pass so the ratio isolates the tracer itself.

Emits BENCH_serve_latency.json:
  open_loop.p50_ms / p99_ms / qps   queue latency percentiles at UTILIZATION
  closed_loop.*_ms                  calibrated per-kind service means
  trace_overhead_ratio              traced / untraced service time (gated)
  latency_ratio                     open-loop p99/p50 — tail amplification
                                    from queueing, machine-normalized (gated)
plus serve_latency.trace.json (Chrome-trace of the final traced pass; open
in ui.perfetto.dev) and serve_latency.probes.jsonl (routed-probe records).
"""
from __future__ import annotations

import json
import time

import numpy as np

BENCH_PATH = "BENCH_serve_latency.json"
TRACE_PATH = "serve_latency.trace.json"
PROBE_PATH = "serve_latency.probes.jsonl"

N_DOCS = 2048
N_TERMS = 4000
AVG_DOC_LEN = 60
N_BOOLEAN = 48
N_RANKED = 24
TOPK = 10
TRAIN_STEPS = 100
N_SHARDS = 2
UTILIZATION = 0.6  # offered load relative to the calibrated service rate
REPS = 3  # off/on passes per tracer state (mean service, best pass taken)
SEED = 23


def _system():
    import jax
    import jax.numpy as jnp

    from repro.common.config import CorpusConfig, LearnedIndexConfig, OptimizerConfig
    from repro.core import fit_thresholds, init_membership, membership_loss
    from repro.data.corpus import synthesize_corpus
    from repro.data.loader import membership_batches
    from repro.index.build import build_inverted_index
    from repro.train import init_train_state, make_train_step

    corpus = synthesize_corpus(
        CorpusConfig(n_docs=N_DOCS, n_terms=N_TERMS, avg_doc_len=AVG_DOC_LEN, seed=SEED)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=32, truncation_k=32, block_size=128)
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    ocfg = OptimizerConfig(lr=0.05, warmup_steps=10, total_steps=TRAIN_STEPS,
                           weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b), ocfg))
    st = init_train_state(params, ocfg)
    for _, batch in zip(range(TRAIN_STEPS), membership_batches(corpus, batch_size=2048)):
        params, st, _ = step(params, st, {k: jnp.asarray(v) for k, v in batch.items()})
    lb = fit_thresholds(params, inv)
    return corpus, inv, li_cfg, lb


def _mean_service(eng, work) -> float:
    """One closed-loop pass over the mixed workload -> mean seconds/query."""
    t0 = time.perf_counter()
    for kind, q in work:
        if kind == "bool":
            eng.query_batch([q])
        else:
            eng.query_topk([q], TOPK)
    return (time.perf_counter() - t0) / len(work)


def latency_rows(write_json: bool = True):
    from repro.data.queries import (
        brute_force_answers, zipf_conjunctions, zipf_disjunctions,
    )
    from repro.obs import ProbeLog, Tracer
    from repro.rank.score import ImpactModel, brute_force_topk
    from repro.serve import BooleanEngine, ServeConfig

    corpus, inv, li_cfg, lb = _system()
    probe_log = ProbeLog(PROBE_PATH if write_json else None)
    cfg = ServeConfig(n_shards=N_SHARDS, probe_log=probe_log)
    eng = BooleanEngine(lb, inv, li_cfg, cfg)
    for sh in eng.shards:
        sh.tier2  # codec selection out of every timed region

    bool_q = zipf_conjunctions(inv.dfs, N_BOOLEAN, seed=SEED + 1)
    ranked_q, _ = zipf_disjunctions(inv.dfs, N_RANKED, seed=SEED + 2)
    rng = np.random.default_rng(SEED)
    work = [("bool", q) for q in bool_q] + [("topk", q) for q in ranked_q]
    work = [work[i] for i in rng.permutation(len(work))]

    # ---- warmup + exactness: the engine must stay bit-exact while observed
    res = eng.query_batch(bool_q)
    for r, e in zip(res, brute_force_answers(corpus, bool_q)):
        assert np.array_equal(r, e), "boolean serving must be exact"
    im = eng.impact_model or ImpactModel.build(inv)
    oracle = brute_force_topk(inv, im, ranked_q, TOPK)
    for r, e in zip(eng.query_topk(ranked_q, TOPK), oracle):
        assert np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores), \
            "ranked serving must match brute-force BM25"

    # ---- tracing overhead: interleaved off/on closed-loop passes
    tracer = Tracer()
    off_s, on_s = [], []
    for _ in range(REPS):
        eng.cfg.trace = None
        off_s.append(_mean_service(eng, work))
        eng.cfg.trace = tracer
        tracer.reset()
        on_s.append(_mean_service(eng, work))
    eng.cfg.trace = None
    trace_overhead = min(on_s) / min(off_s)

    # ---- open loop: Poisson arrivals at UTILIZATION x the service rate
    service = min(off_s)
    rate = UTILIZATION / service
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(work)))
    lat = np.zeros(len(work))
    clock = 0.0
    t_wall = time.perf_counter()
    for i, (kind, q) in enumerate(work):
        t0 = time.perf_counter()
        if kind == "bool":
            eng.query_batch([q])
        else:
            eng.query_topk([q], TOPK)
        svc = time.perf_counter() - t0
        clock = max(clock, arrivals[i]) + svc
        lat[i] = clock - arrivals[i]
    wall = time.perf_counter() - t_wall
    p50, p90, p99 = (float(np.percentile(lat, p)) for p in (50, 90, 99))

    metrics_lat = eng.metrics.snapshot().get("latency", {})
    traj = {
        "workload": {
            "n_docs": N_DOCS,
            "n_terms": N_TERMS,
            "n_postings": int(inv.n_postings),
            "n_boolean": N_BOOLEAN,
            "n_ranked": N_RANKED,
            "topk": TOPK,
            "n_shards": N_SHARDS,
            "utilization": UTILIZATION,
        },
        "closed_loop": {
            "service_ms": 1e3 * service,
            "untraced_ms": [1e3 * s for s in off_s],
            "traced_ms": [1e3 * s for s in on_s],
        },
        "open_loop": {
            "offered_qps": rate,
            "qps": len(work) / wall,
            "p50_ms": 1e3 * p50,
            "p90_ms": 1e3 * p90,
            "p99_ms": 1e3 * p99,
            "n_queries": len(work),
        },
        # traced/untraced mean service within one run — machine-normalized;
        # the span tracer must cost ~nothing when off and <5% when on
        "trace_overhead_ratio": trace_overhead,
        # open-loop tail amplification (queueing + service variance) within
        # one run; a generous floor absorbs scheduler noise on shared CI
        "latency_ratio": p99 / p50,
        "engine_histograms": metrics_lat,
    }
    rows = [
        ("serve_latency/p50", 1e6 * p50, f"p99_ms={1e3 * p99:.2f}"),
        ("serve_latency/qps", 0.0,
         f"qps={traj['open_loop']['qps']:.1f}_offered={rate:.1f}"),
        ("serve_latency/trace_overhead", 0.0, f"ratio={trace_overhead:.3f}"),
    ]
    if write_json:
        with open(BENCH_PATH, "w") as f:
            json.dump(traj, f, indent=2)
        tracer.save(TRACE_PATH)
        probe_log.close()
        rows.append(("serve_latency/json", 0.0,
                     f"wrote {BENCH_PATH}+{TRACE_PATH}+{PROBE_PATH}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in latency_rows():
        print(f"{name},{us:.1f},{derived}")
