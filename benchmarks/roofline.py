"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Terms (per device, per step):
  compute_s    = HLO_flops / PEAK_FLOPS
  memory_s     = HLO_bytes / HBM_BW
  collective_s = Σ collective bytes / ICI_BW
The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPS measures how much
compiled compute is "useful" (catches remat/redundancy waste).
"""
from __future__ import annotations

import json
from typing import Any

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9
ICI_BW = 50e9

# 6·N·D with N = (active) params, D = tokens per step — per arch × shape
ARCH_PARAMS = {  # total / active parameter counts
    "phi4-mini-3.8b": (3.8e9, 3.8e9),
    "gemma2-2b": (2.6e9, 2.6e9),
    "gemma-2b": (2.5e9, 2.5e9),
    "deepseek-v2-lite-16b": (15.7e9, 2.4e9),
    "deepseek-v3-671b": (671e9, 37e9),
}


def model_flops(arch: str, shape: str, kind: str, batch: int, seq: int, n_dev: int) -> float | None:
    if arch not in ARCH_PARAMS:
        return None
    total, active = ARCH_PARAMS[arch]
    if kind == "train":
        tokens = batch * seq
        return 6.0 * active * tokens / n_dev
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * active * tokens / n_dev
    if kind == "decode":
        tokens = batch  # one new token per sequence
        return 2.0 * active * tokens / n_dev
    return None


SHAPE_DIMS = {
    "train_4k": (256, 4096, "train"),
    "prefill_32k": (32, 32768, "prefill"),
    "decode_32k": (128, 32768, "decode"),
    "long_500k": (1, 524288, "decode"),
}


def analyze(record: dict[str, Any]) -> dict[str, Any] | None:
    if record.get("status") != "ok":
        return None
    flops = record["flops_per_device"]
    mem_bytes = record["bytes_per_device"]
    coll = sum(record["collective_bytes_per_device"].values())
    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    out = dict(record)
    out.update(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        # fraction of the roofline-limited time spent in the dominant term —
        # perfect overlap would run at max(terms); serial would be sum(terms)
        roofline_s=max(terms.values()),
        balance=max(terms.values()) / max(1e-12, sum(terms.values())),
    )
    dims = SHAPE_DIMS.get(record["shape"])
    if dims and record["arch"] in ARCH_PARAMS:
        b, s, kind = dims
        mf = model_flops(record["arch"], record["shape"], kind, b, s, record["n_devices"])
        if mf:
            out["model_flops_per_device"] = mf
            out["useful_flop_frac"] = mf / max(flops, 1.0)
            out["mfu_upper_bound"] = mf / PEAK_FLOPS / max(terms.values())
    return out


def rows_from_file(path: str):
    with open(path) as f:
        records = json.load(f)
    rows = []
    for r in records:
        a = analyze(r)
        if a is None:
            rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                         f"status={r['status']}"))
            continue
        extra = ""
        if "useful_flop_frac" in a:
            extra = f" useful_flops={a['useful_flop_frac']:.2f} mfu_bound={a['mfu_upper_bound']:.2f}"
        rows.append((
            f"roofline/{a['arch']}/{a['shape']}",
            a["roofline_s"] * 1e6,
            f"dominant={a['dominant']} compute_s={a['compute_s']:.4f} "
            f"memory_s={a['memory_s']:.4f} collective_s={a['collective_s']:.4f}{extra}",
        ))
    return rows
