"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Terms (per device, per step):
  compute_s    = HLO_flops / PEAK_FLOPS
  memory_s     = HLO_bytes / HBM_BW
  collective_s = Σ collective bytes / ICI_BW
The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPS measures how much
compiled compute is "useful" (catches remat/redundancy waste).
"""
from __future__ import annotations

import json
from typing import Any

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9
ICI_BW = 50e9

# 6·N·D with N = (active) params, D = tokens per step — per arch × shape
ARCH_PARAMS = {  # total / active parameter counts
    "phi4-mini-3.8b": (3.8e9, 3.8e9),
    "gemma2-2b": (2.6e9, 2.6e9),
    "gemma-2b": (2.5e9, 2.5e9),
    "deepseek-v2-lite-16b": (15.7e9, 2.4e9),
    "deepseek-v3-671b": (671e9, 37e9),
}


def model_flops(arch: str, shape: str, kind: str, batch: int, seq: int, n_dev: int) -> float | None:
    if arch not in ARCH_PARAMS:
        return None
    total, active = ARCH_PARAMS[arch]
    if kind == "train":
        tokens = batch * seq
        return 6.0 * active * tokens / n_dev
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * active * tokens / n_dev
    if kind == "decode":
        tokens = batch  # one new token per sequence
        return 2.0 * active * tokens / n_dev
    return None


SHAPE_DIMS = {
    "train_4k": (256, 4096, "train"),
    "prefill_32k": (32, 32768, "prefill"),
    "decode_32k": (128, 32768, "decode"),
    "long_500k": (1, 524288, "decode"),
}


def analyze(record: dict[str, Any]) -> dict[str, Any] | None:
    if record.get("status") != "ok":
        return None
    flops = record["flops_per_device"]
    mem_bytes = record["bytes_per_device"]
    coll = sum(record["collective_bytes_per_device"].values())
    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    out = dict(record)
    out.update(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        # fraction of the roofline-limited time spent in the dominant term —
        # perfect overlap would run at max(terms); serial would be sum(terms)
        roofline_s=max(terms.values()),
        balance=max(terms.values()) / max(1e-12, sum(terms.values())),
    )
    dims = SHAPE_DIMS.get(record["shape"])
    if dims and record["arch"] in ARCH_PARAMS:
        b, s, kind = dims
        mf = model_flops(record["arch"], record["shape"], kind, b, s, record["n_devices"])
        if mf:
            out["model_flops_per_device"] = mf
            out["useful_flop_frac"] = mf / max(flops, 1.0)
            out["mfu_upper_bound"] = mf / PEAK_FLOPS / max(terms.values())
    return out


# ------------------------------------------------------ inverted-index model
# Cost model for the fused ranked-query dispatch (kernels.fused_query): the
# serving engine's RankedStats counts the packed stream bytes its ε-window
# probe lanes touch and the device array traffic of each dispatch
# (fused_stream_bytes / fused_device_bytes), and every probe lane costs a
# near-constant number of integer VPU ops (segment line eval, two word-pair
# unpacks, compare, accumulate).  Positioning achieved bytes/s against the
# HBM roof answers the ISSUE's question directly: is the fused path bound by
# memory bandwidth (good — the paper's compression translates to speed) or
# still by dispatch/bookkeeping overhead?
PEAK_INT_OPS = 3.2e12  # rough int32 VPU throughput per chip (8x939 MHz lanes)
INT_OPS_PER_LANE = 24  # line eval + 2 unpacks + compare + select + accumulate


def index_roofline(
    stream_bytes: int,
    device_bytes: int,
    lanes: int,
    seconds: float,
    queries: int,
    *,
    kernel_seconds: float | None = None,
    bridge_seconds: float | None = None,
) -> dict[str, float]:
    """Fused ranked dispatch accounting -> position vs the HBM-bandwidth roof.

    ``stream_bytes`` are the index bytes the dispatch's lanes read (the
    paper-facing number: what compression makes small); ``device_bytes`` the
    dispatch's array traffic (what HBM actually moves); ``lanes`` the probe
    lanes evaluated; ``seconds`` the measured wall time of the ranked pass
    serving ``queries`` queries.

    When the caller splits the wall into ``kernel_seconds`` (blocked on
    device execution) and ``bridge_seconds`` (host plan/pack/merge),
    achieved bandwidth — and with it ``fraction_of_hbm_roof`` — is computed
    against the *kernel* time, so the roof fraction measures the kernel,
    not Python; the wall-time figure stays reported as
    ``achieved_bytes_per_s_wall``.
    """
    seconds = max(seconds, 1e-12)
    memory_s = device_bytes / HBM_BW
    compute_s = lanes * INT_OPS_PER_LANE / PEAK_INT_OPS
    roof_s = max(memory_s, compute_s)
    exec_s = max(kernel_seconds, 1e-12) if kernel_seconds else seconds
    achieved = device_bytes / exec_s
    out = {
        "stream_bytes": int(stream_bytes),
        "device_bytes": int(device_bytes),
        "lanes": int(lanes),
        "seconds": seconds,
        "bytes_per_query": device_bytes / max(queries, 1),
        "hbm_roof_s": memory_s,
        "int_roof_s": compute_s,
        "roofline_s": roof_s,
        "dominant": "memory" if memory_s >= compute_s else "compute",
        "achieved_bytes_per_s": achieved,
        "achieved_bytes_per_s_wall": device_bytes / seconds,
        "fraction_of_hbm_roof": achieved / HBM_BW,
    }
    if kernel_seconds is not None:
        out["kernel_seconds"] = float(kernel_seconds)
    if bridge_seconds is not None:
        out["bridge_seconds"] = float(bridge_seconds)
    return out


def rows_from_file(path: str):
    with open(path) as f:
        records = json.load(f)
    rows = []
    for r in records:
        a = analyze(r)
        if a is None:
            rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                         f"status={r['status']}"))
            continue
        extra = ""
        if "useful_flop_frac" in a:
            extra = f" useful_flops={a['useful_flop_frac']:.2f} mfu_bound={a['mfu_upper_bound']:.2f}"
        rows.append((
            f"roofline/{a['arch']}/{a['shape']}",
            a["roofline_s"] * 1e6,
            f"dominant={a['dominant']} compute_s={a['compute_s']:.4f} "
            f"memory_s={a['memory_s']:.4f} collective_s={a['collective_s']:.4f}{extra}",
        ))
    return rows
