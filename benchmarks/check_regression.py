"""Benchmark-regression gate: fresh BENCH_*.json vs committed baselines.

Compares the metrics that matter per benchmark file and fails (exit 1) when
any regresses beyond the tolerance:

  BENCH_learned_postings.json   bits_per_posting per codec    (lower is better)
  BENCH_guided_intersect.json   bytes_ratio, latency_ratio    (lower is better)
  BENCH_sharded_serve.json      latency_ratio (best sharded vs K=1, machine-
                                normalized within one run; lower is better)
  BENCH_ranked_topk.json        scored_fraction (postings MaxScore touches vs
                                exhaustive; deterministic), latency_ratio
                                (pruned vs exhaustive top-k, same run),
                                fused.latency_ratio (fused dispatch vs the
                                kernel multi-phase pipeline, same run) and
                                fused.roofline.fraction_of_hbm_roof (achieved
                                bandwidth vs the HBM roof; gated as a floor —
                                higher is better)
  BENCH_serve_latency.json      trace_overhead_ratio (traced vs untraced
                                closed-loop service time through the sched/
                                process-replica path — TraceContext IPC,
                                span shipping and collation included),
                                latency_ratio (open-loop p99/p50 tail
                                amplification under Poisson arrivals)
  BENCH_serve_sustained.json    qps_ratio (serial fan-out vs the continuous-
                                batching scheduler, same run), overload
                                p99_over_deadline (admitted tail vs the
                                deadline budget under 4x overload)
  BENCH_dispatch_overhead.json  host_us_per_dispatch (host-bridge µs per
                                fused dispatch), bridge_over_kernel (host
                                bridge / device-blocked time, same run —
                                the bridge regrowing past the kernel is
                                the regression the arena work removed)

Storage/bytes metrics are deterministic (seeded corpora), so any movement is
a real code change.  The latency metric is the guided/full *ratio* measured
from interleaved repeats within one run, so it is machine-normalized; it
gets the same 15% tolerance plus an absolute floor (a shared CI runner's
microarchitecture can legitimately shift the ratio a little, but guided
falling to less than 2x the speed of full decode fails anywhere).
Absolute ns_per_probe/qps numbers are informational only — they are not
comparable across machines and are not gated.

Usage:
  python benchmarks/check_regression.py --baseline-dir . --fresh-dir fresh/
"""
from __future__ import annotations

import argparse
import json
import os
import sys

TOLERANCE = 0.15  # >15% worse than baseline fails

# (file, dotted-path of a lower-is-better metric, absolute floor the limit
# is never taken below — nonzero only for wall-clock-derived metrics)
METRICS = [
    ("BENCH_learned_postings.json", "codecs.hybrid.bits_per_posting", 0.0),
    ("BENCH_learned_postings.json", "codecs.plm.bits_per_posting", 0.0),
    ("BENCH_learned_postings.json", "codecs.rmi.bits_per_posting", 0.0),
    ("BENCH_learned_postings.json", "codecs.clustered/plm.bits_per_posting", 0.0),
    ("BENCH_guided_intersect.json", "bytes_ratio", 0.0),
    ("BENCH_guided_intersect.json", "store.bits_per_posting", 0.0),
    ("BENCH_guided_intersect.json", "latency_ratio", 0.5),
    # shard fan-out overhead (threads, planning, bitmap merge) relative to
    # the K=1 engine on the same run; the floor absorbs CI-runner thread
    # scheduling noise, but a sharded engine >2x slower fails anywhere
    ("BENCH_sharded_serve.json", "latency_ratio", 2.0),
    # MaxScore work-skipping: deterministic (seeded corpus), must stay well
    # under the exhaustive scorer's postings count
    ("BENCH_ranked_topk.json", "scored_fraction", 0.0),
    # pruned vs exhaustive top-k wall clock within one run; the floor absorbs
    # scheduling noise, but pruning >1.2x slower than brute force fails
    ("BENCH_ranked_topk.json", "latency_ratio", 1.2),
    # fused one-dispatch-per-bucket kernel vs the kernel-enabled multi-phase
    # pipeline, same run (machine-normalized); the floor is the acceptance
    # bar — the fused path must beat the many-dispatch pipeline anywhere
    ("BENCH_ranked_topk.json", "fused.latency_ratio", 1.0),
    # fused one-dispatch path vs the all-numpy host multi-phase engine, same
    # run; with the device-resident arena the single dispatch must beat the
    # host outright — not just cut the dispatch count
    ("BENCH_ranked_topk.json", "fused.latency_ratio_host", 1.0),
    # span tracer on vs off, interleaved passes within one run; the floor is
    # the design budget — tracing a served batch must stay within ~5%
    ("BENCH_serve_latency.json", "trace_overhead_ratio", 1.05),
    # open-loop p99/p50 under Poisson arrivals at fixed utilization; queueing
    # tails are noisy on shared runners, so the floor is generous — but a
    # tail blowing past 25x the median signals real head-of-line blocking
    ("BENCH_serve_latency.json", "latency_ratio", 25.0),
    # serial fan-out qps / scheduler qps within one run (machine-normalized);
    # the floor is the acceptance bar — the process-replica scheduler must at
    # least match serial serving at K shards on any machine
    ("BENCH_serve_sustained.json", "summary.qps_ratio", 1.0),
    # admitted p99 / deadline under 4x-capacity overload: deadline shedding
    # must keep the admitted tail within 2x the budget (shed, don't convoy)
    ("BENCH_serve_sustained.json", "overload.p99_over_deadline", 2.0),
    # host-bridge µs per fused dispatch (plan/pad/group/extract around the
    # device call); wall-clock, so the floor is generous — but the bridge
    # regrowing to several ms per dispatch fails anywhere
    ("BENCH_dispatch_overhead.json", "host_us_per_dispatch", 6000.0),
    # host bridge / device-blocked kernel time within one run (machine-
    # normalized); the floor is the acceptance bar — host work must stay
    # cheaper than the device execution it overlaps
    ("BENCH_dispatch_overhead.json", "bridge_over_kernel", 1.0),
]

# (file, dotted-path of a higher-is-better metric, absolute cap the limit is
# never raised above).  Achieved-bandwidth fractions are wall-clock-derived
# and shift with the runner's memory subsystem, so the cap — not the
# baseline — is the portable bar: the fused dispatch collapsing to ~zero
# achieved bandwidth (e.g. silently degrading to per-query dispatches with
# the same traffic) fails on any machine
FLOOR_METRICS = [
    ("BENCH_ranked_topk.json", "fused.roofline.fraction_of_hbm_roof", 1e-5),
]


def _lookup(obj, dotted: str):
    for part in dotted.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def check(baseline_dir: str, fresh_dir: str, tolerance: float = TOLERANCE) -> list[str]:
    failures = []
    cache: dict[str, dict | None] = {}

    def load(d: str, name: str):
        path = os.path.join(d, name)
        if path not in cache:
            try:
                with open(path) as f:
                    cache[path] = json.load(f)
            except FileNotFoundError:
                cache[path] = None
        return cache[path]

    for fname, metric, floor in METRICS:
        base, fresh = load(baseline_dir, fname), load(fresh_dir, fname)
        if base is None:
            print(f"SKIP {fname}:{metric} — no committed baseline")
            continue
        if fresh is None:
            failures.append(f"{fname} missing from fresh results")
            continue
        b, f = _lookup(base, metric), _lookup(fresh, metric)
        if b is None:
            print(f"SKIP {fname}:{metric} — metric absent in baseline")
            continue
        if f is None:
            failures.append(f"{fname}:{metric} absent in fresh results")
            continue
        limit = max(b * (1 + tolerance), floor)
        verdict = "FAIL" if f > limit else "ok"
        print(f"{verdict:4s} {fname}:{metric}  baseline={b:.4f}  fresh={f:.4f}  limit={limit:.4f}")
        if f > limit:
            failures.append(f"{fname}:{metric} regressed {f:.4f} > {limit:.4f} (baseline {b:.4f})")

    for fname, metric, cap in FLOOR_METRICS:
        base, fresh = load(baseline_dir, fname), load(fresh_dir, fname)
        if base is None:
            print(f"SKIP {fname}:{metric} — no committed baseline")
            continue
        if fresh is None:
            failures.append(f"{fname} missing from fresh results")
            continue
        b, f = _lookup(base, metric), _lookup(fresh, metric)
        if b is None:
            print(f"SKIP {fname}:{metric} — metric absent in baseline")
            continue
        if f is None:
            failures.append(f"{fname}:{metric} absent in fresh results")
            continue
        limit = min(b * (1 - tolerance), cap)
        verdict = "FAIL" if f < limit else "ok"
        print(f"{verdict:4s} {fname}:{metric}  baseline={b:.3e}  fresh={f:.3e}  limit={limit:.3e} (floor)")
        if f < limit:
            failures.append(f"{fname}:{metric} collapsed {f:.3e} < {limit:.3e} (baseline {b:.3e})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default=".", help="dir with committed BENCH_*.json")
    ap.add_argument("--fresh-dir", required=True, help="dir with freshly generated BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args()
    failures = check(args.baseline_dir, args.fresh_dir, args.tolerance)
    if failures:
        print("\nbenchmark regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbenchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
