"""Benchmark harness: one section per paper artifact + roofline.

Prints ``name,us_per_call,derived`` CSV.
  fig1/..   df skew + storage fraction            (paper Fig 1)
  fig2/..   Eq.(2) gain bounds vs truncation k    (paper Fig 2 + Eq. 2)
  fig3/..   % guaranteed-correct queries          (paper Fig 3)
  codec/..  compression ratios (OptPFD vs others) (paper §4 setup)
  learned/.. learned-vs-classical bits/posting    (+ BENCH_learned_postings.json)
  guided/.. model-guided vs full-decode verify    (+ BENCH_guided_intersect.json)
  sharded/.. doc-partitioned serving vs K shards  (+ BENCH_sharded_serve.json)
  ranked/.. MaxScore top-k vs exhaustive scoring  (+ BENCH_ranked_topk.json)
  serve_latency/.. open-loop Poisson tail latency + tracing overhead
                                                  (+ BENCH_serve_latency.json)
  serve_sustained/.. continuous-batching scheduler vs serial fan-out under
                     sustained Poisson load        (+ BENCH_serve_sustained.json)
  dispatch/.. fused-dispatch host overhead + tile autotune
                                                  (+ BENCH_dispatch_overhead.json,
                                                   artifacts/autotune_cache.json)
  kernel/.. Pallas kernels, interpret-mode        (plumbing check)
  roofline/.. per (arch × shape) terms from dryrun_16x16.json if present
"""
import os
import sys

# allow `python benchmarks/run.py` from the repo root (script mode puts
# benchmarks/ itself on sys.path, not its parent)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks.paper_figs import _collections, fig1_rows, fig2_rows, fig3_rows
    from benchmarks.codec_kernels import codec_rows, kernel_rows, unpack_rows
    from benchmarks.dispatch_overhead import overhead_rows
    from benchmarks.guided_intersect import guided_rows
    from benchmarks.learned_postings import learned_rows
    from benchmarks.ranked_topk import ranked_rows
    from benchmarks.roofline import rows_from_file
    from benchmarks.serve_latency import latency_rows, sustained_rows
    from benchmarks.sharded_serve import sharded_rows

    print("name,us_per_call,derived")
    colls = _collections()
    rows = []
    rows += fig1_rows(colls)
    rows += fig2_rows(colls)
    rows += fig3_rows(colls)
    rows += codec_rows()
    rows += unpack_rows()
    rows += learned_rows()
    rows += guided_rows()
    rows += sharded_rows()
    rows += ranked_rows()
    rows += latency_rows()
    rows += sustained_rows()
    rows += overhead_rows()
    rows += kernel_rows()
    for path in ("/root/repo/dryrun_16x16.json", "dryrun_16x16.json"):
        if os.path.exists(path):
            rows += rows_from_file(path)
            break
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
