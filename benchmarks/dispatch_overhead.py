"""Dispatch-overhead microbenchmark: host µs per fused dispatch.

The tentpole claim of the device-resident ranked path is that per-dispatch
host work — planning, padding, grouping, result extraction — stopped
dominating: the perf-counter split in the fused bridge (RankedStats
``fused_bridge_ns`` vs ``fused_kernel_ns``) measures exactly that, and this
benchmark turns it into a gated per-dispatch / per-query number instead of
a by-product of the roofline.

Emits BENCH_dispatch_overhead.json:
  host_us_per_dispatch   host-bridge µs per fused_topk_batch call (gated
                         by check_regression.py with a generous absolute
                         floor — wall-clock on shared runners is noisy, but
                         the bridge regrowing past the kernel fails anywhere)
  host_us_per_query      the same spread over the queries in the batch
  kernel_us_per_dispatch device-blocked µs per call (informational)
  bridge_over_kernel     host bridge / kernel time (the ISSUE's
                         latency_ratio_host story at dispatch granularity)
  autotune               the tile search's winning config + timings; the
                         search also (re)writes artifacts/autotune_cache.json,
                         which CI uploads as an artifact
"""
from __future__ import annotations

import json
import time

import numpy as np

BENCH_PATH = "BENCH_dispatch_overhead.json"

N_QUERIES = 64
TOP_K = 10
PASSES = 5


def overhead_rows(write_json: bool = True):
    try:
        from benchmarks.ranked_topk import N_DOCS, N_TERMS, SEED, _system
    except ImportError:  # script mode: benchmarks/ itself is sys.path[0]
        from ranked_topk import N_DOCS, N_TERMS, SEED, _system
    from repro.data.queries import zipf_disjunctions
    from repro.kernels.autotune import autotune_dense
    from repro.serve import BooleanEngine, ServeConfig

    # tune first: the measured dispatches then run the configuration CI ships
    tune = autotune_dense()

    inv, li_cfg, lb = _system()
    queries, _ = zipf_disjunctions(inv.dfs, N_QUERIES, seed=SEED + 1)
    eng = BooleanEngine(
        lb, inv, li_cfg, ServeConfig(n_shards=1, ranked=dict(fused_kernel=True))
    )
    for sh in eng.shards:
        sh.ensure_payloads()
    eng.query_topk(queries, TOP_K)  # arena build + jit warm, untimed
    eng.reset_stats()
    t0 = time.time()
    for _ in range(PASSES):
        eng.query_topk(queries, TOP_K)
    wall = time.time() - t0
    s = eng.metrics.snapshot()["ranked"]
    dispatches = PASSES  # one fused_topk_batch per query_topk pass at K=1
    host_us_dispatch = s["fused_bridge_ns"] / 1e3 / dispatches
    kernel_us_dispatch = s["fused_kernel_ns"] / 1e3 / dispatches
    out = {
        "workload": {
            "n_docs": N_DOCS,
            "n_terms": N_TERMS,
            "n_queries": N_QUERIES,
            "top_k": TOP_K,
            "passes": PASSES,
        },
        "host_us_per_dispatch": host_us_dispatch,
        "host_us_per_query": host_us_dispatch / N_QUERIES,
        "kernel_us_per_dispatch": kernel_us_dispatch,
        "bridge_over_kernel": s["fused_bridge_ns"] / max(1, s["fused_kernel_ns"]),
        "wall_us_per_query": 1e6 * wall / (PASSES * N_QUERIES),
        "autotune": tune,
    }
    if write_json:
        with open(BENCH_PATH, "w") as f:
            json.dump(out, f, indent=2)
    rows = [
        ("dispatch/host_overhead", host_us_dispatch,
         f"per_query_us={out['host_us_per_query']:.2f}"
         f"_bridge_over_kernel={out['bridge_over_kernel']:.3f}"),
        ("dispatch/autotune", tune["best_us"],
         f"dense={tune['dense']['row_quantum']}x{tune['dense']['term_quantum']}"
         f"_device={tune['device']}"),
    ]
    if write_json:
        rows.append(("dispatch/json", 0.0, f"wrote {BENCH_PATH}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in overhead_rows():
        print(f"{name},{us:.1f},{derived}")
