"""Vertical product search (the paper's §1 motivation): Boolean attribute
pre-filtering with the learned index, fused with dense retrieval scoring —
the recsys `retrieval_cand` path with the paper's technique in front.

Catalogue items have attribute sets (category, brand, tags...). A query is
a conjunctive attribute filter + a user interest vector. Pipeline:
  1. learned index (Algorithm 3) filters the catalogue to candidates;
  2. MIND-style dot scoring ranks the survivors;
  3. results provably contain every matching item (zero-FN guarantee).

  PYTHONPATH=src python examples/boolean_product_search.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import fit_thresholds, init_membership
from repro.data.corpus import synthesize_corpus
from repro.index.build import build_inverted_index
from repro.serve import BooleanEngine, ServeConfig


def main():
    rng = np.random.default_rng(0)
    # catalogue: 3000 items ("docs"), 500 attributes ("terms")
    corpus = synthesize_corpus(
        CorpusConfig(name="catalogue", n_docs=3000, n_terms=500, avg_doc_len=12)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=32, truncation_k=32, block_size=64)
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(algorithm="block", verified=True))

    # dense side: item embeddings + a user interest vector
    item_emb = rng.standard_normal((corpus.n_docs, 32)).astype(np.float32)
    user = rng.standard_normal(32).astype(np.float32)

    # query: items that carry ALL of these attributes
    filt = np.array([[2, 17, 33, -1]], dtype=np.int32)
    candidates = eng.query_batch(filt)[0]
    print(f"Boolean filter -> {len(candidates)} candidate items")

    scores = item_emb[candidates] @ user
    top = candidates[np.argsort(scores)[::-1][:10]]
    print("top-10 after dense scoring:", top.tolist())

    # exactness: no matching item was lost by the learned filter
    truth = [d for d in range(corpus.n_docs)
             if all(corpus.contains(int(t), d) for t in filt[0] if t >= 0)]
    assert set(truth) == set(candidates.tolist())
    print(f"guarantee holds: all {len(truth)} matching items present")


if __name__ == "__main__":
    main()
