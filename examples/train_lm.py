"""End-to-end driver: train a ~100M-param LM (gemma2-family reduced config)
for a few hundred steps on synthetic tokens, with checkpointing + resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil

import jax
import numpy as np

from repro.common.config import ArchConfig, ShapeSpec, TrainConfig
from repro.data.loader import lm_token_batches
from repro.launch.steps import build_cell
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: 8 layers x d512 x ff2048, 32k vocab, gemma2-style blocks
    cfg = ArchConfig(
        name="gemma2-100m", family="lm", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        activation="geglu", attn_types=("local", "global"), window_size=64,
        attn_softcap=50.0, logit_softcap=30.0, embed_scale=True,
        tie_embeddings=True,
    )
    shape = ShapeSpec(name="train", kind="train", seq_len=args.seq, global_batch=args.batch)
    cell = build_cell(cfg, shape, remat="none")

    ckpt_dir = "/tmp/repro_example_lm"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    data = lm_token_batches(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq)
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=ckpt_dir,
                       checkpoint_every=100, log_every=20)
    _, _, metrics = train_loop(cell, tcfg, data_it=data)
    final = float(metrics["loss"])
    print(f"final loss {final:.3f} (uniform-random baseline ~{np.log(cfg.vocab_size):.2f})")
    assert final < np.log(cfg.vocab_size), "model must beat uniform"

    # resume demo: continue a few more steps from the checkpoint
    tcfg2 = TrainConfig(steps=args.steps + 20, checkpoint_dir=ckpt_dir,
                        checkpoint_every=1000, log_every=10)
    train_loop(cell, tcfg2, data_it=data)
    print("resume from checkpoint OK")


if __name__ == "__main__":
    main()
