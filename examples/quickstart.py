"""Quickstart: build a collection, train the learned membership index, serve
exact Boolean queries — the paper's full pipeline in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import CorpusConfig, LearnedIndexConfig, OptimizerConfig
from repro.core import estimate_gain, fit_thresholds, init_membership, membership_loss
from repro.data.corpus import synthesize_corpus
from repro.data.loader import membership_batches
from repro.data.queries import brute_force_answers, sample_queries, zipf_conjunctions
from repro.index.build import build_inverted_index
from repro.serve import BooleanEngine, ServeConfig
from repro.train import init_train_state, make_train_step


def main():
    # 1. a Robust-like collection (synthetic, df-calibrated — DESIGN.md §5)
    corpus = synthesize_corpus(CorpusConfig(n_docs=1500, n_terms=6000, avg_doc_len=70))
    inv = build_inverted_index(corpus)
    print(f"collection: {corpus.n_docs} docs, {corpus.n_postings} postings")

    # 2. the paper's Eq.(2): how much storage could the learned index save?
    g = estimate_gain(inv, k=48)
    print(f"Eq.(2) @ k=48: upper {g.gain_upper_frac:.1%}, "
          f"lower (s=512b) {g.gain_lower_frac:.1%}, |R|={g.n_replaced}")

    # 3. train f(t,d) — the learned index model
    li_cfg = LearnedIndexConfig(embed_dim=64, truncation_k=48, block_size=128)
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    ocfg = OptimizerConfig(lr=0.05, warmup_steps=10, total_steps=200, weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b), ocfg))
    state = init_train_state(params, ocfg)
    for i, batch in zip(range(200), membership_batches(corpus, batch_size=2048)):
        params, state, m = step(params, state, {k: jnp.asarray(v) for k, v in batch.items()})
    print(f"membership model trained, final loss {float(m['loss']):.4f}")

    # 4. learned-Bloom construction: zero false negatives by construction
    lb = fit_thresholds(params, inv)

    # 5. serve conjunctive Boolean queries (Algorithm 3 + exact verification)
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(algorithm="block", verified=True))
    queries = sample_queries(corpus, 16, seed=1)
    results = eng.query_batch(queries)
    exact = brute_force_answers(corpus, queries)
    ok = all(np.array_equal(r, e) for r, e in zip(results, exact))
    print(f"16 queries served, exact={ok}")
    print("memory report (bits):", eng.memory_report())

    # 6. the §3.3 hybrid tier-2 store: per-term min-bits codec (learned or
    # classical), decoded exactly during verification above
    bpp = eng.tier2.size_bits() / inv.n_postings
    print(f"tier-2 hybrid store: {bpp:.2f} bits/posting (raw 32.00), "
          f"codec split {eng.tier2.codec_histogram()}")
    assert ok

    # 7. model-guided conjunctive serving: a batched 2-5-term AND workload
    # verified by ε-window probes on the learned streams (no full decode on
    # the learned terms) — see README "Serving" and BENCH_guided_intersect
    conj = zipf_conjunctions(inv.dfs, 8, seed=3)
    conj_results = eng.query_batch(conj)
    conj_exact = brute_force_answers(corpus, conj)
    assert all(np.array_equal(r, e) for r, e in zip(conj_results, conj_exact))
    report = eng.memory_report()
    print(f"guided conjunctive batch: {len(conj)} queries, "
          f"{sum(len(r) for r in conj_results)} result docs")
    print("memory report (bits):", report)
    assert "tier2_bits" in report
    guided = eng.metrics.snapshot()["guided"]
    print(f"guided probes: {guided['probes']}, bytes touched "
          f"{guided['guided_bytes']} vs full-decode {guided['full_equiv_bytes']} "
          f"(ratio {guided['bytes_ratio']:.3f})")

    # 8. restartable, doc-partitioned serving: persist the sharded index
    # (index/store.py), reload it mmap-lazily, and serve identical results —
    # no re-encoding on restart, 4 shards fanned out by the planner/executor
    import tempfile

    sharded_cfg = ServeConfig(algorithm="block", verified=True, n_shards=4)
    sharded = BooleanEngine(lb, inv, li_cfg, sharded_cfg)
    with tempfile.TemporaryDirectory() as index_dir:
        sharded.save(index_dir)
        restarted = BooleanEngine.from_store(lb, li_cfg, sharded_cfg, index_dir)
        reload_results = restarted.query_batch(conj)
    assert all(np.array_equal(r, e) for r, e in zip(reload_results, conj_exact))
    summary = restarted.metrics.snapshot()["summary"]
    print(f"sharded round trip: {summary['n_shards']} shards served "
          f"{len(conj)} queries from the reloaded store, cache "
          f"{summary['cache_hits']}h/{summary['cache_misses']}m, "
          f"probe bytes {summary['probe_bytes']}")

    # 9. ranked retrieval: a top-10 BM25 disjunction over the tf payload
    # streams — quantized-impact scores, MaxScore pruning, checked against
    # brute-force BM25 over fully decoded postings (bit-identical)
    from repro.data.queries import zipf_disjunctions
    from repro.rank.score import brute_force_topk, dequantize_scores

    ranked_q, _ = zipf_disjunctions(inv.dfs, 1, min_terms=4, max_terms=5, seed=9)
    (top,) = eng.query_topk(ranked_q, 10)
    (oracle,) = brute_force_topk(inv, eng.impact_model, ranked_q, 10)
    assert np.array_equal(top.ids, oracle.ids)
    assert np.array_equal(top.scores, oracle.scores)
    terms = [int(t) for t in ranked_q[0] if t >= 0]
    print(f"top-10 BM25 for OR query {terms} (scores vs brute force: equal):")
    for doc, q_score, f_score in zip(
        top.ids, top.scores, dequantize_scores(top.scores, eng.impact_model)
    ):
        print(f"  doc {int(doc):5d}  impact {int(q_score):4d}  bm25≈{f_score:.3f}")
    rs = eng.metrics.snapshot()["ranked"]
    print(f"ranked path scored {rs['touched_postings']} of "
          f"{rs['exhaustive_postings']} postings "
          f"(fraction {rs['scored_fraction']:.3f})")

    # 10. observability: re-serve the same workloads with the span tracer and
    # probe log on (ServeConfig(obs=dict(trace=..., probe_log=...)) — or
    # `repro.launch.serve --trace-out --probe-log` from the CLI), then read
    # per-phase latency percentiles from the metrics registry and drop the
    # Chrome-trace JSON into ui.perfetto.dev to see the query path
    from repro.obs import ProbeLog, Tracer

    tracer, plog = Tracer(), ProbeLog()  # path-less log collects in memory
    obs_cfg = ServeConfig(algorithm="block", verified=True,
                          obs=dict(trace=tracer, probe_log=plog))
    obs_eng = BooleanEngine(lb, inv, li_cfg, obs_cfg)
    obs_eng.query_batch(conj)
    obs_eng.query_topk(ranked_q, 10)
    lat = obs_eng.metrics.snapshot()["latency"]
    for name in ("query_us", "topk_query_us"):
        h = lat[name]
        print(f"latency {name}: p50 {h['p50'] / 1e3:.2f} ms, "
              f"p99 {h['p99'] / 1e3:.2f} ms over {h['count']} queries")
    routes = sorted({r.route for r in plog.records})
    print(f"traced {len(tracer.spans)} spans across "
          f"{len({s.name for s in tracer.spans})} phases; "
          f"{plog.n_records} probe records, routes {routes}")
    with tempfile.TemporaryDirectory() as d:
        tracer.save(f"{d}/quickstart.trace.json")
        print(f"Chrome trace saved (open in ui.perfetto.dev): "
              f"{len(tracer.chrome_trace()['traceEvents'])} events")

    # 11. the serving front-end: submit everything through one request type.
    # The Session coalesces arrivals into batches (continuous batching),
    # fans them out per shard, and resolves each request to a QueryResult or
    # a typed Rejected — here inline (n_replicas=0); set
    # sched=dict(n_replicas=R) plus store_dir= for process replicas, and see
    # README "Serving front-end" for tenants/priorities/deadlines
    from repro.serve import QueryRequest, Session

    with Session(sharded) as session:
        r = session.submit(QueryRequest(terms=conj[0]))
        assert r.ok and np.array_equal(r.ids, conj_results[0])
        rr = session.submit(QueryRequest(terms=ranked_q[0], mode="ranked", k=10))
        assert np.array_equal(rr.ids, top.ids)
        never = session.submit(QueryRequest(terms=conj[1], deadline_ms=0.0))
        sm = sharded.metrics.snapshot()["sched"]
    print(f"scheduler: served boolean+ranked via Session.submit "
          f"(parity with steps 7/9), queue wait "
          f"{r.queue_us / 1e3:.2f} ms; an already-expired deadline came "
          f"back typed: ok={never.ok} reason={never.reason!r}; "
          f"{sm['batches']} batches dispatched, {sm['shed']['deadline']} shed")
    assert not never.ok and never.reason == "deadline"

    # 12. distributed tracing + SLO telemetry: the same ranked query through
    # a real process replica.  The scheduler propagates a TraceContext over
    # the worker pipe; the worker ships its span buffer back with the reply;
    # the host collator aligns the two monotonic clocks (min-RTT ping
    # offset) and merges everything onto ONE timeline — each worker is its
    # own named pid lane next to the host's.  (`repro.launch.serve
    # --replicas 1 --slo` drives the same path from the CLI.)
    from repro.obs import nesting_violations, render_prometheus

    dist_tracer = Tracer()
    dist_cfg = ServeConfig(algorithm="block", verified=True, n_shards=2,
                           sched=dict(n_replicas=1),
                           obs=dict(trace=dist_tracer, probe_log=ProbeLog()))
    dist_eng = BooleanEngine(lb, inv, li_cfg, dist_cfg)
    with tempfile.TemporaryDirectory() as store_dir:
        with Session(dist_eng, store_dir=store_dir) as session:
            session.warm()  # spawn replicas + pre-compile outside the timing
            rr = session.submit(QueryRequest(terms=ranked_q[0], mode="ranked",
                                             k=10), timeout=60)
            assert rr.ok and np.array_equal(rr.ids, top.ids)  # still bit-exact
            a = rr.autopsy()
            slo = session.slo_report()
    lanes = sorted({s.pid for s in dist_tracer.spans})
    worker_names = {s.name for s in dist_tracer.spans if s.pid != 0}
    assert len(lanes) > 1, "worker spans must merge into the host timeline"
    assert nesting_violations(dist_tracer.spans, slack_us=0.5) == []
    print(f"distributed trace: {len(lanes)} pid lanes (host + "
          f"{len(lanes) - 1} workers), worker phases "
          f"{sorted(worker_names)[:4]}...")
    print(f"autopsy: total {a['total_us'] / 1e3:.2f} ms = queue "
          f"{a['queue_us'] / 1e3:.2f} + dispatch {a['dispatch_us'] / 1e3:.2f}"
          f" + execute {a['execute_us'] / 1e3:.2f} + merge "
          f"{a['merge_us'] / 1e3:.2f} ms ({a['execute_frac']:.0%} execute)")
    ten = slo["tenants"]["default"]
    print(f"slo window: {ten['requests']} request(s), hit rate "
          f"{ten['deadline_hit_rate']:.0%}, p99 {ten['p99_ms']:.2f} ms, "
          f"burn {ten['burn_rate']:.2f}x of target {slo['target']:.0%}")
    prom = render_prometheus({"sched": slo["sched"]})
    print("prometheus exposition (first 3 lines):")
    for line in prom.splitlines()[:3]:
        print(f"  {line}")

    # 13. the device-resident fused ranked path: candidate scoring through
    # the θ-peel top-k loop runs as ONE jitted dispatch over a per-shard
    # device arena — the impact table is uploaded once per process
    # (residency counters prove it) and the host bridge only pads queries
    # and extracts results.  Timing the device execution separately from
    # that bridge is what moved the gated roofline fraction ~25x, from
    # 1.34e-4 (host-timed, pre-arena) to ~3.4e-3 (device-timed, arena) in
    # BENCH_ranked_topk.json — see README "Performance tuning"
    fused_eng = BooleanEngine(lb, inv, li_cfg,
                              ServeConfig(ranked=dict(fused_kernel=True)))
    (ftop,) = fused_eng.query_topk(ranked_q, 10)
    assert np.array_equal(ftop.ids, top.ids)       # still bit-identical to
    assert np.array_equal(ftop.scores, top.scores)  # steps 9's oracle check
    fused_eng.reset_stats()
    fused_eng.query_topk(ranked_q, 10)
    fs = fused_eng.metrics.snapshot()["ranked"]
    arena = fused_eng.shards[0].metrics.snapshot()["arena"]
    print(f"fused dispatch: kernel {fs['fused_kernel_ns'] / 1e6:.2f} ms vs "
          f"host bridge {fs['fused_bridge_ns'] / 1e6:.2f} ms; arena "
          f"{arena['upload_bytes'] / 1e6:.1f} MB uploaded "
          f"{arena['uploads']}x, {arena['hits']} resident dispatch(es)")
    assert arena["uploads"] == 1  # uploaded once, no matter how many queries


if __name__ == "__main__":
    main()
