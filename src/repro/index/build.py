"""Inverted-index builder: doc->terms incidence transposed to term->docs CSR."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.corpus import Corpus


@dataclass
class InvertedIndex:
    n_docs: int
    n_terms: int
    term_offsets: np.ndarray  # (n_terms+1,) int64 into doc_ids
    doc_ids: np.ndarray  # (total_postings,) int32, sorted per term
    tfs: np.ndarray | None = None  # (total_postings,) int32 term frequencies

    def postings(self, t: int) -> np.ndarray:
        return self.doc_ids[self.term_offsets[t] : self.term_offsets[t + 1]]

    def term_tfs(self, t: int) -> np.ndarray:
        """Term frequencies aligned with postings(t)."""
        if self.tfs is None:
            raise ValueError("index carries no term frequencies")
        return self.tfs[self.term_offsets[t] : self.term_offsets[t + 1]]

    def df(self, t: int | np.ndarray) -> np.ndarray:
        return self.term_offsets[np.asarray(t) + 1] - self.term_offsets[np.asarray(t)]

    @property
    def dfs(self) -> np.ndarray:
        return np.diff(self.term_offsets)

    @property
    def n_postings(self) -> int:
        return int(self.doc_ids.shape[0])


def build_inverted_index(corpus: Corpus) -> InvertedIndex:
    """Counting-sort transpose of the (doc, term) incidence; O(P)."""
    doc_of = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64), np.diff(corpus.doc_offsets)
    )
    term = corpus.term_ids.astype(np.int64)
    # stable sort by term keeps doc_ids ascending within each posting list
    # (doc_of is already ascending for equal terms because corpus is doc-major)
    order = np.argsort(term, kind="stable")
    sorted_docs = doc_of[order].astype(np.int32)
    counts = np.bincount(term, minlength=corpus.n_terms)
    offsets = np.zeros(corpus.n_terms + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    tfs = corpus.term_freqs
    return InvertedIndex(
        n_docs=corpus.n_docs,
        n_terms=corpus.n_terms,
        term_offsets=offsets,
        doc_ids=sorted_docs,
        tfs=None if tfs is None else tfs[order].astype(np.int32),
    )


def slice_index(inv: InvertedIndex, lo: int, hi: int) -> InvertedIndex:
    """Doc-range restriction of the index: postings in [lo, hi), ids rebased.

    The document-partitioned serving layer's builder: shard s owns global doc
    ids [lo, hi) and serves them as local ids 0..hi-lo-1.  Per-term order is
    preserved (postings are sorted by doc id, so a contiguous range selects a
    contiguous run of each list).  O(P) vectorized; lo=0, hi=n_docs is the
    identity (modulo array copies).
    """
    if not 0 <= lo <= hi <= inv.n_docs:
        raise ValueError(f"bad doc range [{lo}, {hi}) for {inv.n_docs} docs")
    sel = (inv.doc_ids >= lo) & (inv.doc_ids < hi)
    term_of = np.repeat(np.arange(inv.n_terms, dtype=np.int64), inv.dfs)
    counts = np.bincount(term_of[sel], minlength=inv.n_terms)
    offsets = np.zeros(inv.n_terms + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return InvertedIndex(
        n_docs=hi - lo,
        n_terms=inv.n_terms,
        term_offsets=offsets,
        doc_ids=(inv.doc_ids[sel] - lo).astype(np.int32),
        tfs=None if inv.tfs is None else inv.tfs[sel],
    )


def truncate_index(inv: InvertedIndex, k: int) -> InvertedIndex:
    """Tier-1 index: every posting list truncated to its first k entries.

    The paper makes no assumption about *which* k entries are kept (§3.2);
    we keep the k lowest doc ids (standard impact-ordering would also work).
    """
    dfs = inv.dfs
    keep = np.minimum(dfs, k)
    offsets = np.zeros(inv.n_terms + 1, dtype=np.int64)
    np.cumsum(keep, out=offsets[1:])
    doc_ids = np.empty(int(offsets[-1]), dtype=np.int32)
    # vectorized ragged copy
    src_start = inv.term_offsets[:-1]
    for t in np.nonzero(keep)[0]:
        doc_ids[offsets[t] : offsets[t + 1]] = inv.doc_ids[
            src_start[t] : src_start[t] + keep[t]
        ]
    return InvertedIndex(inv.n_docs, inv.n_terms, offsets, doc_ids)


def block_lists(inv: InvertedIndex, block_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-term block bitmaps for Algorithm 3, packed into uint32 words.

    Returns (bitmaps, n_blocks): bitmaps is (n_terms, ceil(n_blocks/32)) u32;
    bit b of term t set iff some doc in block b contains t.
    """
    n_blocks = -(-inv.n_docs // block_size)
    words = -(-n_blocks // 32)
    bitmaps = np.zeros((inv.n_terms, words), dtype=np.uint32)
    term_of = np.repeat(
        np.arange(inv.n_terms, dtype=np.int64), np.diff(inv.term_offsets)
    )
    blk = (inv.doc_ids // block_size).astype(np.int64)
    word, bit = blk // 32, (blk % 32).astype(np.uint32)
    np.bitwise_or.at(bitmaps, (term_of, word), np.uint32(1) << bit)
    return bitmaps, n_blocks
