"""List intersection primitives.

CPU-side exact intersection (numpy) for index building / oracles, plus
jax-native batched intersection over padded posting matrices — the form the
TPU serving path uses (sorted-list galloping is branchy/serial; on TPU we
intersect via membership matmuls or packed bitsets — see kernels/bitset).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def intersect_sorted(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Exact sorted-list intersection (numpy oracle)."""
    return np.intersect1d(a, b, assume_unique=True)


def membership_mask(p: np.ndarray, cands: np.ndarray) -> np.ndarray:
    """mask over cands: cands[i] ∈ p (sorted p, vectorized binary search).

    Candidates past the last posting get sel == len(p); the clamp makes them
    compare against p[-1], which can only match when equal (searchsorted
    returns len(p) only for cands strictly greater than p[-1]).
    """
    if len(p) == 0:
        return np.zeros(len(cands), dtype=bool)
    sel = np.searchsorted(p, cands)
    sel = np.clip(sel, 0, len(p) - 1)
    return p[sel] == cands


def gallop_membership(p: np.ndarray, cands: np.ndarray) -> np.ndarray:
    """mask over sorted cands: cands[i] ∈ p, by exponential (galloping) search.

    One forward-moving cursor per list: each candidate gallops ahead from the
    previous match position, then binary-searches the overshoot bracket —
    O(Σ log gap), which beats per-candidate binary search when the candidate
    set is small and clustered relative to p (the verification hot path:
    Bloom-filtered candidates vs a long posting list).  Falls back to the
    vectorized binary search when cands is within ~1/8 of |p|.
    """
    n = len(p)
    if n == 0:
        return np.zeros(len(cands), dtype=bool)
    if len(cands) * 8 >= n:
        return membership_mask(p, cands)
    out = np.zeros(len(cands), dtype=bool)
    pos = 0
    for i, d in enumerate(np.asarray(cands).tolist()):
        if pos >= n:
            break
        step = 1
        hi = pos
        while hi < n and p[hi] < d:
            hi += step
            step <<= 1
        lo = max(pos, hi - (step >> 1))
        hi = min(hi, n)
        j = lo + int(np.searchsorted(p[lo:hi], d))
        out[i] = j < n and p[j] == d
        pos = j
    return out


def intersect_many(lists: list[np.ndarray]) -> np.ndarray:
    if not lists:
        return np.empty(0, dtype=np.int32)
    cur = lists[0]
    for nxt in sorted(lists[1:], key=len):
        if cur.size == 0:
            break
        cur = intersect_sorted(cur, nxt)
    return cur.astype(np.int32)


def padded_intersect(
    lists: jax.Array,  # (n_lists, max_len) int32, -1 padded, sorted rows
    lengths: jax.Array,  # (n_lists,)
) -> jax.Array:
    """Jax-native conjunctive intersection of padded sorted lists.

    Returns a boolean mask over lists[0]: element i survives iff it occurs in
    every other list. Binary search per element (searchsorted is vectorized).
    O(L · n_lists · log L) — used by the two-tier tier-1 pass.
    """
    base = lists[0]
    valid = jnp.arange(lists.shape[1]) < lengths[0]

    def one_list(carry, xs):
        row, ln = xs
        idx = jnp.searchsorted(row, base)
        idx = jnp.clip(idx, 0, lists.shape[1] - 1)
        found = (jnp.take(row, idx) == base) & (idx < ln)
        return carry & found, None

    mask, _ = jax.lax.scan(one_list, valid, (lists[1:], lengths[1:]))
    return mask


def padded_union(lists: jax.Array, lengths: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Union of padded sorted lists -> (sorted unique ids padded with INT32_MAX, count).

    Used by Algorithm 2: L = ∪ truncated lists.
    """
    n, m = lists.shape
    flat = jnp.where(
        (jnp.arange(m)[None, :] < lengths[:, None]) & (lists >= 0),
        lists,
        jnp.iinfo(jnp.int32).max,
    ).reshape(-1)
    s = jnp.sort(flat)
    is_new = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    is_new &= s != jnp.iinfo(jnp.int32).max
    count = is_new.sum()
    # stable compaction: sort by (not is_new) keeps unique elements in order
    order = jnp.argsort(~is_new, stable=True)
    out = jnp.where(jnp.arange(n * m) < count, s[order], jnp.iinfo(jnp.int32).max)
    return out, count
