"""Postings-list compression codecs.

The paper measures storage under OptPFOR [Lemire & Boytsov '15]; we implement
OptPFD (per-128-block optimal bit width + exception patching) plus varbyte,
Elias-Fano and raw bitvectors, so the Fig-1/Fig-2 storage analysis and the
hybrid representations of §3.3 are all measurable.

All codecs operate on a sorted doc-id list; d-gap transform first. Encoders
return a uint32 word array; sizes are exact bit counts (compressed_size_bits)
so Eq. (2) can be evaluated without byte-alignment noise.
"""
from __future__ import annotations

import numpy as np

BLOCK = 128  # PFor block length; matches SIMD-friendly CPU codecs & 128-lane VREG


# --------------------------------------------------------------------------- dgap
def dgaps(doc_ids: np.ndarray) -> np.ndarray:
    if len(doc_ids) == 0:
        return doc_ids.astype(np.uint32)
    out = np.empty_like(doc_ids, dtype=np.uint32)
    out[0] = doc_ids[0]
    np.subtract(doc_ids[1:], doc_ids[:-1], out=out[1:], casting="unsafe")
    return out


def undgaps(gaps: np.ndarray) -> np.ndarray:
    """Inverse d-gap transform, int64-safe.

    The cumulative sum runs in int64 and is checked before narrowing: a doc id
    past 2^31-1 (corrupt stream or gap overflow) raises instead of silently
    wrapping to a negative int32.
    """
    ids = np.cumsum(gaps.astype(np.int64))
    if ids.size and int(ids[-1]) > np.iinfo(np.int32).max:
        raise OverflowError(f"doc id {int(ids[-1])} exceeds int32 range")
    return ids.astype(np.int32)


# --------------------------------------------------------------------------- varbyte
def varbyte_size_bits(gaps: np.ndarray) -> int:
    if len(gaps) == 0:
        return 0
    v = np.maximum(gaps.astype(np.int64), 1)
    nbytes = (np.floor(np.log2(v)).astype(np.int64) // 7) + 1
    return int(nbytes.sum() * 8)


def varbyte_encode(gaps: np.ndarray) -> np.ndarray:
    out = bytearray()
    for g in gaps.tolist():
        g = int(g)
        while True:
            b = g & 0x7F
            g >>= 7
            if g:
                out.append(b)
            else:
                out.append(b | 0x80)
                break
    buf = np.frombuffer(bytes(out), dtype=np.uint8)
    pad = (-len(buf)) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, np.uint8)])
    return buf.view(np.uint32).copy()


def varbyte_decode(words: np.ndarray, n: int) -> np.ndarray:
    buf = words.view(np.uint8)
    out = np.empty(n, dtype=np.uint32)
    val, shift, j = 0, 0, 0
    for b in buf.tolist():
        val |= (b & 0x7F) << shift
        if b & 0x80:
            out[j] = val
            j += 1
            if j == n:
                break
            val, shift = 0, 0
        else:
            shift += 7
    return out


# --------------------------------------------------------------------------- bitpack
def pack_bits(vals: np.ndarray, width: int) -> np.ndarray:
    """Pack uint32 vals (each < 2**width) into a dense little-endian bitstream."""
    n = len(vals)
    if width == 0 or n == 0:
        return np.zeros(0, dtype=np.uint32)
    total_bits = n * width
    words = np.zeros((total_bits + 31) // 32, dtype=np.uint64)
    bitpos = np.arange(n, dtype=np.int64) * width
    word_idx, off = bitpos // 32, (bitpos % 32).astype(np.uint64)
    v = vals.astype(np.uint64)
    lo = (v << off) & np.uint64(0xFFFFFFFF)
    hi = v >> (np.uint64(32) - off).clip(max=np.uint64(63))
    hi = np.where(off == 0, 0, hi)
    np.bitwise_or.at(words, word_idx, lo)
    spill = word_idx + 1 < len(words)
    np.bitwise_or.at(words, word_idx[spill] + 1, hi[spill])
    return words.astype(np.uint32)


def unpack_bits(words: np.ndarray, width: int, n: int) -> np.ndarray:
    if width == 0 or n == 0:
        return np.zeros(n, dtype=np.uint32)
    return unpack_bits_at(words, width, np.arange(n, dtype=np.int64))


def unpack_bits_at(words: np.ndarray, width: int, indices: np.ndarray) -> np.ndarray:
    """Unpack only the values at `indices` from a pack_bits stream.

    The guided-search probe path decodes ε-windows, not whole lists, so it
    must read packed corrections at arbitrary positions without touching the
    rest of the stream.
    """
    if width == 0 or len(indices) == 0:
        return np.zeros(len(indices), dtype=np.uint32)
    w = words.astype(np.uint64)
    bitpos = np.asarray(indices, dtype=np.int64) * width
    word_idx, off = bitpos // 32, (bitpos % 32).astype(np.uint64)
    lo = w[word_idx] >> off
    nxt = np.where(word_idx + 1 < len(w), w[np.minimum(word_idx + 1, len(w) - 1)], 0)
    hi = np.where(off == 0, 0, nxt << (np.uint64(32) - off))
    mask = (np.uint64(1) << np.uint64(width)) - np.uint64(1)
    return ((lo | hi) & mask).astype(np.uint32)


# --------------------------------------------------------------------------- OptPFD
def _block_cost_bits(block: np.ndarray, b: int) -> int:
    """Cost of one block at base width b: header + packed + exceptions.

    Exceptions (vals >= 2**b) store their high bits in a 32-bit overflow slot
    plus an 8-bit position; header = 8 bits (width) + 16 bits (n_exceptions).
    """
    exc = int((block >> np.uint32(b)).astype(bool).sum()) if b < 32 else 0
    return 24 + len(block) * b + exc * 40


def optpfd_size_bits(gaps: np.ndarray) -> int:
    """Per-block optimal width (the 'Opt' in OptPFD)."""
    if len(gaps) == 0:
        return 0
    total = 0
    for s in range(0, len(gaps), BLOCK):
        block = gaps[s : s + BLOCK].astype(np.uint32)
        maxv = int(block.max())
        widths = range(0, max(1, maxv.bit_length()) + 1)
        total += min(_block_cost_bits(block, b) for b in widths)
    return total


def optpfd_encode(gaps: np.ndarray) -> np.ndarray:
    """Streamable encoding: per block [width|n_exc|n] + packed + exception pairs."""
    chunks: list[np.ndarray] = []
    for s in range(0, len(gaps), BLOCK):
        block = gaps[s : s + BLOCK].astype(np.uint32)
        maxv = int(block.max()) if len(block) else 0
        best_b, best_c = 0, None
        for b in range(0, max(1, maxv.bit_length()) + 1):
            c = _block_cost_bits(block, b)
            if best_c is None or c < best_c:
                best_b, best_c = b, c
        b = best_b
        if b < 32:
            exc_pos = np.nonzero(block >> np.uint32(b))[0]
        else:
            exc_pos = np.zeros(0, dtype=np.int64)
        low = block & ((np.uint32(1) << np.uint32(b)) - np.uint32(1)) if b < 32 else block
        header = np.array([b | (len(exc_pos) << 8) | (len(block) << 24)], dtype=np.uint32)
        packed = pack_bits(low, b)
        exc = np.stack(
            [exc_pos.astype(np.uint32), (block[exc_pos] >> np.uint32(b))], axis=1
        ).reshape(-1) if len(exc_pos) else np.zeros(0, np.uint32)
        chunks += [header, packed, exc]
    return np.concatenate(chunks) if chunks else np.zeros(0, np.uint32)


def optpfd_decode(words: np.ndarray, n: int) -> np.ndarray:
    out = np.empty(n, dtype=np.uint32)
    pos, done = 0, 0
    while done < n:
        h = int(words[pos]); pos += 1
        b, n_exc, blen = h & 0xFF, (h >> 8) & 0xFFFF, h >> 24
        n_words = (blen * b + 31) // 32
        block = unpack_bits(words[pos : pos + n_words], b, blen)
        pos += n_words
        for _ in range(n_exc):
            p, hi = int(words[pos]), int(words[pos + 1]); pos += 2
            block[p] |= np.uint32(hi << b)
        out[done : done + blen] = block
        done += blen
    return out


# --------------------------------------------------------------------------- Elias-Fano
def _ef_split(n: int, universe: int) -> int:
    """Low-bit width l for Elias-Fano: floor(log2(u/n)), 0 when the list is
    dense (universe <= n, where the high-bit unary part alone is optimal)."""
    if universe <= n:
        return 0
    return int(np.floor(np.log2(universe / n)))


def eliasfano_size_bits(doc_ids: np.ndarray, universe: int) -> int:
    n = len(doc_ids)
    if n == 0:
        return 0
    universe = max(universe, int(doc_ids[-1]) + 1)
    l = _ef_split(n, universe)
    # n low halves + unary high halves (n stop bits + universe>>l bucket bits)
    return n * l + 2 * n + (universe >> l) + 2


def eliasfano_encode(doc_ids: np.ndarray, universe: int) -> np.ndarray:
    """Streamable Elias-Fano: [l | n_high_words<<8] + packed lows + unary highs."""
    n = len(doc_ids)
    if n == 0:
        return np.zeros(0, np.uint32)
    ids = np.asarray(doc_ids, np.int64)
    universe = max(universe, int(ids[-1]) + 1)
    l = _ef_split(n, universe)
    low = (ids & ((1 << l) - 1)).astype(np.uint32)
    high = (ids >> l).astype(np.int64)
    hv_bits = n + (universe >> l) + 1
    hv = np.zeros(hv_bits, np.uint8)
    hv[high + np.arange(n, dtype=np.int64)] = 1
    hv_words = np.packbits(hv, bitorder="little")
    pad = (-len(hv_words)) % 4
    if pad:
        hv_words = np.concatenate([hv_words, np.zeros(pad, np.uint8)])
    hv_words = hv_words.view(np.uint32)
    header = np.array([l | (len(hv_words) << 8)], dtype=np.uint32)
    return np.concatenate([header, pack_bits(low, l), hv_words])


def eliasfano_decode(words: np.ndarray, n: int) -> np.ndarray:
    if n == 0:
        return np.zeros(0, np.int32)
    h = int(words[0])
    l, n_high_words = h & 0xFF, h >> 8
    n_low_words = (n * l + 31) // 32
    low = unpack_bits(words[1 : 1 + n_low_words], l, n).astype(np.int64)
    hv = np.unpackbits(
        words[1 + n_low_words : 1 + n_low_words + n_high_words].view(np.uint8),
        bitorder="little",
    )
    ones = np.flatnonzero(hv)[:n]
    high = ones - np.arange(n, dtype=np.int64)
    return ((high << l) | low).astype(np.int32)


def bitvector_size_bits(universe: int) -> int:
    return universe


def bitvector_encode(doc_ids: np.ndarray, universe: int) -> np.ndarray:
    n = len(doc_ids)
    if n == 0:
        return np.zeros(0, np.uint32)
    ids = np.asarray(doc_ids, np.int64)
    universe = max(universe, int(ids[-1]) + 1)
    bits = np.zeros(universe, np.uint8)
    bits[ids] = 1
    by = np.packbits(bits, bitorder="little")
    pad = (-len(by)) % 4
    if pad:
        by = np.concatenate([by, np.zeros(pad, np.uint8)])
    return by.view(np.uint32).copy()


def bitvector_decode(words: np.ndarray, n: int) -> np.ndarray:
    if n == 0:
        return np.zeros(0, np.int32)
    bits = np.unpackbits(words.view(np.uint8), bitorder="little")
    return np.flatnonzero(bits)[:n].astype(np.int32)


# --------------------------------------------------------------------------- dispatch
# Every codec has a size model; every codec here also has an exact lossless
# encoder/decoder pair.  "plm"/"rmi" are the learned rank-model codecs of
# repro.postings (lazy-imported to keep this module numpy-only at import
# time); "hybrid" is the per-term min-bits selector (repro.postings.hybrid).
CODECS = ("optpfd", "varbyte", "eliasfano", "bitvector", "plm", "rmi")


def _default_universe(doc_ids: np.ndarray, universe: int | None) -> int:
    if universe is not None:
        return universe
    return int(doc_ids[-1]) + 1 if len(doc_ids) else 0


def compressed_size_bits(
    doc_ids: np.ndarray,
    universe: int,
    codec: str = "optpfd",
    *,
    eps: int | None = None,
) -> int:
    """Exact compressed bits of one posting list under `codec`.

    `eps` is the learned-codec error bound (plm correction budget); classical
    codecs ignore it.  codec="hybrid" returns the per-term minimum over all
    codecs plus the selector tag bits.
    """
    doc_ids = np.asarray(doc_ids)
    if codec == "optpfd":
        return optpfd_size_bits(dgaps(doc_ids))
    if codec == "varbyte":
        return varbyte_size_bits(dgaps(doc_ids))
    if codec == "eliasfano":
        return eliasfano_size_bits(doc_ids, universe)
    if codec == "bitvector":
        return bitvector_size_bits(universe)
    if codec == "plm":
        from repro.postings.plm import DEFAULT_EPS, plm_size_bits

        return plm_size_bits(doc_ids, DEFAULT_EPS if eps is None else eps)
    if codec == "rmi":
        from repro.postings.rmi import rmi_size_bits

        return rmi_size_bits(doc_ids)
    if codec == "hybrid":
        from repro.postings.hybrid import hybrid_size_bits

        return hybrid_size_bits(doc_ids, universe, eps=eps)
    raise ValueError(f"unknown codec {codec}")


def encode_postings(
    doc_ids: np.ndarray,
    codec: str = "optpfd",
    *,
    universe: int | None = None,
    eps: int | None = None,
) -> np.ndarray:
    """Encode a sorted doc-id list to a uint32 word stream under `codec`."""
    doc_ids = np.asarray(doc_ids)
    if codec == "optpfd":
        return optpfd_encode(dgaps(doc_ids))
    if codec == "varbyte":
        return varbyte_encode(dgaps(doc_ids))
    if codec == "eliasfano":
        return eliasfano_encode(doc_ids, _default_universe(doc_ids, universe))
    if codec == "bitvector":
        return bitvector_encode(doc_ids, _default_universe(doc_ids, universe))
    if codec == "plm":
        from repro.postings.plm import DEFAULT_EPS, plm_encode

        return plm_encode(doc_ids, DEFAULT_EPS if eps is None else eps)
    if codec == "rmi":
        from repro.postings.rmi import rmi_encode

        return rmi_encode(doc_ids)
    if codec == "hybrid":
        from repro.postings.hybrid import hybrid_encode

        return hybrid_encode(doc_ids, _default_universe(doc_ids, universe), eps=eps)
    raise ValueError(f"unknown codec {codec}")


def decode_postings(words: np.ndarray, n: int, codec: str = "optpfd") -> np.ndarray:
    """Exact inverse of encode_postings -> sorted int32 doc ids."""
    if codec == "optpfd":
        return undgaps(optpfd_decode(words, n))
    if codec == "varbyte":
        return undgaps(varbyte_decode(words, n))
    if codec == "eliasfano":
        return eliasfano_decode(words, n)
    if codec == "bitvector":
        return bitvector_decode(words, n)
    if codec == "plm":
        from repro.postings.plm import plm_decode

        return plm_decode(words, n)
    if codec == "rmi":
        from repro.postings.rmi import rmi_decode

        return rmi_decode(words, n)
    if codec == "hybrid":
        from repro.postings.hybrid import hybrid_decode

        return hybrid_decode(words, n)
    raise ValueError(f"unknown codec {codec}")


def index_size_bits(
    term_offsets: np.ndarray,
    doc_ids: np.ndarray,
    universe: int,
    codec: str = "optpfd",
    *,
    eps: int | None = None,
) -> np.ndarray:
    """Per-term compressed sizes for a whole index (vector over terms)."""
    n_terms = len(term_offsets) - 1
    sizes = np.zeros(n_terms, dtype=np.int64)
    for t in range(n_terms):
        lo, hi = term_offsets[t], term_offsets[t + 1]
        if hi > lo:
            sizes[t] = compressed_size_bits(doc_ids[lo:hi], universe, codec, eps=eps)
    return sizes
