"""Postings-list compression codecs.

The paper measures storage under OptPFOR [Lemire & Boytsov '15]; we implement
OptPFD (per-128-block optimal bit width + exception patching) plus varbyte,
Elias-Fano and raw bitvectors, so the Fig-1/Fig-2 storage analysis and the
hybrid representations of §3.3 are all measurable.

All codecs operate on a sorted doc-id list; d-gap transform first. Encoders
return a uint32 word array; sizes are exact bit counts (compressed_size_bits)
so Eq. (2) can be evaluated without byte-alignment noise.
"""
from __future__ import annotations

import numpy as np

BLOCK = 128  # PFor block length; matches SIMD-friendly CPU codecs & 128-lane VREG


# --------------------------------------------------------------------------- dgap
def dgaps(doc_ids: np.ndarray) -> np.ndarray:
    if len(doc_ids) == 0:
        return doc_ids.astype(np.uint32)
    out = np.empty_like(doc_ids, dtype=np.uint32)
    out[0] = doc_ids[0]
    np.subtract(doc_ids[1:], doc_ids[:-1], out=out[1:], casting="unsafe")
    return out


def undgaps(gaps: np.ndarray) -> np.ndarray:
    return np.cumsum(gaps.astype(np.int64)).astype(np.int32)


# --------------------------------------------------------------------------- varbyte
def varbyte_size_bits(gaps: np.ndarray) -> int:
    if len(gaps) == 0:
        return 0
    v = np.maximum(gaps.astype(np.int64), 1)
    nbytes = (np.floor(np.log2(v)).astype(np.int64) // 7) + 1
    return int(nbytes.sum() * 8)


def varbyte_encode(gaps: np.ndarray) -> np.ndarray:
    out = bytearray()
    for g in gaps.tolist():
        g = int(g)
        while True:
            b = g & 0x7F
            g >>= 7
            if g:
                out.append(b)
            else:
                out.append(b | 0x80)
                break
    buf = np.frombuffer(bytes(out), dtype=np.uint8)
    pad = (-len(buf)) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, np.uint8)])
    return buf.view(np.uint32).copy()


def varbyte_decode(words: np.ndarray, n: int) -> np.ndarray:
    buf = words.view(np.uint8)
    out = np.empty(n, dtype=np.uint32)
    val, shift, j = 0, 0, 0
    for b in buf.tolist():
        val |= (b & 0x7F) << shift
        if b & 0x80:
            out[j] = val
            j += 1
            if j == n:
                break
            val, shift = 0, 0
        else:
            shift += 7
    return out


# --------------------------------------------------------------------------- bitpack
def pack_bits(vals: np.ndarray, width: int) -> np.ndarray:
    """Pack uint32 vals (each < 2**width) into a dense little-endian bitstream."""
    n = len(vals)
    if width == 0 or n == 0:
        return np.zeros(0, dtype=np.uint32)
    total_bits = n * width
    words = np.zeros((total_bits + 31) // 32, dtype=np.uint64)
    bitpos = np.arange(n, dtype=np.int64) * width
    word_idx, off = bitpos // 32, (bitpos % 32).astype(np.uint64)
    v = vals.astype(np.uint64)
    lo = (v << off) & np.uint64(0xFFFFFFFF)
    hi = v >> (np.uint64(32) - off).clip(max=np.uint64(63))
    hi = np.where(off == 0, 0, hi)
    np.bitwise_or.at(words, word_idx, lo)
    spill = word_idx + 1 < len(words)
    np.bitwise_or.at(words, word_idx[spill] + 1, hi[spill])
    return words.astype(np.uint32)


def unpack_bits(words: np.ndarray, width: int, n: int) -> np.ndarray:
    if width == 0 or n == 0:
        return np.zeros(n, dtype=np.uint32)
    w = words.astype(np.uint64)
    bitpos = np.arange(n, dtype=np.int64) * width
    word_idx, off = bitpos // 32, (bitpos % 32).astype(np.uint64)
    lo = w[word_idx] >> off
    nxt = np.where(word_idx + 1 < len(w), w[np.minimum(word_idx + 1, len(w) - 1)], 0)
    hi = np.where(off == 0, 0, nxt << (np.uint64(32) - off))
    mask = (np.uint64(1) << np.uint64(width)) - np.uint64(1)
    return ((lo | hi) & mask).astype(np.uint32)


# --------------------------------------------------------------------------- OptPFD
def _block_cost_bits(block: np.ndarray, b: int) -> int:
    """Cost of one block at base width b: header + packed + exceptions.

    Exceptions (vals >= 2**b) store their high bits in a 32-bit overflow slot
    plus an 8-bit position; header = 8 bits (width) + 16 bits (n_exceptions).
    """
    exc = int((block >> np.uint32(b)).astype(bool).sum()) if b < 32 else 0
    return 24 + len(block) * b + exc * 40


def optpfd_size_bits(gaps: np.ndarray) -> int:
    """Per-block optimal width (the 'Opt' in OptPFD)."""
    if len(gaps) == 0:
        return 0
    total = 0
    for s in range(0, len(gaps), BLOCK):
        block = gaps[s : s + BLOCK].astype(np.uint32)
        maxv = int(block.max())
        widths = range(0, max(1, maxv.bit_length()) + 1)
        total += min(_block_cost_bits(block, b) for b in widths)
    return total


def optpfd_encode(gaps: np.ndarray) -> np.ndarray:
    """Streamable encoding: per block [width|n_exc|n] + packed + exception pairs."""
    chunks: list[np.ndarray] = []
    for s in range(0, len(gaps), BLOCK):
        block = gaps[s : s + BLOCK].astype(np.uint32)
        maxv = int(block.max()) if len(block) else 0
        best_b, best_c = 0, None
        for b in range(0, max(1, maxv.bit_length()) + 1):
            c = _block_cost_bits(block, b)
            if best_c is None or c < best_c:
                best_b, best_c = b, c
        b = best_b
        if b < 32:
            exc_pos = np.nonzero(block >> np.uint32(b))[0]
        else:
            exc_pos = np.zeros(0, dtype=np.int64)
        low = block & ((np.uint32(1) << np.uint32(b)) - np.uint32(1)) if b < 32 else block
        header = np.array([b | (len(exc_pos) << 8) | (len(block) << 24)], dtype=np.uint32)
        packed = pack_bits(low, b)
        exc = np.stack(
            [exc_pos.astype(np.uint32), (block[exc_pos] >> np.uint32(b))], axis=1
        ).reshape(-1) if len(exc_pos) else np.zeros(0, np.uint32)
        chunks += [header, packed, exc]
    return np.concatenate(chunks) if chunks else np.zeros(0, np.uint32)


def optpfd_decode(words: np.ndarray, n: int) -> np.ndarray:
    out = np.empty(n, dtype=np.uint32)
    pos, done = 0, 0
    while done < n:
        h = int(words[pos]); pos += 1
        b, n_exc, blen = h & 0xFF, (h >> 8) & 0xFFFF, h >> 24
        n_words = (blen * b + 31) // 32
        block = unpack_bits(words[pos : pos + n_words], b, blen)
        pos += n_words
        for _ in range(n_exc):
            p, hi = int(words[pos]), int(words[pos + 1]); pos += 2
            block[p] |= np.uint32(hi << b)
        out[done : done + blen] = block
        done += blen
    return out


# --------------------------------------------------------------------------- Elias-Fano
def eliasfano_size_bits(doc_ids: np.ndarray, universe: int) -> int:
    n = len(doc_ids)
    if n == 0:
        return 0
    l = max(0, int(np.floor(np.log2(max(universe, 1) / n))) if universe > n else 0)
    return n * l + 2 * n + universe // max(1, 2**l) + 2  # low bits + unary high bits


def bitvector_size_bits(universe: int) -> int:
    return universe


# --------------------------------------------------------------------------- dispatch
CODECS = ("optpfd", "varbyte", "eliasfano", "bitvector")


def compressed_size_bits(doc_ids: np.ndarray, universe: int, codec: str = "optpfd") -> int:
    g = dgaps(np.asarray(doc_ids))
    if codec == "optpfd":
        return optpfd_size_bits(g)
    if codec == "varbyte":
        return varbyte_size_bits(g)
    if codec == "eliasfano":
        return eliasfano_size_bits(np.asarray(doc_ids), universe)
    if codec == "bitvector":
        return bitvector_size_bits(universe)
    raise ValueError(f"unknown codec {codec}")


def encode_postings(doc_ids: np.ndarray, codec: str = "optpfd") -> np.ndarray:
    g = dgaps(np.asarray(doc_ids))
    if codec == "optpfd":
        return optpfd_encode(g)
    if codec == "varbyte":
        return varbyte_encode(g)
    raise ValueError(f"codec {codec} has size-model only (no bytestream encoder)")


def decode_postings(words: np.ndarray, n: int, codec: str = "optpfd") -> np.ndarray:
    if codec == "optpfd":
        g = optpfd_decode(words, n)
    elif codec == "varbyte":
        g = varbyte_decode(words, n)
    else:
        raise ValueError(f"codec {codec} has size-model only (no bytestream decoder)")
    return undgaps(g)


def index_size_bits(
    term_offsets: np.ndarray, doc_ids: np.ndarray, universe: int, codec: str = "optpfd"
) -> np.ndarray:
    """Per-term compressed sizes for a whole index (vector over terms)."""
    n_terms = len(term_offsets) - 1
    sizes = np.zeros(n_terms, dtype=np.int64)
    for t in range(n_terms):
        lo, hi = term_offsets[t], term_offsets[t + 1]
        if hi > lo:
            sizes[t] = compressed_size_bits(doc_ids[lo:hi], universe, codec)
    return sizes
