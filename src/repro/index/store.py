"""Persistent index format: InvertedIndex + HybridPostings on disk, mmap-lazy.

The serving stack used to rebuild its compressed tier-2 store from live
Python objects on every process start — every restart re-ran codec selection
and PLM/RMI fits over the whole collection.  This module is the restartable
form: a versioned directory layout holding the CSR inverted index and every
term's tag-prefixed hybrid stream (codec tag, ε, segment models, bit-packed
corrections) as flat binary arenas, loaded back with ``np.memmap`` so an
engine starts in O(open) time and only the stream bytes a query actually
probes are ever paged in.

Single-index layout (``save_index`` / ``load_index``)::

  <dir>/meta.json           magic, STORE_VERSION, n_docs/n_terms/universe,
                            per-array dtype+shape manifest, crc32 checksums
  <dir>/term_offsets.bin    int64  (n_terms+1,)   CSR offsets into doc_ids
  <dir>/doc_ids.bin         int32  (n_postings,)  sorted per term
  <dir>/tfs.bin             int32  (n_postings,)  term frequencies (may be empty)
  <dir>/lens.bin            int64  (n_terms,)     posting-list lengths
  <dir>/tags.bin            uint8  (n_terms,)     codec tag per term
  <dir>/bits.bin            int64  (n_terms,)     measured size incl. TAG_BITS
  <dir>/stream_offsets.bin  int64  (n_terms+1,)   word offsets into streams
  <dir>/streams.bin         uint32 (total_words,) tag-prefixed hybrid streams
  <dir>/payload_offsets.bin int64  (n_terms+1,)   word offsets into payloads
  <dir>/payloads.bin        uint32 (payload_words,) packed quantized impacts
  <dir>/ub_offsets.bin      int64  (n_terms+1,)   offsets into seg_ubs
  <dir>/seg_ubs.bin         uint32 (n_segments,)  per-segment score bounds

Layout v2 added the ranked-tier arrays (tfs, payloads, segment score
bounds); a v1 directory still loads — its payload arrays are simply absent
and the store serves Boolean-only.  Loading a layout *newer* than this
reader raises ``UnsupportedVersionError`` before any array is parsed.

Doc-partitioned layout (``save_sharded`` / ``load_sharded``): a top-level
``shards.json`` records the version, global doc count and every shard's
``[lo, hi)`` doc-id range; ``shard-NNNN/`` subdirectories each hold one
single-index layout over *local* doc ids (``global = local + lo``).

Round-trips are bit-exact per codec: streams are written verbatim, so a
reloaded store decodes the identical word sequences the builder measured.
"""
from __future__ import annotations

import json
import os
import zlib

import numpy as np

from repro.index.build import InvertedIndex
from repro.postings.hybrid import HybridPostings

STORE_VERSION = 2  # v2: ranked payload streams + segment score bounds
MAGIC = "repro-index"
META = "meta.json"
SHARDS_META = "shards.json"

_ARRAYS = (
    # (name, attr owner, dtype)
    ("term_offsets", "inv", np.int64),
    ("doc_ids", "inv", np.int32),
    ("lens", "store", np.int64),
    ("tags", "store", np.uint8),
    ("bits", "store", np.int64),
    ("stream_offsets", "store", np.int64),
    ("streams", "store", np.uint32),
)

# layout-v2 additions; absent from v1 metas, loaded only when present
_ARRAYS_V2 = (
    ("tfs", "inv", np.int32),
    ("payload_offsets", "store", np.int64),
    ("payloads", "store", np.uint32),
    ("ub_offsets", "store", np.int64),
    ("seg_ubs", "store", np.uint32),
)


class UnsupportedVersionError(ValueError):
    """The on-disk layout was written by a newer repro than this reader."""


class StreamArena:
    """Per-term uint32 stream views into one flat (possibly memmapped) arena.

    Quacks like the ``list[np.ndarray]`` HybridPostings carries when built in
    memory, but holds a single backing buffer: ``arena[t]`` is a zero-copy
    slice, so loading an index touches no stream bytes until a term is probed.
    """

    def __init__(self, words: np.ndarray, offsets: np.ndarray):
        self._words = words
        self._offsets = offsets

    def __len__(self) -> int:
        return len(self._offsets) - 1

    def __getitem__(self, t: int) -> np.ndarray:
        return self._words[int(self._offsets[t]) : int(self._offsets[t + 1])]

    def __iter__(self):
        return (self[t] for t in range(len(self)))


def _flatten_streams(streams) -> tuple[np.ndarray, np.ndarray]:
    offsets = np.zeros(len(streams) + 1, np.int64)
    np.cumsum([int(s.size) for s in streams], out=offsets[1:])
    if int(offsets[-1]) == 0:
        return np.zeros(0, np.uint32), offsets
    return np.concatenate([np.asarray(s, np.uint32) for s in streams]), offsets


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_index(path: str, inv: InvertedIndex, store: HybridPostings) -> None:
    """Write one (inverted index, hybrid store) pair to a versioned layout."""
    if store.n_terms != inv.n_terms:
        raise ValueError(f"store has {store.n_terms} terms, index {inv.n_terms}")
    os.makedirs(path, exist_ok=True)
    streams, stream_offsets = _flatten_streams(store.streams)
    arrays = {
        "term_offsets": np.asarray(inv.term_offsets, np.int64),
        "doc_ids": np.asarray(inv.doc_ids, np.int32),
        "tfs": (np.zeros(0, np.int32) if inv.tfs is None
                else np.asarray(inv.tfs, np.int32)),
        "lens": np.asarray(store.lens, np.int64),
        "tags": np.asarray(store.tags, np.uint8),
        "bits": np.asarray(store.bits, np.int64),
        "stream_offsets": stream_offsets,
        "streams": streams,
    }
    if store.has_payloads:
        payloads, payload_offsets = _flatten_streams(store.payload_streams)
        arrays["payload_offsets"] = payload_offsets
        arrays["payloads"] = payloads
        arrays["ub_offsets"] = np.asarray(store.ub_offsets, np.int64)
        arrays["seg_ubs"] = np.asarray(store.seg_ubs, np.uint32)
    else:
        zero_off = np.zeros(store.n_terms + 1, np.int64)
        arrays["payload_offsets"] = zero_off
        arrays["payloads"] = np.zeros(0, np.uint32)
        arrays["ub_offsets"] = zero_off
        arrays["seg_ubs"] = np.zeros(0, np.uint32)
    manifest = list(_ARRAYS) + list(_ARRAYS_V2)
    meta = {
        "magic": MAGIC,
        "version": STORE_VERSION,
        "n_docs": int(inv.n_docs),
        "n_terms": int(inv.n_terms),
        "universe": int(store.universe),
        "n_postings": int(inv.n_postings),
        "payload_bits": int(store.payload_bits),
        "payload_scale": float(store.payload_scale),
        "arrays": {
            name: {"dtype": np.dtype(dt).name, "shape": list(arrays[name].shape),
                   "crc32": _crc(arrays[name])}
            for name, _, dt in manifest
        },
    }
    for name, _, dt in manifest:
        arrays[name].astype(dt, copy=False).tofile(os.path.join(path, f"{name}.bin"))
    # meta last: a directory without meta.json is an aborted write, not an index
    with open(os.path.join(path, META), "w") as f:
        json.dump(meta, f, indent=1)


def _read_meta(path: str) -> dict:
    meta_path = os.path.join(path, META)
    if not os.path.exists(meta_path):
        raise FileNotFoundError(f"no index at {path} ({META} missing)")
    with open(meta_path) as f:
        meta = json.load(f)
    if meta.get("magic") != MAGIC:
        raise ValueError(f"{path}: not a {MAGIC} store")
    _check_version(meta, path)
    return meta


def _check_version(meta: dict, path: str) -> None:
    """Reject layouts this reader cannot parse, clearly.

    Newer layouts raise UnsupportedVersionError up front (rather than a
    crc/parse crash halfway into an array whose meaning changed); older
    versions back to 1 load fine — their additions are simply absent.
    """
    version = meta.get("version")
    if not isinstance(version, int) or version < 1:
        raise ValueError(f"{path}: bad store version {version!r}")
    if version > STORE_VERSION:
        raise UnsupportedVersionError(
            f"{path}: layout version {version} was written by a newer repro "
            f"(this reader supports <= {STORE_VERSION}); upgrade the reader "
            f"or re-save the index with it"
        )


def load_index(
    path: str, *, mmap: bool = True, verify: bool = False
) -> tuple[InvertedIndex, HybridPostings]:
    """Open a saved index.  mmap=True (default) pages bytes in lazily;
    verify=True additionally checks every array's crc32 (reads everything)."""
    meta = _read_meta(path)
    arrays: dict[str, np.ndarray] = {}
    manifest = [
        (name, owner, dt)
        for name, owner, dt in list(_ARRAYS) + list(_ARRAYS_V2)
        if name in meta["arrays"]  # v1 metas lack the ranked-tier arrays
    ]
    for name, _, dt in manifest:
        spec = meta["arrays"][name]
        fp = os.path.join(path, f"{name}.bin")
        n = int(np.prod(spec["shape"])) if spec["shape"] else 0
        if n == 0:
            arrays[name] = np.zeros(spec["shape"], dtype=dt)
        elif mmap:
            arrays[name] = np.memmap(fp, dtype=dt, mode="r", shape=tuple(spec["shape"]))
        else:
            arrays[name] = np.fromfile(fp, dtype=dt).reshape(spec["shape"])
        if verify and _crc(arrays[name]) != spec["crc32"]:
            raise ValueError(f"{path}/{name}.bin: crc32 mismatch (corrupt store)")
    tfs = arrays.get("tfs")
    inv = InvertedIndex(
        n_docs=meta["n_docs"],
        n_terms=meta["n_terms"],
        term_offsets=arrays["term_offsets"],
        doc_ids=arrays["doc_ids"],
        tfs=tfs if tfs is not None and tfs.size else None,
    )
    store = HybridPostings(
        universe=meta["universe"],
        lens=arrays["lens"],
        tags=arrays["tags"],
        bits=arrays["bits"],
        streams=StreamArena(arrays["streams"], arrays["stream_offsets"]),
    )
    if int(meta.get("payload_bits", 0)) > 0 and "payloads" in arrays:
        store.payload_bits = int(meta["payload_bits"])
        store.payload_scale = float(meta.get("payload_scale", 0.0))
        store.payload_streams = StreamArena(
            arrays["payloads"], arrays["payload_offsets"]
        )
        store.ub_offsets = arrays["ub_offsets"]
        store.seg_ubs = arrays["seg_ubs"]
    return inv, store


# -------------------------------------------------------------- sharded form
def _check_ranges(ranges, n_docs: int) -> None:
    """Ranges must tile [0, n_docs) contiguously with 32-aligned interior
    boundaries — BooleanEngine._merge word-copies each shard's packed bitmap
    at lo//32, so a misaligned or overlapping range would silently remap doc
    ids instead of failing."""
    prev = 0
    for i, (lo, hi) in enumerate(ranges):
        if lo != prev or hi < lo:
            raise ValueError(f"shard {i}: range [{lo}, {hi}) breaks contiguity at {prev}")
        if hi != n_docs and hi % 32 != 0:
            raise ValueError(f"shard {i}: boundary {hi} not 32-aligned")
        prev = hi
    if prev != n_docs:
        raise ValueError(f"shard ranges cover [0, {prev}), index has {n_docs} docs")


def save_sharded(
    path: str,
    n_docs: int,
    shards: list[tuple[tuple[int, int], InvertedIndex | None, HybridPostings | None]],
) -> None:
    """Write a doc-partitioned index: shards.json + one subdir per shard.

    ``shards`` lists ((lo, hi), local_inv, local_store) tiling [0, n_docs)
    contiguously with 32-aligned interior boundaries (checked — the bitmap
    merge depends on it); empty ranges (lo == hi) are recorded in the
    manifest but get no subdir and may carry None payloads.
    """
    _check_ranges([r for r, _, _ in shards], n_docs)
    os.makedirs(path, exist_ok=True)
    ranges = []
    for i, ((lo, hi), inv, store) in enumerate(shards):
        ranges.append([int(lo), int(hi)])
        if hi > lo:
            save_index(os.path.join(path, f"shard-{i:04d}"), inv, store)
    with open(os.path.join(path, SHARDS_META), "w") as f:
        json.dump({"magic": MAGIC, "version": STORE_VERSION,
                   "n_docs": int(n_docs), "ranges": ranges}, f, indent=1)


def load_sharded(
    path: str, *, mmap: bool = True, verify: bool = False
) -> tuple[int, list[tuple[tuple[int, int], InvertedIndex | None, HybridPostings | None]]]:
    """-> (n_docs, [((lo, hi), inv, store)]); empty ranges load as (None, None)."""
    meta_path = os.path.join(path, SHARDS_META)
    if not os.path.exists(meta_path):
        raise FileNotFoundError(f"no sharded index at {path} ({SHARDS_META} missing)")
    with open(meta_path) as f:
        meta = json.load(f)
    if meta.get("magic") != MAGIC:
        raise ValueError(f"{path}: not a {MAGIC} sharded store")
    _check_version(meta, path)
    _check_ranges(meta["ranges"], int(meta["n_docs"]))
    out = []
    for i, (lo, hi) in enumerate(meta["ranges"]):
        if hi > lo:
            inv, store = load_index(
                os.path.join(path, f"shard-{i:04d}"), mmap=mmap, verify=verify
            )
            if inv.n_docs != hi - lo:
                raise ValueError(f"{path}/shard-{i:04d}: {inv.n_docs} docs != range {hi - lo}")
        else:
            inv = store = None
        out.append(((int(lo), int(hi)), inv, store))
    return int(meta["n_docs"]), out
