from repro.index.build import InvertedIndex, build_inverted_index
from repro.index.compress import (
    CODECS,
    compressed_size_bits,
    decode_postings,
    encode_postings,
)
from repro.index.intersect import intersect_sorted, intersect_many

__all__ = [
    "InvertedIndex",
    "build_inverted_index",
    "CODECS",
    "compressed_size_bits",
    "encode_postings",
    "decode_postings",
    "intersect_sorted",
    "intersect_many",
]
