"""Fault-tolerant sharded checkpointing with elastic resharding.

Design (DESIGN.md §7):
  * one .npz per leaf group + a JSON manifest with treedef, shapes, dtypes;
  * writes go to `<dir>/tmp.<step>` then a single atomic os.rename to
    `<dir>/step_<n>` — a crash mid-write never corrupts the latest ckpt;
  * restore targets ANY mesh: leaves are loaded host-side then device_put
    with the *target* sharding (elastic scale up/down = reshard on load);
  * keep_last garbage-collects old steps, newest-first retention.

On multi-host pods each host writes only the shards it owns
(process-local addressable shards); this single-host build degenerates to
one writer without changing the format.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any, *, extra: dict | None = None) -> str:
    paths, leaves, _ = _flatten_with_paths(tree)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    # store raw bytes: numpy's npz cannot represent bf16 — dtype lives in the
    # manifest and is reconstructed via ml_dtypes on restore
    arrays = {
        f"leaf_{i}": np.frombuffer(np.asarray(l).tobytes(), dtype=np.uint8)
        for i, l in enumerate(leaves)
    }
    np.savez(os.path.join(tmp, "shards.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(name.split("_")[1])
        for name in os.listdir(directory)
        if name.startswith("step_") and os.path.exists(os.path.join(directory, name, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of `like`; device_put with `shardings`
    (a matching pytree of NamedSharding, or None = default placement).

    Elastic resharding: `shardings` may target a different mesh than the
    one the checkpoint was written from — leaves are loaded host-side and
    re-laid-out, so scale-up/down restarts are transparent.
    """
    import ml_dtypes  # bf16 & friends

    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shards.npz"))

    def _dtype(name: str) -> np.dtype:
        try:
            return np.dtype(name)
        except TypeError:
            return np.dtype(getattr(ml_dtypes, name))

    leaves = [
        np.frombuffer(data[f"leaf_{i}"].tobytes(), dtype=_dtype(dt)).reshape(shp)
        for i, (dt, shp) in enumerate(zip(manifest["dtypes"], manifest["shapes"]))
    ]
    _, like_leaves, treedef = _flatten_with_paths(like)
    if len(like_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, target structure has {len(like_leaves)}"
        )
    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding)
        )
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shard_leaves)]
    else:
        leaves = [jax.device_put(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        out = save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()
        return out

    def restore_latest(self, like: Any, shardings: Any = None) -> tuple[int, Any] | None:
        step = latest_step(self.directory)
        if step is None:
            return None
        return step, restore_checkpoint(self.directory, step, like, shardings=shardings)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
