"""Pallas TPU kernels for the perf-critical compute of the learned index.

Four hot spots, per DESIGN.md §3:
  membership/  f(t, ·) scoring over doc tiles: MXU matmul + threshold + bit-pack
  bitset/      Algorithm-3 block-bitmap AND + popcount over packed u32 words
  pfor/        OptPFD fixed-width bit-unpack (tier-2 postings decode)
  plm_decode/  learned-codec (plm/rmi) batched segment-eval + correction add

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper, CPU fallback via interpret=True), ref.py (pure-jnp oracle).
"""
