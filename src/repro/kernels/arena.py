"""Persistent device arenas: upload a shard's index once, dispatch forever.

Every fused/guided/score dispatch used to re-stage its inputs: the host
bridge gathered packed words, built (Q, T, C, W) tiles and ``device_put``
them per call — ~84 small transfers per ranked batch, which the profiler
shows costing more than the kernel itself.  The arena inverts that: the
index-derived state a dispatch needs is uploaded to the device **once per
shard per process** and every subsequent dispatch passes the resident
buffers straight to jit — per-dispatch host traffic is only the (tiny)
query-dependent arrays.

Two residency surfaces:

  * ``DeviceArena`` — one per shard: the decoded term impacts laid out as a
    dense ``(n_terms + 1, n_docs)`` table (row t = term t's quantized
    impact per local doc, zero where absent; the extra row is an all-zero
    pad target for -1 query slots).  This is the input of the dense
    one-dispatch ranked loop (kernels.fused_query.dense): scoring a batch
    is a row *gather* plus a sum over the term axis — no per-posting
    scatter, which XLA:CPU serializes.  The dense layout trades memory for
    dispatch shape: it is built only while ``n_docs <= DENSE_MAX_DOCS`` and
    ``(n_terms + 1) * n_docs <= DENSE_MAX_CELLS`` (the table then costs at
    most tens of MB at the narrowest dtype that holds the max impact).
    Built lazily on the first fused use — decode cost is startup, not
    serving — and counted on the shard's metrics registry.
  * ``resident()`` — a module-level cache mapping a host stream (by
    identity) to its device copy, for kernels that consume long-lived
    index-derived arrays directly (guided_search gathers its term models'
    segment start/base/slope tables from resident copies).  The host
    array is kept referenced so an id() can never be reused while its
    device twin is alive.

Counters prove residence: ``uploads``/``upload_bytes`` move only while an
arena is built, ``hits`` on every dispatch that reused it — the residence
test asserts exactly that (zero re-uploads across repeated dispatches).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.obs import trace

# the dense device loop keeps a (Q, n_docs) int32 accumulator plus the
# impact table in device memory; past these sizes the bucketed kernel path
# wins, so the arena simply isn't built
DENSE_MAX_DOCS = 1 << 17
DENSE_MAX_CELLS = 1 << 26  # (n_terms + 1) * n_docs cap (64 MB at uint8)


@dataclass
class ArenaCounters:
    uploads: int = 0  # device_put events (arena build only)
    upload_bytes: int = 0
    hits: int = 0  # dispatches served from the resident buffers

    def as_dict(self) -> dict[str, int]:
        return {
            "uploads": int(self.uploads),
            "upload_bytes": int(self.upload_bytes),
            "hits": int(self.hits),
        }


def _impact_dtype(max_impact: int):
    if max_impact <= np.iinfo(np.uint8).max:
        return np.uint8
    if max_impact <= np.iinfo(np.uint16).max:
        return np.uint16
    return np.int32


@dataclass
class DeviceArena:
    """One shard's device-resident ranked-scoring arena.

    ``table[t, d]`` is term t's quantized impact on local doc d (0 where
    the posting is absent); row ``n_terms`` is all-zero so padded query
    slots gather nothing.  The table lives on device from construction on —
    the dense dispatch passes it to jit as-is, no per-call transfer.
    """

    n_docs: int
    n_terms: int
    table: object  # (n_terms + 1, n_docs) device array, smallest impact dtype
    host_lens: np.ndarray  # (n_terms,) int64 — lane counting stays host-side
    counters: ArenaCounters = field(default_factory=ArenaCounters)

    @classmethod
    def eligible(cls, n_terms: int, n_docs: int) -> bool:
        return (
            0 < n_docs <= DENSE_MAX_DOCS
            and (n_terms + 1) * n_docs <= DENSE_MAX_CELLS
        )

    @classmethod
    def build(cls, src, n_terms: int, n_docs: int) -> "DeviceArena":
        """Decode every non-empty term through ``src`` (a RankedSource) and
        upload the dense impact table.  One-time cost, traced and counted."""
        import jax.numpy as jnp

        lens = np.zeros(n_terms, np.int64)
        table = np.zeros((n_terms + 1, n_docs), np.int32)
        max_imp = 0
        for t in range(n_terms):
            if src.n(t) <= 0:
                continue
            ids, q = src.full(t)
            lens[t] = len(ids)
            table[t, np.asarray(ids, np.int64)] = q
            if len(q):
                max_imp = max(max_imp, int(np.max(q)))
        table = table.astype(_impact_dtype(max_imp))
        with trace.span(
            "arena.upload", terms=int((lens > 0).sum()),
            lanes=int(lens.sum()), bytes=int(table.nbytes),
        ):
            arena = cls(
                n_docs=int(n_docs),
                n_terms=int(n_terms),
                table=jnp.asarray(table),
                host_lens=lens,
            )
        arena.counters.uploads = 1
        arena.counters.upload_bytes = int(table.nbytes)
        return arena

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.table.dtype).itemsize)

    def lanes(self, terms) -> int:
        """Total postings lanes the given term ids cover (host-side count)."""
        return int(self.host_lens[np.asarray(terms, np.int64)].sum()) if len(terms) else 0


# --------------------------------------------------------- stream residency
# host stream id() -> (host ref, device copy); the host ref pins the id
_RESIDENT: dict[int, tuple[np.ndarray, object]] = {}
_STREAM_COUNTERS = ArenaCounters()


def resident(stream: np.ndarray):
    """Device twin of a long-lived host stream, uploaded at most once.

    Meant for index-derived arrays whose lifetime is the store's (packed
    correction/payload words): repeat dispatches stop paying the
    ``device_put``.  Do not pass per-query temporaries — they would pin.
    """
    key = id(stream)
    hit = _RESIDENT.get(key)
    if hit is not None:
        _STREAM_COUNTERS.hits += 1
        return hit[1]
    import jax.numpy as jnp

    dev = jnp.asarray(stream)
    _RESIDENT[key] = (stream, dev)
    _STREAM_COUNTERS.uploads += 1
    _STREAM_COUNTERS.upload_bytes += int(np.asarray(stream).nbytes)
    return dev


def stream_residency_counters() -> dict[str, int]:
    d = _STREAM_COUNTERS.as_dict()
    d["streams"] = len(_RESIDENT)
    return d
