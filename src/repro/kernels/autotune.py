"""Tile autotuner for the fused dense ranked dispatch.

The dense one-dispatch path (kernels.fused_query.dense) pads every batch to
a (rows, terms) bucket before jit: the row quantum and term quantum trade
padding waste (large quanta score pad rows/gather pad terms) against
jit-shape churn (small quanta compile one executable per batch size).  The
right point depends on the device — how much a wasted lane costs vs a
compile — so it is *searched*, not hard-coded: ``autotune_dense`` times a
mixed-batch-size synthetic workload under each (row_quantum, term_quantum)
candidate on the live backend, picks the fastest, applies it
(``dense.set_tile_params``) and persists the choice to a JSON cache keyed
by device kind.

The cache (``artifacts/autotune_cache.json``, uploaded as a CI artifact) is
a plain ``{device_key: {"dense": {...}, "timings_us": {...}}}`` map:
``apply_cache()`` restores a previously tuned configuration at startup
without re-running the search, and a cache tuned on one device kind never
leaks onto another.

Run directly (``python -m repro.kernels.autotune``) to tune and write the
cache; the dispatch-overhead benchmark does the same in CI.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

DEFAULT_CACHE = os.path.join("artifacts", "autotune_cache.json")
ROW_QUANTA = (4, 8, 16)
TERM_QUANTA = (2, 4, 8)


def device_key() -> str:
    """Stable identity of the backend the timings were taken on."""
    import jax

    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', 'unknown')}"


def _bucket(n: int, quantum: int) -> int:
    b = quantum
    while b < n:
        b *= 2
    return b


def _synthetic_arena(n_docs: int, n_terms: int, avg_len: int, seed: int):
    """A DeviceArena over synthetic postings — no index/store required."""
    import jax.numpy as jnp

    from repro.kernels.arena import DeviceArena

    rng = np.random.default_rng(seed)
    table = np.zeros((n_terms + 1, n_docs), np.uint8)
    lens = np.zeros(n_terms, np.int64)
    for t in range(n_terms):
        n = int(min(n_docs, 1 + rng.poisson(avg_len)))
        ids = rng.choice(n_docs, size=n, replace=False)
        table[t, ids] = rng.integers(1, 32, size=n)
        lens[t] = n
    return DeviceArena(
        n_docs=n_docs, n_terms=n_terms, table=jnp.asarray(table), host_lens=lens
    )


def _workload(n_terms: int, batch_sizes, terms_per_query: int, seed: int):
    """Mixed-size batches of random term lists — the shapes real coalesced
    traffic produces, so the tuner pays for jit churn exactly when serving
    would."""
    rng = np.random.default_rng(seed)
    batches = []
    for q in batch_sizes:
        batch = []
        for _ in range(q):
            w = int(rng.integers(2, terms_per_query + 1))
            batch.append(sorted(rng.choice(n_terms, size=w, replace=False)))
        batches.append(batch)
    return batches


def _time_config(arena, batches, k: int, row_q: int, term_q: int, reps: int) -> float:
    from repro.kernels.fused_query import dense

    dense.set_tile_params(row_q, term_q)

    def run_once() -> None:
        outs = []
        for batch in batches:
            Qb = _bucket(len(batch), row_q)
            T = _bucket(max(len(ts) for ts in batch), term_q)
            qt = np.full((Qb, T), -1, np.int32)
            for i, ts in enumerate(batch):
                qt[i, : len(ts)] = ts
            floors = np.zeros(Qb, np.int32)
            outs.append(dense.dense_topk(arena, qt, floors, k=k))
        for ids, scores, _ in outs:
            ids.block_until_ready()
            scores.block_until_ready()

    run_once()  # absorb compilation: steady-state dispatch is what's tuned
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        run_once()
        best = min(best, time.perf_counter() - t0)
    return float(best)


def autotune_dense(
    *,
    n_docs: int = 4096,
    n_terms: int = 512,
    avg_len: int = 48,
    batch_sizes=(1, 3, 5, 8, 13, 16),
    terms_per_query: int = 6,
    k: int = 10,
    reps: int = 3,
    seed: int = 7,
    cache_path: str | None = DEFAULT_CACHE,
) -> dict:
    """Search (row_quantum, term_quantum), apply the winner, persist it.

    Returns ``{"device": key, "dense": best_params, "timings_us": {...}}``;
    the process-global tile params are left set to the winner.
    """
    from repro.kernels.fused_query import dense

    arena = _synthetic_arena(n_docs, n_terms, avg_len, seed)
    batches = _workload(n_terms, batch_sizes, terms_per_query, seed + 1)
    prev = dense.tile_params()
    timings: dict[str, float] = {}
    best_cfg, best_s = None, np.inf
    try:
        for row_q in ROW_QUANTA:
            for term_q in TERM_QUANTA:
                s = _time_config(arena, batches, k, row_q, term_q, reps)
                timings[f"{row_q}x{term_q}"] = 1e6 * s
                if s < best_s:
                    best_cfg, best_s = (row_q, term_q), s
    finally:
        # the winner sticks; anything else (including an exception midway)
        # restores the tunables the process started with
        if best_cfg is not None:
            dense.set_tile_params(*best_cfg)
        else:
            dense.set_tile_params(prev["row_quantum"], prev["term_quantum"])
    report = {
        "device": device_key(),
        "dense": {"row_quantum": best_cfg[0], "term_quantum": best_cfg[1]},
        "best_us": 1e6 * best_s,
        "timings_us": timings,
        "workload": {
            "n_docs": n_docs,
            "n_terms": n_terms,
            "batch_sizes": list(batch_sizes),
            "k": k,
        },
    }
    if cache_path:
        save_cache(report, cache_path)
    return report


def save_cache(report: dict, path: str = DEFAULT_CACHE) -> None:
    """Merge one device's tuning into the on-disk cache (other keys kept)."""
    cache: dict = {}
    try:
        with open(path) as f:
            cache = json.load(f)
    except (OSError, ValueError):
        pass
    cache[report["device"]] = {k: v for k, v in report.items() if k != "device"}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(cache, f, indent=2)


def apply_cache(path: str = DEFAULT_CACHE) -> dict | None:
    """Restore this device's tuned tile params from the cache, if present."""
    from repro.kernels.fused_query import dense

    try:
        with open(path) as f:
            cache = json.load(f)
    except (OSError, ValueError):
        return None
    entry = cache.get(device_key())
    if not entry or "dense" not in entry:
        return None
    dense.set_tile_params(
        int(entry["dense"]["row_quantum"]), int(entry["dense"]["term_quantum"])
    )
    return entry


if __name__ == "__main__":
    r = autotune_dense()
    print(json.dumps(r, indent=2))
