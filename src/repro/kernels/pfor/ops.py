"""Host-facing PFor decode: width-bucketed batch decode + exception patching
+ gap prefix-sum, bridging index/compress.py streams to the Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.index.compress import BLOCK as CBLOCK
from repro.kernels.pfor.kernel import unpack_blocks
from repro.kernels.pfor.ref import BLOCK, words_per_block

assert CBLOCK == BLOCK


def parse_stream(words: np.ndarray, n: int):
    """Split an optpfd_encode stream into per-width block batches.

    Returns (batches, layout): batches[width] = (n_blocks_w, wpb) u32 array;
    layout = list of (width, slot_in_batch, block_len, exceptions[(pos, hi)]).
    """
    batches: dict[int, list[np.ndarray]] = {}
    layout = []
    pos, done = 0, 0
    while done < n:
        h = int(words[pos]); pos += 1
        b, n_exc, blen = h & 0xFF, (h >> 8) & 0xFFFF, h >> 24
        wpb = words_per_block(b)
        n_words = (blen * b + 31) // 32
        chunk = np.zeros(wpb, dtype=np.uint32)
        chunk[:n_words] = words[pos : pos + n_words]
        pos += n_words
        exc = []
        for _ in range(n_exc):
            exc.append((int(words[pos]), int(words[pos + 1])))
            pos += 2
        slot = len(batches.setdefault(b, []))
        batches[b].append(chunk)
        layout.append((b, slot, blen, exc))
        done += blen
    return {w: np.stack(c) for w, c in batches.items()}, layout


def decode_stream(words: np.ndarray, n: int, *, interpret: bool = True) -> np.ndarray:
    """Full OptPFD decode via the Pallas kernel; returns doc ids (gaps summed)."""
    batches, layout = parse_stream(words, n)
    decoded = {
        w: np.asarray(unpack_blocks(jnp.asarray(batch), width=w, interpret=interpret))
        for w, batch in batches.items()
    }
    gaps = np.empty(n, dtype=np.uint32)
    out_pos = 0
    for width, slot, blen, exc in layout:
        vals = decoded[width][slot, :blen].copy()
        for p, hi in exc:  # patch pass (<2% of values; host-side)
            vals[p] |= np.uint32(hi << width)
        gaps[out_pos : out_pos + blen] = vals
        out_pos += blen
    return np.cumsum(gaps.astype(np.int64)).astype(np.int32)
