"""Pure-jnp oracle for fixed-width bit-unpack (OptPFD block decode).

uint32-only arithmetic (jax default is 32-bit mode): a width<=32 value spans
at most two adjacent words; shifts stay in [0, 31] via where-guards.
"""
from __future__ import annotations

import jax.numpy as jnp

BLOCK = 128  # values per PFor block (matches index/compress.py)


def words_per_block(width: int) -> int:
    return max(1, (BLOCK * width + 31) // 32)


def unpack_block_ref(words: jnp.ndarray, width: int) -> jnp.ndarray:
    """(.., words_per_block) u32 -> (.., BLOCK) u32 at static bit width.

    Little-endian dense bitstream: value i occupies bits [i*w, (i+1)*w).
    width == 0 -> all zeros.
    """
    lead = words.shape[:-1]
    if width == 0:
        return jnp.zeros((*lead, BLOCK), dtype=jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) if width == 32 else jnp.uint32((1 << width) - 1)
    bitpos = jnp.arange(BLOCK, dtype=jnp.uint32) * jnp.uint32(width)
    word_idx = (bitpos // jnp.uint32(32)).astype(jnp.int32)
    off = bitpos % jnp.uint32(32)
    lo = jnp.take(words, word_idx, axis=-1) >> off
    nxt_idx = jnp.minimum(word_idx + 1, words.shape[-1] - 1)
    nxt = jnp.take(words, nxt_idx, axis=-1)
    shift = jnp.where(off == 0, jnp.uint32(0), jnp.uint32(32) - off)
    hi = jnp.where(off == 0, jnp.uint32(0), nxt << shift)
    return (lo | hi) & mask
