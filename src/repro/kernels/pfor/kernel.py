"""PFor fixed-width bit-unpack as a Pallas kernel.

TPU adaptation of SIMD PFor decode (Lemire & Boytsov; DESIGN.md §3): the
serving path groups compressed blocks by bit width, so each kernel launch
decodes a batch of same-width blocks — width is a *static* kernel parameter,
making every gather index and shift a compile-time constant vector. One
128-value block per grid row = one VREG-shaped tile; B_BLK blocks per grid
step amortize grid overhead.

Exceptions (the 'patch' in patched frame-of-reference) are scatter-applied
outside the kernel — they are <2% of values by construction of OptPFD's cost
model, so the patch pass is bandwidth-trivial.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pfor.ref import BLOCK, words_per_block

B_BLK = 64  # blocks decoded per grid step


def _make_kernel(width: int, wpb: int):
    def kernel(w_ref, o_ref):
        # all index math is rebuilt in-kernel from the static width so no
        # host-side array constants are captured (Pallas restriction)
        mask = jnp.uint32(0xFFFFFFFF) if width == 32 else jnp.uint32((1 << width) - 1)
        bitpos = jnp.arange(BLOCK, dtype=jnp.uint32) * jnp.uint32(width)
        word_idx = (bitpos // jnp.uint32(32)).astype(jnp.int32)
        off = bitpos % jnp.uint32(32)
        shift = jnp.where(off == 0, jnp.uint32(0), jnp.uint32(32) - off)
        nxt_idx = jnp.minimum(word_idx + 1, wpb - 1)
        w = w_ref[...]  # (B_BLK, wpb)
        lo = jnp.take(w, word_idx, axis=1) >> off[None, :]
        nxt = jnp.take(w, nxt_idx, axis=1)
        hi = jnp.where((off == 0)[None, :], jnp.uint32(0), nxt << shift[None, :])
        o_ref[...] = (lo | hi) & mask

    return kernel


@partial(jax.jit, static_argnames=("width", "interpret"))
def unpack_blocks(
    words: jax.Array,  # (n_blocks, words_per_block(width)) uint32
    *,
    width: int,
    interpret: bool = True,
) -> jax.Array:
    """Decode same-width PFor blocks -> (n_blocks, 128) uint32 values."""
    n, wpb = words.shape
    assert wpb == words_per_block(width), (wpb, width)
    if width == 0:
        return jnp.zeros((n, BLOCK), dtype=jnp.uint32)
    pad = (-n) % B_BLK
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _make_kernel(width, wpb),
        grid=((n + pad) // B_BLK,),
        in_specs=[pl.BlockSpec((B_BLK, wpb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((B_BLK, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, BLOCK), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[:n]
