"""Fused membership scoring: (Q,E)x(E,D) MXU matmul + threshold + bit-pack.

TPU adaptation of the paper's f(t, d) hot loop (DESIGN.md §3): instead of a
per-pair pointer-chase, a whole (128-query × 512-doc) tile is scored on the
MXU per grid step and immediately reduced to a packed u32 bitmask in VMEM —
the bitmask is 32× smaller than the logits, so HBM write-back is negligible
and the op stays compute-bound.

Block shapes: Q_BLK=128 rows (MXU-aligned), D_BLK=512 docs -> 16 output words
per query row. E (embed dim) is loaded whole per tile: E<=512 fits VMEM
comfortably (128·512·4B = 256 KiB per operand tile).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_BLK = 128
D_BLK = 512
LANE = 32  # bits per packed word


def _membership_kernel(q_ref, d_ref, tau_ref, bias_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)  # (Q_BLK, E)
    d = d_ref[...].astype(jnp.float32)  # (D_BLK, E)
    logits = jax.lax.dot_general(
        q, d, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q_BLK, D_BLK)
    logits = logits + bias_ref[0]
    hits = logits >= tau_ref[...][:, None]  # (Q_BLK, D_BLK)
    # pack 32 doc-lanes per u32 word; little-endian bit order matches ref
    h = hits.reshape(Q_BLK, D_BLK // LANE, LANE).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(LANE, dtype=jnp.uint32))[None, None, :]
    out_ref[...] = (h * weights).sum(axis=-1).astype(jnp.uint32)


@partial(jax.jit, static_argnames=("interpret",))
def membership_bitmask(
    q_embed: jax.Array,  # (Q, E), Q % Q_BLK == 0
    d_embed: jax.Array,  # (D, E), D % D_BLK == 0
    tau: jax.Array,  # (Q,)
    bias: jax.Array,  # ()
    *,
    interpret: bool = True,
) -> jax.Array:
    q, e = q_embed.shape
    d = d_embed.shape[0]
    assert q % Q_BLK == 0 and d % D_BLK == 0, (q, d)
    grid = (q // Q_BLK, d // D_BLK)
    return pl.pallas_call(
        _membership_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_BLK, e), lambda i, j: (i, 0)),
            pl.BlockSpec((D_BLK, e), lambda i, j: (j, 0)),
            pl.BlockSpec((Q_BLK,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((Q_BLK, D_BLK // LANE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, d // LANE), jnp.uint32),
        interpret=interpret,
    )(q_embed, d_embed, tau, jnp.reshape(bias, (1,)))
