"""Pure-jnp oracle for the fused membership-scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pack_bool_u32(bits: jnp.ndarray) -> jnp.ndarray:
    """(.., D) bool -> (.., D//32) uint32, little-endian bit order. D % 32 == 0."""
    *lead, d = bits.shape
    b = bits.reshape(*lead, d // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (b * weights).sum(axis=-1).astype(jnp.uint32)


def membership_bitmask_ref(
    q_embed: jnp.ndarray,  # (Q, E) float — query-term embeddings
    d_embed: jnp.ndarray,  # (D, E) float — doc embeddings
    tau: jnp.ndarray,  # (Q,) float — per-term thresholds
    bias: jnp.ndarray,  # () float
) -> jnp.ndarray:
    """Returns (Q, D//32) uint32 packed hit-mask: bit set iff logit >= tau."""
    logits = q_embed.astype(jnp.float32) @ d_embed.astype(jnp.float32).T + bias
    hits = logits >= tau[:, None]
    return pack_bool_u32(hits)


def membership_logits_ref(q_embed, d_embed, bias):
    return q_embed.astype(jnp.float32) @ d_embed.astype(jnp.float32).T + bias
