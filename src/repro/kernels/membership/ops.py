"""Public wrapper: pads ragged (Q, D) to kernel tiles, gathers embeddings.

`score_terms_bitmask` is the drop-in accelerated path for Algorithm 1/3
document scans: term ids + doc-embedding table -> packed hit bitmask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.membership.kernel import D_BLK, LANE, Q_BLK, membership_bitmask


def _pad_to(x: jax.Array, m: int, axis: int, value=0) -> jax.Array:
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def score_terms_bitmask(
    params,
    terms: jax.Array,  # (Q,) int32 term ids
    tau: jax.Array,  # (n_terms,) thresholds
    *,
    interpret: bool = True,
) -> jax.Array:
    """(Q,) term ids -> (Q, ceil(D/32)) packed membership bitmask."""
    te = jnp.take(params["term_embed"]["table"], terms, axis=0)
    de = params["doc_embed"]["table"]
    tq = jnp.take(tau, terms)
    n_docs = de.shape[0]
    teq = _pad_to(te, Q_BLK, 0)
    # padded tau rows = +inf so padding never fires
    tqq = _pad_to(tq, Q_BLK, 0, value=jnp.inf)
    dep = _pad_to(de, D_BLK, 0)
    mask = membership_bitmask(teq, dep, tqq, params["bias"], interpret=interpret)
    out_words = -(-n_docs // LANE)
    mask = mask[: terms.shape[0], :out_words]
    # zero the tail bits of the final word (padded docs)
    tail = n_docs % LANE
    if tail:
        last = jnp.uint32((1 << tail) - 1)
        word_mask = jnp.where(
            jnp.arange(out_words) == out_words - 1, last, jnp.uint32(0xFFFFFFFF)
        )
        mask = mask & word_mask[None, :]
    return mask
