"""Pure-jnp oracle for batched ε-window membership/rank probes.

One batch row = one (term, candidate) probe: the candidate's rank bracket
[r_lo, r_lo + n_valid) inside one model segment, with the window's
corrections already unpacked.  Decode uses the canonical single-multiply
float32 + banker's-rint formula of repro.postings.plm.eval_segments, so the
window ids — and therefore the probe verdicts — are bit-identical to the
host decode path and to the Pallas kernel.

Outputs per probe: found (1 iff candidate present) and lt (#window ids
strictly below the candidate; host adds r_lo to get the global rank).
"""
from __future__ import annotations

import jax.numpy as jnp


def probe_ref(
    seg_starts: jnp.ndarray,  # (P, 1) int32 rank of the segment's first posting
    bases: jnp.ndarray,  # (P, 1) int32 integer intercept
    slopes: jnp.ndarray,  # (P, 1) float32
    r_lo: jnp.ndarray,  # (P, 1) int32 first rank of the probe window
    n_valid: jnp.ndarray,  # (P, 1) int32 window length (may be 0)
    cands: jnp.ndarray,  # (P, 1) int32 candidate doc ids
    corr: jnp.ndarray,  # (P, W) int32 window corrections
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """-> (found (P,1) int32, lt (P,1) int32)."""
    W = corr.shape[1]
    j = jnp.arange(W, dtype=jnp.int32)[None, :]
    ranks = r_lo + j
    di = (ranks - seg_starts).astype(jnp.float32)
    pred = bases + jnp.rint(slopes * di).astype(jnp.int32)
    ids = pred + corr
    valid = j < n_valid
    found = (valid & (ids == cands)).any(axis=1, keepdims=True).astype(jnp.int32)
    lt = (valid & (ids < cands)).sum(axis=1, keepdims=True).astype(jnp.int32)
    return found, lt
