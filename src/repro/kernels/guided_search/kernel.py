"""Batched ε-window probes as a Pallas kernel.

The guided-intersection hot path issues many independent (term, candidate)
probes per verification round; each is a tiny decode (one segment line over a
±ε rank window) + compare + count.  Batched, that is one fused VPU pass over
a (B_BLK, W) tile: evaluate the line, add corrections, compare against the
candidate, reduce to found/lt per row — the probe analogue of the
plm_decode full-list kernel, with the same single-multiply float32 + rint
formula so verdicts are bit-exact against the jnp reference and host numpy.

Per-probe scalars arrive as (P, 1) columns; W is the padded window length
(host pads to a multiple of 128 lanes).  Invalid lanes (j >= n_valid) are
masked out of both reductions, so empty windows yield found=0, lt=0.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_BLK = 8  # probes per grid step


def _kernel(seg_ref, base_ref, slope_ref, rlo_ref, nval_ref, cand_ref, corr_ref,
            found_ref, lt_ref):
    W = corr_ref.shape[1]
    j = jax.lax.broadcasted_iota(jnp.int32, (corr_ref.shape[0], W), 1)
    ranks = rlo_ref[...] + j
    di = (ranks - seg_ref[...]).astype(jnp.float32)
    pred = base_ref[...] + jnp.rint(slope_ref[...] * di).astype(jnp.int32)
    ids = pred + corr_ref[...]
    valid = j < nval_ref[...]
    eq = valid & (ids == cand_ref[...])
    lt = valid & (ids < cand_ref[...])
    found_ref[...] = eq.any(axis=1, keepdims=True).astype(jnp.int32)
    lt_ref[...] = lt.sum(axis=1, keepdims=True).astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def probe_batch(
    seg_starts: jax.Array,  # (P, 1) int32
    bases: jax.Array,  # (P, 1) int32
    slopes: jax.Array,  # (P, 1) float32
    r_lo: jax.Array,  # (P, 1) int32
    n_valid: jax.Array,  # (P, 1) int32
    cands: jax.Array,  # (P, 1) int32
    corr: jax.Array,  # (P, W) int32
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Probe P windows -> (found (P,1) int32, lt (P,1) int32)."""
    P, W = corr.shape
    pad = (-P) % B_BLK
    scalars = [seg_starts, bases, slopes, r_lo, n_valid, cands]
    if pad:
        scalars = [jnp.pad(a, ((0, pad), (0, 0))) for a in scalars]
        corr = jnp.pad(corr, ((0, pad), (0, 0)))
    col_spec = pl.BlockSpec((B_BLK, 1), lambda i: (i, 0))
    win_spec = pl.BlockSpec((B_BLK, W), lambda i: (i, 0))
    found, lt = pl.pallas_call(
        _kernel,
        grid=((P + pad) // B_BLK,),
        in_specs=[col_spec] * 6 + [win_spec],
        out_specs=[col_spec, col_spec],
        out_shape=[
            jax.ShapeDtypeStruct((P + pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((P + pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*scalars, corr)
    return found[:P], lt[:P]
