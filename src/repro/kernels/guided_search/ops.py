"""Host bridge: TermModel + candidates -> padded probe windows -> kernel.

Computes the exact rank brackets on the host (repro.postings.search), gathers
each window's packed corrections with a scattered unpack (only the touched
stream words are read — the count is returned for byte accounting), pads the
window axis to a multiple of 128 lanes and the probe axis to the kernel
block, and launches one probe_batch call for the whole candidate set.

Two guards keep the dense (P, W) layout sane:
  * probes whose bracket exceeds MAX_W ranks (degenerate/low-slope segments
    scan whole segments) are answered on the host instead of inflating every
    row's padding to the outlier's width;
  * P and W are rounded up to power-of-two-ish buckets so jax.jit compiles
    a handful of shapes instead of one per candidate-set size.

The term model's segment tables (starts/bases/slopes) are index-derived and
live as long as the store, so they ride the device-residency cache
(kernels.arena.resident): uploaded once per model per process, gathered by
segment id *on device* per dispatch — the host boundary only carries the
query-dependent arrays (segment column, brackets, corrections).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.arena import resident
from repro.kernels.guided_search.kernel import probe_batch
from repro.obs import trace

_LANES = 128
MAX_W = 1024  # widest window the kernel pads to; wider brackets go to host


def _bucket(n: int, quantum: int) -> int:
    """Round n up to quantum * 2^k — bounds the number of jit shapes."""
    b = quantum
    while b < n:
        b *= 2
    return b


def probe_windows(
    tm, cands: np.ndarray, *, interpret: bool = True
) -> tuple[np.ndarray, np.ndarray, int]:
    """Batched guided probes of one term -> (found bool, rank int64, bytes).

    `tm` is a repro.postings.search.TermModel; `bytes` counts the packed
    correction stream bytes the windows touched (metadata is accounted by
    the caller at model-load time).
    """
    from repro.postings.search import _touched_words, decode_window, flatten_windows
    from repro.index.compress import unpack_bits_at

    d = np.asarray(cands, np.int64)
    P = len(d)
    seg, r_lo, lens, probe_of, col, flat_ranks = flatten_windows(tm, d)
    if len(flat_ranks) == 0:
        return np.zeros(P, bool), r_lo.copy(), 0
    touched = 4 * _touched_words(flat_ranks, tm.width)
    found = np.zeros(P, bool)
    rank = r_lo.copy()

    wide = lens > MAX_W
    if wide.any():  # outlier brackets: host-decode, don't widen the batch
        in_wide = wide[probe_of]
        ids = decode_window(tm, seg[probe_of[in_wide]], flat_ranks[in_wide])
        dw = d[probe_of[in_wide]]
        np.logical_or.at(found, probe_of[in_wide], ids == dw)
        np.add.at(rank, probe_of[in_wide], (ids < dw).astype(np.int64))
        keep = ~in_wide
        probe_of, col, flat_ranks = probe_of[keep], col[keep], flat_ranks[keep]
        lens = np.where(wide, 0, lens)
        if len(flat_ranks) == 0:
            return found, rank, touched

    W = _bucket(int(lens.max()), _LANES)
    Pb = _bucket(P, 8)
    corr_vals = unpack_bits_at(tm.corr_words, tm.width, flat_ranks).astype(np.int64)
    corr = np.zeros((Pb, W), np.int32)
    corr[probe_of, col] = (corr_vals + tm.corr_min).astype(np.int32)

    def colv(a, dtype):
        out = np.zeros(Pb, dtype)
        out[:P] = np.asarray(a, dtype)
        return jnp.asarray(out.reshape(Pb, 1))

    # resident segment tables, gathered on device by the padded seg column
    # (pad rows gather segment 0; their lens column is 0, so the kernel
    # never reads the gathered values)
    segd = colv(seg, np.int64)
    with trace.span("kernel.guided_search", probes=int(Pb), window=int(W),
                    bytes=int(touched)):
        kf, lt = probe_batch(
            jnp.take(resident(tm.starts), segd, axis=0).astype(jnp.int32),
            jnp.take(resident(tm.bases), segd, axis=0).astype(jnp.int32),
            jnp.take(resident(tm.slopes), segd, axis=0).astype(jnp.float32),
            colv(r_lo, np.int32),
            colv(lens, np.int32),
            colv(d, np.int32),
            jnp.asarray(corr),
            interpret=interpret,
        )
    kf = np.asarray(kf).reshape(-1)[:P].astype(bool)
    lt = np.asarray(lt).reshape(-1)[:P].astype(np.int64)
    narrow = lens > 0
    found[narrow] |= kf[narrow]
    rank[narrow] += lt[narrow]
    return found, rank, touched
