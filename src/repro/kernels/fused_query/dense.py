"""On-device ranked scoring loop: one jitted dispatch per batch bucket.

The multi-phase path keeps θ on the host: every peel round decodes a term,
merges, re-partitions — N host<->device round trips per batch whose Python
bookkeeping the profiler shows dominating the fused wall clock.  This module
collapses the whole scoring loop into **one** jitted callable over the
shard's resident ``DeviceArena`` (kernels.arena):

  1. gather — each query row gathers its T term rows from the resident
     (n_terms + 1, n_docs) impact table (padded slots hit the all-zero pad
     row) and sums over the term axis into a (Q, n_docs) int32 accumulator.
     This replaces the COO lane expansion + scatter-add formulation, which
     XLA:CPU serializes at ~70 ns/posting — the gather+sum is a contiguous
     streaming read of T rows per query;
  2. θ-peel — a ``lax.while_loop`` peels the top-k rounds on device: per
     round one masked argmax per row (ties resolve to the smaller doc id,
     the oracle's order), the peeled cell zeroed in place, rounds stopping
     early once no row can still beat its floor.  The loop's round counter
     comes back to the host so accounting can charge the accumulator scans
     actually performed.

Exactness: the dense sum over term rows equals the host merge's posting
sums (integer adds, order-free), per-row floors mask exactly
``score > max(floor, 0)`` (the ``select_topk`` rule), and the argmax tie
discipline matches the oracle's (score desc, id asc) — so results are
bit-identical to the multi-phase engine and the brute-force oracle, which
tests and benchmarks assert.

Shapes are padded to power-of-two buckets — (rows, term slots, k) — so jit
compiles a handful of specializations; ``observed_shapes()`` /
``warm_shape()`` let the scheduler snapshot and restore exactly the
compiled set across worker restarts (``cache_size()`` proves re-jit-free).
The row/term bucket quanta are the dense path's autotuned tile knobs
(kernels.autotune).
"""
from __future__ import annotations

import numpy as np

NEVER = 1 << 30  # empty heap-slot sentinel (matches kernel.NEVER)

# the peel loop costs one (Q, n_docs) scan per round: past this k the
# bucketed kernel path wins, so the bridge routes large-k items there
DENSE_MAX_K = 32

# shape-bucket quanta: power-of-two multiples bound the jit shape count;
# the autotuner (kernels.autotune) may retune these per device kind
_ROW_QUANTUM = 8
_TERM_QUANTUM = 4

# static shapes this process has dispatched: (n_docs, Q, T, k)
_SHAPES: set[tuple[int, int, int, int]] = set()


def tile_params() -> dict[str, int]:
    return {"row_quantum": _ROW_QUANTUM, "term_quantum": _TERM_QUANTUM}


def set_tile_params(row_quantum: int | None = None, term_quantum: int | None = None) -> None:
    global _ROW_QUANTUM, _TERM_QUANTUM
    if row_quantum is not None:
        _ROW_QUANTUM = max(1, int(row_quantum))
    if term_quantum is not None:
        _TERM_QUANTUM = max(1, int(term_quantum))


def _dense_impl(table, qt, floors, *, k: int):
    import jax
    import jax.numpy as jnp

    Q, T = qt.shape
    n_pad = table.shape[0] - 1  # all-zero pad row
    t = jnp.where(qt >= 0, qt, n_pad)
    scores = table[t].astype(jnp.int32).sum(axis=1)  # (Q, n_docs)

    fl = jnp.maximum(floors, 0)[:, None]  # (Q, 1): select_topk's > floor rule
    rows_iota = jnp.arange(Q)
    out_i = jnp.full((Q, k), NEVER, jnp.int32)
    out_s = jnp.zeros((Q, k), jnp.int32)

    def cond(carry):
        j, go, *_ = carry
        return (j < k) & go

    def body(carry):
        j, _, scores, out_i, out_s = carry
        elig = jnp.where(scores > fl, scores, 0)
        best = jnp.argmax(elig, axis=1).astype(jnp.int32)  # first max: min id
        val = jnp.take_along_axis(elig, best[:, None], axis=1)[:, 0]
        hit = val > 0
        out_i = out_i.at[:, j].set(jnp.where(hit, best, NEVER))
        out_s = out_s.at[:, j].set(jnp.where(hit, val, 0))
        # zero the peeled cell in place; a missed row zeroes an ineligible
        # cell (best = 0 with every score <= floor), which changes nothing
        scores = scores.at[rows_iota, best].set(0)
        return j + 1, hit.any(), scores, out_i, out_s

    j, _, scores, out_i, out_s = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.bool_(True), scores, out_i, out_s)
    )
    return out_i, out_s, j


_JITTED = None


def _jitted():
    global _JITTED
    if _JITTED is None:
        import jax

        _JITTED = jax.jit(_dense_impl, static_argnames=("k",))
    return _JITTED


def dense_topk(arena, qt: np.ndarray, floors: np.ndarray, *, k: int):
    """One device dispatch: (Q, T) padded term rows -> (Q, k) ids/scores.

    ``arena`` is the shard's DeviceArena (resident buffers — nothing index-
    derived is staged here); ``qt`` is -1-padded term ids, ``floors`` the
    per-row strict score floors.  Returns device arrays (ids, scores,
    rounds) — callers block when they materialize, which is where the
    pipelined bridge defers to.
    """
    import jax.numpy as jnp

    Q, T = qt.shape
    _SHAPES.add((arena.n_docs, Q, T, int(k)))
    arena.counters.hits += 1
    return _jitted()(
        arena.table, jnp.asarray(qt), jnp.asarray(floors), k=int(k)
    )


def cache_size() -> int:
    """Compiled-specialization count (re-jit-free assertions in tests)."""
    return int(_jitted()._cache_size()) if _JITTED is not None else 0


def observed_shapes() -> list[tuple[int, int, int, int]]:
    """Static shapes dispatched by this process — the warm-snapshot payload."""
    return sorted(_SHAPES)


def warm_shape(arena, shape) -> None:
    """Pre-compile one observed shape against ``arena`` with inert inputs.

    Compilation keys on static shapes only, so an all-pad term matrix
    compiles the exact executable real traffic will hit.
    """
    n_docs, Q, T, k = (int(x) for x in shape)
    if n_docs != arena.n_docs:
        return
    qt = np.full((Q, T), -1, np.int32)
    floors = np.zeros(Q, np.int32)
    out = dense_topk(arena, qt, floors, k=k)
    import jax

    jax.block_until_ready(out)
