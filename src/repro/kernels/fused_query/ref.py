"""Pure-numpy oracle for the fused ranked-query kernel.

Mirrors kernel._make_kernel lane for lane on the same padded arrays: segment
line in float32 with a single multiply + rint, word-pair shift/or/mask
unpack for corrections and payloads, floor mask, then K peeled argmax
rounds.  Used by the tests for kernel-vs-ref bit identity and by ops.py as
the use_kernel=False host path.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.fused_query.kernel import NEVER  # noqa: F401  (shared sentinel)


def _unpack(lo, hi, shift, mask):
    up = np.where(shift > 0, hi.astype(np.uint64) << (32 - shift), 0)
    return ((lo.astype(np.uint64) >> shift) | up) & mask


def fused_topk_ref(width, cmin, rlo, wlen, start, base, slope, clo, chi, plo,
                   phi, cand, part, floor, *, k: int, pbits: int):
    """(Q, T, C, W) probe tiles -> (Q, k) top-k ids + scores, numpy."""
    Q, T, C, W = clo.shape
    j = np.arange(W, dtype=np.int64)[None, None, None, :]
    ranks = rlo[..., None].astype(np.int64) + j
    di = (ranks - start[..., None]).astype(np.float32)
    pred = base[..., None].astype(np.int64) + np.rint(
        slope[..., None].astype(np.float32) * di
    ).astype(np.int64)
    w = width.astype(np.uint64)[:, :, None, None]
    cmask = (np.uint64(1) << w) - np.uint64(1)
    cshift = (ranks.astype(np.uint64) * w) % np.uint64(32)
    corr = _unpack(clo, chi, cshift, cmask).astype(np.int64)
    ids = pred + corr + cmin[:, :, None, None].astype(np.int64)
    valid = j < wlen[..., None]
    eq = valid & (ids == cand[:, None, :, None].astype(np.int64))
    pshift = (ranks.astype(np.uint64) * np.uint64(pbits)) % np.uint64(32)
    pmask = np.uint64((1 << pbits) - 1)
    imp = _unpack(plo, phi, pshift, pmask).astype(np.int64)
    score = part.astype(np.int64) + np.where(eq, imp, 0).sum(axis=3).sum(axis=1)
    alive = np.where(score > floor.astype(np.int64), score, 0)
    out_ids = np.full((Q, k), -1, np.int32)
    out_scores = np.zeros((Q, k), np.int32)
    cand64 = cand.astype(np.int64)
    for i in range(k):
        best = np.argmax(alive, axis=1)
        val = alive[np.arange(Q), best]
        hit = val > 0
        out_ids[hit, i] = cand64[np.arange(Q), best][hit].astype(np.int32)
        out_scores[hit, i] = val[hit].astype(np.int32)
        alive[np.arange(Q), best] = 0
    return out_ids, out_scores
