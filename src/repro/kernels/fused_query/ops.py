"""Host bridge: a ranked batch -> MaxScore peel -> fused kernel dispatches.

``fused_topk_batch`` answers a whole shard batch of ranked queries with one
Pallas dispatch per candidate-size bucket.  Per item it first mirrors ``rank.topk.topk_query``'s
host phases *exactly* — required-term conjunctive seeding, the essential-term
peel (terms by descending upper bound, merged while an unseen document could
still reach the running threshold θ), and the exhaustive-cutoff shortcut —
because those phases are sequential by nature (θ tightens after every
decode).  What remains per item is the probe tail: surviving candidates ×
non-essential terms.  The multi-phase path walks that tail as hundreds of
tiny host<->device round trips (ε-window probe, correction unpack, payload
unpack, impact add, host select per term); here the tail of *every* item in
the batch becomes lanes of one (query, term, candidate, window) tile and a
``fused_topk`` dispatch per bucket returns each query's final top-k.

Exactness: candidates are dropped only when
``partial + Σ_tail seg_ub < max(floor + 1, θ)`` — θ is the kth largest
partial, so at least k candidates finish >= θ and nothing below the bound can
enter the top-k; ties at the bound are kept.  Survivors get *complete*
scores in-kernel (every tail term probed), so the final selection is the
oracle's — bit-identical to the multi-phase path, which the tests and
benchmarks assert.

Tail lanes come in two flavours:
  * learned-codec terms with a narrow rank bracket -> real ε-window lanes
    (the kernel re-runs guided search + in-register unpack);
  * classical-codec terms, width >= 32, or brackets wider than W_CAP ->
    resolved on the host (binary search / window decode) into a 1-lane
    window whose segment line reproduces the known doc id, with the payload
    words still unpacked in-register at the found rank.

Axes are padded to power-of-two buckets (rows to the kernel block,
candidates to 128·2^k, windows to 2^k) so jax.jit compiles a handful of
shapes — the same recompile-convoy discipline as the boolean path, which
``Session.warm()`` pre-triggers.  Candidate counts are heavy-tailed, so rows
are *grouped* by candidate bucket, one dispatch per populated bucket: a
handful of dispatches per batch instead of one maximally-padded tile (or
hundreds of multi-phase host hops).

When the shard carries a resident ``DeviceArena`` (kernels.arena), items
without required terms and with k <= DENSE_MAX_K skip the host peel
entirely: the whole scoring loop — gather, accumulate, θ-peel — runs as
**one** jitted dispatch over the resident impact table
(kernels.fused_query.dense), the host contributing only the (Q, T) term-id
tile.  Dispatches are *pipelined*: dense groups launch first and stay in
flight while the host peels and packs the legacy items, and their outputs
are materialized only at merge time — host plan/pack of the next group
overlaps device execution of the previous one.  ``RankedStats`` splits the
wall into ``fused_kernel_ns`` (blocked on device) and ``fused_bridge_ns``
(host bridge) so the roofline measures the kernel, not Python.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.kernels.fused_query.dense import DENSE_MAX_K

from repro.kernels.fused_query.kernel import B_BLK, NEVER, fused_topk
from repro.kernels.fused_query.ref import fused_topk_ref
from repro.obs import trace
from repro.rank.score import TopKResult, select_topk
from repro.rank.topk import _EMPTY, _exhaustive, _kth_partial, _merge_add

_CANDQ = 128  # candidate-axis bucket quantum
W_CAP = 32  # widest ε-window shipped to the kernel; wider lanes resolve on host


def _bucket(n: int, quantum: int) -> int:
    """Round n up to quantum * 2^k — bounds the number of jit shapes."""
    b = quantum
    while b < n:
        b *= 2
    return b


@dataclass
class _Pending:
    """One item's kernel-bound remainder after the host peel."""

    cands: np.ndarray  # (C,) int64 surviving candidates, ascending
    partial: np.ndarray  # (C,) int64 partial scores from essential terms
    tail: list  # non-essential term ids, descending upper bound
    k: int
    floor: int


def _peel(src, terms, k, required, floor, cutoff, stats):
    """topk_query's host phases, stopping where the probe tail begins.

    Returns a finished TopKResult when the item never reaches the tail
    (trivial/exhaustive/fully-peeled), else a _Pending for the kernel.
    """
    if k <= 0:
        return _EMPTY
    stats.queries += 1
    terms = sorted({int(t) for t in terms if src.n(int(t)) > 0})
    req_all = {int(r) for r in required}
    req = [t for t in sorted(req_all) if src.n(t) > 0]
    if len(req) < len(req_all):
        return _EMPTY  # a required term absent on this shard: empty AND
    if not terms:
        return _EMPTY
    stats.exhaustive_postings += sum(src.n(t) for t in terms)

    if not req and sum(src.n(t) for t in terms) <= cutoff:
        stats.exhaustive_queries += 1
        return _exhaustive(src, terms, k, floor, stats, None)

    optional = [t for t in terms if t not in set(req)]
    if req:
        req = sorted(req, key=src.n)
        cands, partial = src.full(req[0])
        partial = partial.astype(np.int64)
        stats.scored_postings += len(cands)
        for t in req[1:]:
            if len(cands) == 0:
                return _EMPTY
            found, q = src.probe(t, cands)
            stats.probed_postings += len(cands)
            cands, partial = cands[found], partial[found] + q[found]
        if len(cands) == 0:
            return _EMPTY
        accepting_new = False
    else:
        cands = np.zeros(0, np.int32)
        partial = np.zeros(0, np.int64)
        accepting_new = True

    optional.sort(key=lambda t: (-src.ub(t), t))
    ubs = np.array([src.ub(t) for t in optional], np.int64)
    suffix = np.concatenate([np.cumsum(ubs[::-1])[::-1], [0]])
    theta = _kth_partial(partial, k)
    j = 0
    while j < len(optional):
        if not (accepting_new and suffix[j] >= max(floor + 1, theta)):
            break
        ids, q = src.full(optional[j])
        stats.scored_postings += len(ids)
        cands, partial = _merge_add(cands, partial, ids, q)
        theta = max(theta, _kth_partial(partial, k))
        j += 1
    tail = optional[j:]
    if not tail or len(cands) == 0:
        return select_topk(cands, partial, k, floor)

    # joint candidate prune at segment granularity: everything below cannot
    # reach the threshold even if every tail term pays its block max
    alive_min = max(floor + 1, theta)
    bound = partial.copy()
    for t in tail:
        bound += src.seg_ub(t, np.asarray(cands, np.int64)).astype(np.int64)
    keep = bound >= alive_min
    cands, partial = cands[keep], partial[keep]
    if len(cands) == 0:
        return select_topk(cands, partial, k, floor)
    stats.probed_postings += len(cands) * len(tail)
    return _Pending(np.asarray(cands, np.int64), partial, tail, k, floor)


def _window_ranks(rlo, wlen):
    """Flatten per-candidate [rlo, rlo+wlen) brackets into one rank vector."""
    lens = np.asarray(wlen, np.int64)
    if lens.max(initial=0) <= 1:  # the common case: every window resolved
        return np.asarray(rlo, np.int64)
    first = np.repeat(np.cumsum(lens) - lens, lens)
    return np.repeat(rlo, lens) + np.arange(len(first), dtype=np.int64) - first


def _gather_words(stream, word_idx, use):
    """Lo/hi packed-word pairs at word_idx where use, 0 elsewhere/out-of-range
    — the host half of the kernel's unpack_bits_at replication."""
    s = np.asarray(stream, np.uint32)
    lo = np.zeros(word_idx.shape, np.uint32)
    hi = np.zeros(word_idx.shape, np.uint32)
    n = len(s)
    if n and use.any():
        wi = np.clip(word_idx, 0, n - 1)
        lo[use] = s[wi[use]]
        nxt = use & (word_idx + 1 < n)
        hi[nxt] = s[(wi + 1)[nxt]]
    return lo, hi


def _term_lanes(src, t, cands, pbits):
    """One (item, tail-term) slot -> per-candidate window lanes + streams.

    Returns (rlo, wlen, start, base, slope, width, cmin, corr_words,
    use_corr, stream_bytes); resolved lanes carry use_corr=False and a
    segment line that reproduces the known doc id exactly.
    """
    from repro.postings.search import _touched_words, decode_window, rank_windows

    C = len(cands)
    rlo = np.zeros(C, np.int64)
    wlen = np.zeros(C, np.int64)
    start = np.zeros(C, np.int64)
    base = np.zeros(C, np.int64)
    slope = np.zeros(C, np.float32)
    use_corr = np.zeros(C, bool)
    tm = src.term_model(t)
    stream_bytes = 0

    if tm is not None and 0 < tm.width < 32:
        width, cmin, corr_words = int(tm.width), int(tm.corr_min), tm.corr_words
        seg, r_lo, r_hi = rank_windows(tm, cands)
        lens = np.maximum(r_hi - r_lo + 1, 0)
        wide = lens > W_CAP
        narrow = ~wide & (lens > 0)
        rlo[narrow] = r_lo[narrow]
        wlen[narrow] = lens[narrow]
        start[narrow] = tm.starts[seg[narrow]]
        base[narrow] = tm.bases[seg[narrow]]
        slope[narrow] = tm.slopes[seg[narrow]]
        use_corr[narrow] = True
        if narrow.any():
            # touched correction words of every narrow lane, for the roofline
            stream_bytes += 4 * _touched_words(
                _window_ranks(rlo[narrow], wlen[narrow]), width
            )
        if wide.any():  # outlier brackets: host-decode, don't widen the batch
            widx = np.nonzero(wide)[0]
            lens_w = lens[widx].astype(np.int64)
            probe_of = np.repeat(widx, lens_w)
            loc = np.repeat(np.arange(len(widx)), lens_w)
            first = np.repeat(np.cumsum(lens_w) - lens_w, lens_w)
            fl_ranks = r_lo[probe_of] + (np.arange(len(probe_of)) - first)
            ids_dec = decode_window(tm, seg[probe_of], fl_ranks)
            dw = cands[probe_of]
            eqc = np.bincount(loc, weights=(ids_dec == dw), minlength=len(widx))
            ltc = np.bincount(loc, weights=(ids_dec < dw), minlength=len(widx))
            stream_bytes += 4 * _touched_words(fl_ranks, width)
            hit = eqc > 0
            h = widx[hit]
            rlo[h] = (r_lo[widx] + ltc.astype(np.int64))[hit]
            wlen[h] = 1
            base[h] = cands[h] - cmin  # line reproduces the id; corr zeroed
    else:
        # classical codec (or width >= 32): rank by binary search in the
        # cached decode; a found candidate becomes a 1-lane resolved window
        width, cmin, corr_words = 0, 0, np.zeros(0, np.uint32)
        p = src.postings(t)
        rank = np.searchsorted(p, cands).astype(np.int64)
        found = (rank < len(p)) & (p[np.minimum(rank, max(len(p) - 1, 0))] == cands)
        rlo[found] = rank[found]
        wlen[found] = 1
        base[found] = cands[found]

    valid = wlen > 0
    if valid.any():
        stream_bytes += 4 * _touched_words(_window_ranks(rlo[valid], wlen[valid]), pbits)
    return rlo, wlen, start, base, slope, width, cmin, corr_words, use_corr, stream_bytes


def fused_topk_batch(
    src,
    items,
    *,
    exhaustive_cutoff: int = 2048,
    stats=None,
    use_kernel: bool = True,
    interpret: bool = True,
):
    """Answer [(terms, k, required, floor), ...] with fused dispatches.

    ``src`` is a shard _RankedSource (needs the RankedSource protocol plus
    term_model/postings/payload_words/payload_bits).  Returns one TopKResult
    per item, in *local* doc ids, bit-identical to looping topk_query.
    """
    from repro.rank.topk import RankedStats

    stats = stats if stats is not None else RankedStats()
    t_all0 = time.perf_counter_ns()
    kernel_ns0 = stats.fused_kernel_ns
    results: list = [None] * len(items)

    # split: items a resident arena can answer in one dense dispatch (no
    # required terms, peelable k) never touch the host peel at all
    arena = getattr(src, "arena", None) if use_kernel else None
    dense_items: list[tuple[int, list[int], int, int]] = []
    legacy: list[int] = []
    for i, (terms, k, required, floor) in enumerate(items):
        if arena is None or len(required) or not (0 < k <= DENSE_MAX_K):
            legacy.append(i)
            continue
        stats.queries += 1
        tt = sorted({int(t) for t in terms if src.n(int(t)) > 0})
        if not tt:
            results[i] = _EMPTY
            continue
        n_sum = sum(src.n(t) for t in tt)
        stats.exhaustive_postings += n_sum
        stats.scored_postings += n_sum
        stats.exhaustive_queries += 1
        dense_items.append((i, tt, int(k), int(floor)))

    # pipelined dispatch: dense groups launch first and stay in flight on
    # the device while the host peels and packs the legacy items below
    inflight = _dispatch_dense(arena, dense_items, stats) if dense_items else []

    pend: list[tuple[int, _Pending]] = []
    for i in legacy:
        terms, k, required, floor = items[i]
        r = _peel(src, terms, k, required, floor, exhaustive_cutoff, stats)
        if isinstance(r, _Pending):
            pend.append((i, r))
        else:
            results[i] = r

    if pend:
        # Candidate counts are heavy-tailed (median ~100, max = shard size):
        # a single dense C = max(C_i) tile would make every query pay the
        # widest query's candidate axis.  Group rows by power-of-two
        # candidate bucket instead — one dispatch per populated bucket (a
        # handful per batch, vs hundreds of per-term hops on the multi-phase
        # path), each with a tight (T, C, W) tile for its rows.
        pbits = int(src.payload_bits)
        groups: dict[int, list[tuple[int, _Pending]]] = {}
        for i, p in pend:
            groups.setdefault(_bucket(len(p.cands), _CANDQ), []).append((i, p))
        for C, grp in sorted(groups.items()):
            _dispatch_group(
                src, grp, C, pbits, stats, results,
                use_kernel=use_kernel, interpret=interpret,
            )

    # deferred merge: only now block on the in-flight dense outputs
    for fut in inflight:
        _extract_dense(fut, stats, results)
    stats.fused_bridge_ns += max(
        0, (time.perf_counter_ns() - t_all0) - (stats.fused_kernel_ns - kernel_ns0)
    )
    return results


def _dispatch_dense(arena, dense_items, stats):
    """Dense-eligible items -> one resident-arena dispatch per (k) bucket.

    Returns in-flight handles (device arrays still executing); the caller
    materializes them at merge time — that deferral is the pipeline.
    """
    from repro.kernels.fused_query import dense

    tp = dense.tile_params()
    groups: dict[int, list] = {}
    for it in dense_items:
        groups.setdefault(_bucket(it[2], 1), []).append(it)
    inflight = []
    for kb, grp in sorted(groups.items()):
        Qb = _bucket(len(grp), tp["row_quantum"])
        T = _bucket(max(len(tt) for _, tt, _, _ in grp), tp["term_quantum"])
        qt = np.full((Qb, T), -1, np.int32)
        floors = np.zeros(Qb, np.int32)
        for row, (_, tt, _, fl) in enumerate(grp):
            qt[row, : len(tt)] = tt
            floors[row] = fl
        stats.fused_queries += len(grp)
        stats.fused_lanes += sum(arena.lanes(tt) for _, tt, _, _ in grp)
        # stream traffic: the table rows each live term slot gathers
        stats.fused_stream_bytes += (
            sum(len(tt) for _, tt, _, _ in grp) * arena.n_docs * arena.itemsize
        )
        out = dense.dense_topk(arena, qt, floors, k=kb)
        inflight.append((arena, grp, kb, Qb, T, out))
    return inflight


def _extract_dense(fut, stats, results):
    """Materialize one in-flight dense dispatch and merge its rows."""
    arena, grp, kb, Qb, T, out = fut
    n_docs, isz = arena.n_docs, arena.itemsize
    with trace.span("kernel.fused_query", queries=int(Qb), terms=int(T),
                    k=int(kb), dense=1, candidates=int(n_docs)):
        t0 = time.perf_counter_ns()
        ids_o, sc_o, rounds = (np.asarray(x) for x in out)
        stats.fused_kernel_ns += time.perf_counter_ns() - t0
    # device traffic actually performed: table-row gather, accumulator,
    # one accumulator scan per peel round performed, in/out tiles
    stats.fused_device_bytes += (
        Qb * T * n_docs * isz
        + Qb * n_docs * 4
        + int(rounds) * Qb * n_docs * 4
        + Qb * T * 4 + Qb * 4
        + 2 * Qb * kb * 4
    )
    for row, (i, _tt, k, _fl) in enumerate(grp):
        hit = sc_o[row] > 0  # non-empty heap slots form a prefix
        results[i] = TopKResult(
            ids=ids_o[row][hit][:k].astype(np.int32),
            scores=sc_o[row][hit][:k].astype(np.int64),
        )


def _dispatch_group(src, pend, C, pbits, stats, results, *, use_kernel, interpret):
    """One candidate-bucket group -> one fused kernel dispatch."""
    T = max(len(p.tail) for _, p in pend)
    K = min(max(p.k for _, p in pend), C)
    Qb = _bucket(len(pend), B_BLK)

    lanes = []  # (row, slot, C_i, lane data) from the host window builder
    Wmax, stream_bytes = 1, 0
    for row, (_, p) in enumerate(pend):
        for slot, t in enumerate(p.tail):
            ln = _term_lanes(src, t, p.cands, pbits)
            Wmax = max(Wmax, int(ln[1].max()) if len(ln[1]) else 1)
            stream_bytes += ln[9]
            lanes.append((row, slot, t, len(p.cands), ln))
    W = _bucket(Wmax, 1)  # power of two from 1: most windows resolve to 1 lane

    width_a = np.zeros((Qb, T), np.uint32)
    cmin_a = np.zeros((Qb, T), np.int32)
    rlo_a = np.zeros((Qb, T, C), np.int32)
    wlen_a = np.zeros((Qb, T, C), np.int32)
    start_a = np.zeros((Qb, T, C), np.int32)
    base_a = np.zeros((Qb, T, C), np.int32)
    slope_a = np.zeros((Qb, T, C), np.float32)
    clo_a = np.zeros((Qb, T, C, W), np.uint32)
    chi_a = np.zeros((Qb, T, C, W), np.uint32)
    plo_a = np.zeros((Qb, T, C, W), np.uint32)
    phi_a = np.zeros((Qb, T, C, W), np.uint32)
    cand_a = np.full((Qb, C), NEVER, np.int32)
    part_a = np.zeros((Qb, C), np.int32)
    floor_a = np.zeros((Qb, 1), np.int32)

    for row, (_, p) in enumerate(pend):
        n = len(p.cands)
        cand_a[row, :n] = p.cands
        part_a[row, :n] = p.partial
        floor_a[row, 0] = p.floor
    jw = np.arange(W, dtype=np.int64)
    for row, slot, t, n, ln in lanes:
        rlo, wlen, start, base, slope, width, cmin, corr_words, use_corr, _ = ln
        width_a[row, slot] = width
        cmin_a[row, slot] = cmin
        rlo_a[row, slot, :n] = rlo
        wlen_a[row, slot, :n] = wlen
        start_a[row, slot, :n] = start
        base_a[row, slot, :n] = base
        slope_a[row, slot, :n] = slope
        ranks = rlo[:, None] + jw[None, :]
        use = jw[None, :] < wlen[:, None]
        if width:
            clo, chi = _gather_words(
                corr_words, (ranks * width) >> 5, use & use_corr[:, None]
            )
            clo_a[row, slot, :n], chi_a[row, slot, :n] = clo, chi
        plo, phi = _gather_words(src.payload_words(t), (ranks * pbits) >> 5, use)
        plo_a[row, slot, :n], phi_a[row, slot, :n] = plo, phi

    arrays = (width_a, cmin_a, rlo_a, wlen_a, start_a, base_a, slope_a,
              clo_a, chi_a, plo_a, phi_a, cand_a, part_a, floor_a)
    n_lanes = int(wlen_a.sum())
    device_bytes = sum(a.nbytes for a in arrays) + 2 * Qb * K * 4
    stats.fused_queries += len(pend)
    stats.fused_lanes += n_lanes
    stats.fused_stream_bytes += stream_bytes
    stats.fused_device_bytes += device_bytes
    with trace.span("kernel.fused_query", queries=int(Qb), terms=int(T),
                    candidates=int(C), window=int(W), k=int(K),
                    lanes=n_lanes, bytes=int(device_bytes)):
        if use_kernel:
            import jax.numpy as jnp

            t0 = time.perf_counter_ns()
            ids_o, sc_o = fused_topk(
                *(jnp.asarray(a) for a in arrays), k=K, pbits=pbits,
                interpret=interpret,
            )
            ids_o, sc_o = np.asarray(ids_o), np.asarray(sc_o)
            stats.fused_kernel_ns += time.perf_counter_ns() - t0
        else:
            ids_o, sc_o = fused_topk_ref(*arrays, k=K, pbits=pbits)

    for row, (i, p) in enumerate(pend):
        hit = sc_o[row] > 0  # non-empty heap slots form a prefix
        results[i] = TopKResult(
            ids=ids_o[row][hit][: p.k].astype(np.int32),
            scores=sc_o[row][hit][: p.k].astype(np.int64),
        )
