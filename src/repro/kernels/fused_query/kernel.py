"""Fused ranked-query kernel: candidates -> ε-window probe -> top-k, one dispatch.

The multi-phase ranked path answers a batch with five host<->device hops:
guided ε-window probes, correction unpack, payload unpack, impact summation,
and host-side top-k selection.  This kernel collapses the tail of that
pipeline into a single Pallas dispatch over (query, term, candidate, window)
tiles: per lane it evaluates the rank-model segment line (same
single-multiply float32 + rint formula as plm_decode / guided_search),
unpacks the bit-packed correction *and* payload words in-register from
pre-gathered word pairs (the shift/or/mask math of
repro.index.compress.unpack_bits_at, width < 32), compares the reconstructed
doc id against the candidate, and accumulates int32 BM25 impact sums.  The
per-query top-k heap lives in VMEM scratch: K peeled argmax rounds over the
surviving scores.  Candidates arrive sorted ascending, and argmax takes the
first maximum, so score ties resolve to the smaller doc id — bit-identical
to rank.score.select_topk's (score desc, id asc) ordering.

Shapes (Q = padded queries, T = tail terms, C = candidates, W = window):
  per (Q, T):       width u32, corr_min i32
  per (Q, T, C):    rlo, wlen, segstart, base i32; slope f32
  per (Q, T, C, W): corr/payload lo+hi word pairs u32
  per (Q, C):       candidate ids (pad = NEVER), partial scores i32
  per (Q, 1):       score floor i32
Outputs (Q, K) ids / scores; empty slots are id -1, score 0 (floor >= 0 and
quantized impacts >= 1 guarantee real hits score > 0).

MaxScore-style early termination happens at two levels: the host bridge
(ops.py) peels essential terms and drops candidates whose per-segment upper
bound cannot reach the running threshold, and in-kernel the floor mask
zeroes lanes that cannot enter the heap.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

B_BLK = 4  # query rows per grid step
NEVER = 1 << 30  # candidate-pad sentinel: above any doc id a stream can hold


def _unpack(lo, hi, shift, mask):
    """In-register word-pair unpack, the unpack_bits_at little-endian layout."""
    up = jnp.where(shift > jnp.uint32(0), hi << (jnp.uint32(32) - shift), jnp.uint32(0))
    return ((lo >> shift) | up) & mask


def _make_kernel(k: int, pbits: int):
    def _kernel(width_ref, cmin_ref, rlo_ref, wlen_ref, start_ref, base_ref,
                slope_ref, clo_ref, chi_ref, plo_ref, phi_ref, cand_ref,
                part_ref, floor_ref, ids_ref, scores_ref, alive_ref):
        B, T, C, W = clo_ref.shape
        j = jax.lax.broadcasted_iota(jnp.int32, (B, T, C, W), 3)
        ranks = rlo_ref[...][..., None] + j
        # guided ε-window search: evaluate the segment line at every rank
        di = (ranks - start_ref[...][..., None]).astype(jnp.float32)
        pred = base_ref[...][..., None] + jnp.rint(
            slope_ref[...][..., None] * di
        ).astype(jnp.int32)
        w = width_ref[...].astype(jnp.uint32)[:, :, None, None]
        cmask = (jnp.uint32(1) << w) - jnp.uint32(1)
        cshift = (ranks.astype(jnp.uint32) * w) % jnp.uint32(32)
        corr = _unpack(clo_ref[...], chi_ref[...], cshift, cmask).astype(jnp.int32)
        ids = pred + corr + cmin_ref[...][:, :, None, None]
        valid = j < wlen_ref[...][..., None]
        # list ids strictly increase inside a window: at most one lane matches
        eq = valid & (ids == cand_ref[...][:, None, :, None])
        pshift = (ranks.astype(jnp.uint32) * jnp.uint32(pbits)) % jnp.uint32(32)
        pmask = jnp.uint32((1 << pbits) - 1)
        imp = _unpack(plo_ref[...], phi_ref[...], pshift, pmask).astype(jnp.int32)
        score = part_ref[...] + jnp.where(eq, imp, 0).sum(axis=3).sum(axis=1)
        # top-k heap in scratch: floor-mask, then K peeled argmax rounds
        alive_ref[...] = jnp.where(score > floor_ref[...], score, 0)
        cand = cand_ref[...]
        ci = jax.lax.broadcasted_iota(jnp.int32, (B, C), 1)
        cols_i, cols_s = [], []
        for _ in range(k):
            m = alive_ref[...]
            best = jnp.argmax(m, axis=1).astype(jnp.int32)
            oh = ci == best[:, None]
            val = jnp.where(oh, m, 0).sum(axis=1)
            sid = jnp.where(val > 0, jnp.where(oh, cand, 0).sum(axis=1), -1)
            alive_ref[...] = jnp.where(oh, 0, m)
            cols_i.append(sid)
            cols_s.append(val)
        ids_ref[...] = jnp.stack(cols_i, axis=1)
        scores_ref[...] = jnp.stack(cols_s, axis=1)

    return _kernel


@partial(jax.jit, static_argnames=("k", "pbits", "interpret"))
def fused_topk(width, cmin, rlo, wlen, start, base, slope, clo, chi, plo, phi,
               cand, part, floor, *, k: int, pbits: int, interpret: bool = True):
    """One dispatch: (Q, T, C, W) probe tiles -> (Q, k) top-k ids + scores."""
    Q, T, C = rlo.shape
    W = clo.shape[3]
    pad = (-Q) % B_BLK
    if pad:
        def p(a):
            return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        width, cmin, rlo, wlen, start, base, slope, clo, chi, plo, phi, \
            cand, part, floor = map(p, (width, cmin, rlo, wlen, start, base,
                                        slope, clo, chi, plo, phi, cand, part,
                                        floor))
    Qp = Q + pad
    qt = pl.BlockSpec((B_BLK, T), lambda i: (i, 0))
    qtc = pl.BlockSpec((B_BLK, T, C), lambda i: (i, 0, 0))
    qtcw = pl.BlockSpec((B_BLK, T, C, W), lambda i: (i, 0, 0, 0))
    qc = pl.BlockSpec((B_BLK, C), lambda i: (i, 0))
    q1 = pl.BlockSpec((B_BLK, 1), lambda i: (i, 0))
    qk = pl.BlockSpec((B_BLK, k), lambda i: (i, 0))
    ids, scores = pl.pallas_call(
        _make_kernel(k, pbits),
        grid=(Qp // B_BLK,),
        in_specs=[qt, qt, qtc, qtc, qtc, qtc, qtc, qtcw, qtcw, qtcw, qtcw,
                  qc, qc, q1],
        out_specs=[qk, qk],
        out_shape=[jax.ShapeDtypeStruct((Qp, k), jnp.int32),
                   jax.ShapeDtypeStruct((Qp, k), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((B_BLK, C), jnp.int32)],
        interpret=interpret,
    )(width, cmin, rlo, wlen, start, base, slope, clo, chi, plo, phi, cand,
      part, floor)
    return ids[:Q], scores[:Q]
