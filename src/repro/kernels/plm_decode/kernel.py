"""Batched PLM/RMI decode as a Pallas kernel.

Learned-codec decompression is a fused gather + FMA + add: locate each rank's
segment (a comparison one-hot over the per-list segment table), evaluate the
segment's line in float32, round, add the bit-unpacked correction.  The whole
batch of lists decodes in one launch — the serving-path analogue of the
width-bucketed PFor kernel, but for the learned representation.

Shapes per grid step: B_BLK lists × S segments × R ranks.  S and R are static
(host pads to the batch maxima), so every comparison and select lowers to
vector ops with compile-time shapes; the (B_BLK, R, S) one-hot lives in VMEM
and is the only intermediate.  Padding rows/segments use start = SENTINEL and
decode to corr (0), trimmed by the host bridge in ops.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.plm_decode.ref import SENTINEL

B_BLK = 8  # lists decoded per grid step


def _kernel(starts_ref, bases_ref, slopes_ref, corr_ref, out_ref):
    starts = starts_ref[...]  # (B_BLK, S)
    R = corr_ref.shape[1]
    ranks = jnp.arange(R, dtype=jnp.int32)
    active = starts[:, None, :] <= ranks[None, :, None]  # (B_BLK, R, S)
    nxt = jnp.concatenate(
        [starts[:, 1:], jnp.full((starts.shape[0], 1), SENTINEL, jnp.int32)], axis=1
    )
    onehot = active & (nxt[:, None, :] > ranks[None, :, None])
    ohf = onehot.astype(jnp.float32)
    ohi = onehot.astype(jnp.int32)
    sel_slope = (ohf * slopes_ref[...][:, None, :]).sum(-1)
    sel_base = (ohi * bases_ref[...][:, None, :]).sum(-1)
    sel_start = (ohi * starts[:, None, :]).sum(-1)
    di = (ranks[None, :] - sel_start).astype(jnp.float32)
    frac = jnp.rint(sel_slope * di).astype(jnp.int32)
    out_ref[...] = sel_base + frac + corr_ref[...]


@partial(jax.jit, static_argnames=("interpret",))
def decode_batch(
    starts: jax.Array,  # (B, S) int32, SENTINEL-padded
    bases: jax.Array,  # (B, S) int32
    slopes: jax.Array,  # (B, S) float32
    corr: jax.Array,  # (B, R) int32
    *,
    interpret: bool = True,
) -> jax.Array:
    """Decode B padded lists -> (B, R) int32 doc ids."""
    B, S = starts.shape
    R = corr.shape[1]
    pad = (-B) % B_BLK
    if pad:
        starts = jnp.pad(starts, ((0, pad), (0, 0)), constant_values=SENTINEL)
        bases = jnp.pad(bases, ((0, pad), (0, 0)))
        slopes = jnp.pad(slopes, ((0, pad), (0, 0)))
        corr = jnp.pad(corr, ((0, pad), (0, 0)))
    seg_spec = pl.BlockSpec((B_BLK, S), lambda i: (i, 0))
    out = pl.pallas_call(
        _kernel,
        grid=((B + pad) // B_BLK,),
        in_specs=[seg_spec, seg_spec, seg_spec, pl.BlockSpec((B_BLK, R), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((B_BLK, R), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad, R), jnp.int32),
        interpret=interpret,
    )(starts, bases, slopes, corr)
    return out[:B]
