"""Host bridge: plm/rmi word streams -> padded batches -> Pallas decode.

Parses each stream (postings/plm.py layout), bit-unpacks corrections on the
host, pads segment tables to the batch max S and rank axes to a multiple of
128, launches one kernel call for the whole batch, and trims per-list
results.  The uint32 stream fields are reinterpreted as int32 for the kernel
(doc ids < 2^31 by the index contract, enforced in the host decoder)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.plm_decode.kernel import decode_batch
from repro.kernels.plm_decode.ref import SENTINEL
from repro.obs import trace
from repro.postings.plm import parse_stream

_SENTINEL = int(SENTINEL)


def decode_lists(
    streams: list[np.ndarray], lens: list[int], *, interpret: bool = True
) -> list[np.ndarray]:
    """Batched exact decode of many plm/rmi streams -> list of int32 id arrays."""
    nonempty = [i for i, n in enumerate(lens) if n > 0]
    out: list[np.ndarray] = [np.zeros(0, np.int32)] * len(lens)
    if not nonempty:
        return out
    parsed = [parse_stream(streams[i], lens[i]) for i in nonempty]
    S = max(len(p[0]) for p in parsed)
    R = -(-max(lens[i] for i in nonempty) // 128) * 128
    B = len(parsed)
    starts = np.full((B, S), _SENTINEL, np.int32)
    bases = np.zeros((B, S), np.int32)
    slopes = np.zeros((B, S), np.float32)
    corr = np.zeros((B, R), np.int32)
    for row, (st, ba, sl, co) in enumerate(parsed):
        s = len(st)
        starts[row, :s] = st.astype(np.int32)
        bases[row, :s] = ba.astype(np.int32)
        slopes[row, :s] = sl
        corr[row, : len(co)] = co.astype(np.int32)
    with trace.span("kernel.plm_decode", lists=int(B), ranks=int(R)):
        ids = np.asarray(
            decode_batch(
                jnp.asarray(starts),
                jnp.asarray(bases),
                jnp.asarray(slopes),
                jnp.asarray(corr),
                interpret=interpret,
            )
        )
    for row, i in enumerate(nonempty):
        out[i] = ids[row, : lens[i]].astype(np.int32)
    return out
