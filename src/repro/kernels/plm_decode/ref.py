"""Pure-jnp oracle for batched PLM/RMI segment evaluation + correction add.

One batch row = one posting list, padded to S segments and R ranks.  Padding
segments carry start = SENTINEL so they are never active; every real rank is
covered by exactly one segment (starts are strictly increasing and start at
0), so the one-hot select below is an exact gather.

The single float32 multiply + banker's rint matches
repro.postings.plm.eval_segments bit-for-bit (one rounding, so no FMA
contraction ambiguity), which is what makes kernel-decoded ids identical to
the host decode path.
"""
from __future__ import annotations

import jax.numpy as jnp

SENTINEL = jnp.iinfo(jnp.int32).max  # start value marking padding segments


def decode_ref(
    starts: jnp.ndarray,  # (B, S) int32, padded with SENTINEL
    bases: jnp.ndarray,  # (B, S) int32 (integer intercept, exact)
    slopes: jnp.ndarray,  # (B, S) float32
    corr: jnp.ndarray,  # (B, R) int32 corrections
) -> jnp.ndarray:
    """-> (B, R) int32 decoded doc ids (padding ranks decode to corr value)."""
    B, S = starts.shape
    R = corr.shape[1]
    ranks = jnp.arange(R, dtype=jnp.int32)
    active = starts[:, None, :] <= ranks[None, :, None]  # (B, R, S)
    nxt = jnp.concatenate(
        [starts[:, 1:], jnp.full((B, 1), SENTINEL, jnp.int32)], axis=1
    )
    onehot = active & (nxt[:, None, :] > ranks[None, :, None])
    ohf = onehot.astype(jnp.float32)
    ohi = onehot.astype(jnp.int32)
    sel_slope = (ohf * slopes[:, None, :]).sum(-1)  # exact: one nonzero term
    sel_base = (ohi * bases[:, None, :]).sum(-1)
    sel_start = (ohi * starts[:, None, :]).sum(-1)
    di = (ranks[None, :] - sel_start).astype(jnp.float32)
    frac = jnp.rint(sel_slope * di).astype(jnp.int32)
    return sel_base + frac + corr
