"""Host numpy reference for the bm25_score kernel — the semantics oracle.

Integer impact sums are order-independent, so np.sum reproduces the kernel's
reduction exactly; the float score is the same single f32 multiply of the
exact integer sum.
"""
from __future__ import annotations

import numpy as np


def score_ref(impacts: np.ndarray, scale: float) -> tuple[np.ndarray, np.ndarray]:
    """(P, T) int impacts -> (int32 scores (P,), float32 scores (P,))."""
    ints = np.asarray(impacts, np.int64).sum(axis=1).astype(np.int32)
    floats = ints.astype(np.float32) * np.float32(scale)
    return ints, floats
