"""Batched quantized-BM25 scoring as a Pallas kernel.

The ranked tier's exhaustive scorer produces a dense (candidate, term)
window of quantized impacts — impact q(t, d) where candidate d matched term
t, 0 elsewhere.  Scoring it is one fused VPU pass per (B_BLK, T) tile: mask,
reduce the integer impacts per row, and dequantize with a single float32
multiply.

Scores are *integer* sums of <= 2^bits - 1 impacts over <= T terms, so the
reduction is associative and the kernel is bit-exact against the jnp
reference and host numpy with no ordering caveats; the float score is one
f32 multiply of that exact integer (same single-rounding discipline as the
plm_decode / guided_search kernels), so it is bit-exact too.

T is the padded term axis: the host bridge pads to 128 lanes with zero
impacts, which are additive identities — no separate valid mask is needed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_BLK = 8  # candidate rows per grid step


def _kernel(imp_ref, scale_ref, int_ref, f32_ref):
    imp = imp_ref[...]  # (B, T) int32 quantized impacts, 0 where unmatched
    total = imp.sum(axis=1, keepdims=True)  # exact: integer add is associative
    int_ref[...] = total
    f32_ref[...] = total.astype(jnp.float32) * scale_ref[...]


@partial(jax.jit, static_argnames=("interpret",))
def score_batch(
    impacts: jax.Array,  # (P, T) int32
    scale: jax.Array,  # (1, 1) float32 dequantization scale
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Score P candidate windows -> (int scores (P,1) i32, float (P,1) f32)."""
    P, T = impacts.shape
    pad = (-P) % B_BLK
    if pad:
        impacts = jnp.pad(impacts, ((0, pad), (0, 0)))
    win_spec = pl.BlockSpec((B_BLK, T), lambda i: (i, 0))
    col_spec = pl.BlockSpec((B_BLK, 1), lambda i: (i, 0))
    scale_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    ints, floats = pl.pallas_call(
        _kernel,
        grid=((P + pad) // B_BLK,),
        in_specs=[win_spec, scale_spec],
        out_specs=[col_spec, col_spec],
        out_shape=[
            jax.ShapeDtypeStruct((P + pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((P + pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(impacts, scale)
    return ints[:P], floats[:P]
