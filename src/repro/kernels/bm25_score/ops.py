"""Host bridge: (candidate, term) impact windows -> bm25_score kernel.

Pads the term axis to 128 lanes (zero impacts are additive identities) and
the candidate axis to the kernel block, rounding the candidate count up to
power-of-two-ish buckets so jax.jit compiles a handful of shapes instead of
one per candidate-set size (same discipline as guided_search/ops.py).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.bm25_score.kernel import score_batch
from repro.obs import trace

_LANES = 128


def _bucket(n: int, quantum: int) -> int:
    b = quantum
    while b < n:
        b *= 2
    return b


def score_candidates(
    impacts: np.ndarray, scale: float, *, interpret: bool = True
) -> tuple[np.ndarray, np.ndarray]:
    """Score a (P, T) quantized-impact window on the Pallas kernel.

    -> (int32 scores (P,), float32 scores (P,)); bit-exact against
    ref.score_ref — integer reduction + one f32 multiply both ways.
    """
    import jax.numpy as jnp

    imp = np.asarray(impacts, np.int32)
    P, T = imp.shape
    if P == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.float32)
    Tb = _bucket(T, _LANES)
    Pb = _bucket(P, 8)
    padded = np.zeros((Pb, Tb), np.int32)
    padded[:P, :T] = imp
    with trace.span("kernel.bm25_score", candidates=int(Pb), terms=int(Tb)):
        ints, floats = score_batch(
            jnp.asarray(padded),
            jnp.asarray(np.float32(scale).reshape(1, 1)),
            interpret=interpret,
        )
    return (
        np.asarray(ints).reshape(-1)[:P],
        np.asarray(floats).reshape(-1)[:P],
    )
