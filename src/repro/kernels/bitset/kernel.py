"""Packed-bitset conjunctive AND + popcount — Algorithm 3's block intersect.

(Q, T, W) per-query per-term block bitmaps -> (Q, W) AND + (Q,) surviving
block count. W is tiled into VMEM-sized chunks; the T-way AND runs as an
unrolled reduction inside the tile (T = max query terms is small, ≤ 8).

Popcount uses the SWAR ladder (no popcnt primitive in Mosaic): classic
Hacker's-Delight bit-slicing, all vectorizable u32 ops on the VPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

W_BLK = 1024  # u32 words per tile = 32k blocks per grid step


def _popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    m1 = jnp.uint32(0x55555555)
    m2 = jnp.uint32(0x33333333)
    m4 = jnp.uint32(0x0F0F0F0F)
    x = x - ((x >> jnp.uint32(1)) & m1)
    x = (x & m2) + ((x >> jnp.uint32(2)) & m2)
    x = (x + (x >> jnp.uint32(4))) & m4
    return ((x * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def _bitset_kernel(maps_ref, valid_ref, and_ref, cnt_ref):
    t = maps_ref.shape[1]
    full = jnp.uint32(0xFFFFFFFF)
    acc = jnp.full((maps_ref.shape[2],), full, dtype=jnp.uint32)
    for i in range(t):  # T is tiny and static -> unrolled vector ANDs
        row = jnp.where(valid_ref[0, i] > 0, maps_ref[0, i, :], full)
        acc = acc & row
    and_ref[0, :] = acc
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[0] += _popcount_u32(acc).sum()


@partial(jax.jit, static_argnames=("interpret",))
def bitset_and_popcount(
    bitmaps: jax.Array,  # (Q, T, W) uint32, W % W_BLK == 0
    valid: jax.Array,  # (Q, T) int32 (bool as int for SMEM-friendliness)
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    q, t, w = bitmaps.shape
    assert w % W_BLK == 0, w
    grid = (q, w // W_BLK)
    return pl.pallas_call(
        _bitset_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, W_BLK), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, W_BLK), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, w), jnp.uint32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=interpret,
    )(bitmaps, valid.astype(jnp.int32))
