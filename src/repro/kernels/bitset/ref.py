"""Pure-jnp oracle for packed-bitset AND-reduce + popcount."""
from __future__ import annotations

import jax.numpy as jnp


def bitset_and_ref(bitmaps: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """(T, W) u32 maps, (T,) bool validity -> (W,) u32 conjunctive AND.

    Invalid rows act as all-ones (neutral for AND) — matches Algorithm 3's
    padded query slots.
    """
    full = jnp.uint32(0xFFFFFFFF)
    maps = jnp.where(valid[:, None], bitmaps, full)
    out = full * jnp.ones_like(bitmaps[0])
    for t in range(bitmaps.shape[0]):
        out = out & maps[t]
    return out


def popcount_ref(words: jnp.ndarray) -> jnp.ndarray:
    """(W,) u32 -> () int32 total set bits."""
    x = words
    c = jnp.zeros_like(x)
    for k in range(32):
        c = c + ((x >> jnp.uint32(k)) & jnp.uint32(1))
    return c.astype(jnp.int32).sum()
