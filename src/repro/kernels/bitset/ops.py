"""Public wrapper for the bitset kernel: gathers per-term block bitmaps,
pads W to kernel tiles, returns AND-mask + surviving-block counts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitset.kernel import W_BLK, bitset_and_popcount


def query_block_intersect(
    bitmaps: jax.Array,  # (n_terms, W) uint32 — per-term block bitmaps
    queries: jax.Array,  # (Q, T) int32 padded with -1
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns ((Q, W) AND bitmap, (Q,) popcount of surviving blocks)."""
    w = bitmaps.shape[1]
    valid = queries >= 0
    qmaps = jnp.take(bitmaps, jnp.maximum(queries, 0), axis=0)  # (Q, T, W)
    pad = (-w) % W_BLK
    if pad:
        # pad words are all-ones in every row so AND keeps them; they are
        # stripped from the returned mask and do inflate popcount — mask them
        # to zero instead (padded rows -> 0 contributes nothing).
        qmaps = jnp.pad(qmaps, ((0, 0), (0, 0), (0, pad)))
    anded, cnt = bitset_and_popcount(qmaps, valid, interpret=interpret)
    return anded[:, :w], cnt
