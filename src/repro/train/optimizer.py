"""AdamW with optional int8 (block-quantized) moments + LR schedules.

Hand-rolled (optax is not vendored here) and pytree-native. The int8 moment
mode is the memory feature that lets the deepseek-v3-671b optimizer state fit
v5e HBM (DESIGN.md §7): both Adam moments are stored as int8 with per-256-
element fp32 absmax scales — 4.5x smaller than fp32 moments.

Quantized moments are PARAM-SHAPED (q has the same shape as the param; scales
block along the last axis) so their sharding can mirror the param's sharding
exactly — a flat layout forces the SPMD partitioner into involuntary full
rematerialization on every Adam update (measured on dsv3: ~10 TB/step of
resharding traffic; see EXPERIMENTS.md §Perf iteration 1).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.common.config import OptimizerConfig

QBLOCK = 256


# ------------------------------------------------------------- int8 moments
def _blk(last: int) -> int:
    return min(QBLOCK, max(1, last))


def quantize_blockwise(x: jax.Array) -> dict[str, jax.Array]:
    """x (..., L) -> {'q': int8 (..., L), 'scale': f32 (..., ceil(L/B))}."""
    if x.ndim == 0:
        x = x.reshape(1)
    last = x.shape[-1]
    b = _blk(last)
    pad = (-last) % b
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*x.shape[:-1], -1, b)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0  # (..., nblk)
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    q = q.reshape(*x.shape[:-1], last + pad)[..., :last]
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize_blockwise(qs: dict[str, jax.Array], shape: tuple[int, ...]) -> jax.Array:
    if len(shape) == 0:
        return (qs["q"].astype(jnp.float32) * qs["scale"]).reshape(())
    last = shape[-1]
    b = _blk(last)
    pad = (-last) % b
    qp = jnp.pad(qs["q"], [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    blocks = qp.astype(jnp.float32).reshape(*shape[:-1], -1, b)
    out = blocks * qs["scale"][..., None]
    return out.reshape(*shape[:-1], last + pad)[..., :last]


# ------------------------------------------------------------- schedules
def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


# ------------------------------------------------------------- AdamW
class AdamState(NamedTuple):
    step: jax.Array
    m: Any  # pytree (fp32 arrays or {'q','scale'} dicts)
    v: Any


def init_adam(params: Any, cfg: OptimizerConfig) -> AdamState:
    if cfg.moment_dtype == "int8":
        mk = lambda p: quantize_blockwise(jnp.zeros(p.shape, jnp.float32))
        return AdamState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(mk, params),
            jax.tree.map(mk, params),
        )
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(jnp.zeros((), jnp.int32), z, z)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(
    grads: Any, state: AdamState, params: Any, cfg: OptimizerConfig
) -> tuple[Any, AdamState, dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) if cfg.grad_clip > 0 else 1.0
    b1, b2 = cfg.betas
    lr = lr_schedule(cfg, step)
    quant = cfg.moment_dtype == "int8"

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = dequantize_blockwise(m, p.shape) if quant else m
        v_f = dequantize_blockwise(v, p.shape) if quant else v
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * jnp.square(g)
        mhat = m_f / (1 - b1**step.astype(jnp.float32))
        vhat = v_f / (1 - b2**step.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if quant:
            return new_p, quantize_blockwise(m_f), quantize_blockwise(v_f)
        return new_p, m_f, v_f

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tree.flatten_up_to(state.m)
    flat_v = tree.flatten_up_to(state.v)
    out = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamState(step, new_m, new_v), metrics


def sgd_update(grads, params, lr: float):
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
