from repro.train.optimizer import (
    AdamState,
    adam_update,
    init_adam,
    lr_schedule,
    quantize_blockwise,
    dequantize_blockwise,
)
from repro.train.steps import init_train_state, make_eval_step, make_train_step

__all__ = [
    "AdamState",
    "adam_update",
    "init_adam",
    "lr_schedule",
    "quantize_blockwise",
    "dequantize_blockwise",
    "init_train_state",
    "make_eval_step",
    "make_train_step",
]
