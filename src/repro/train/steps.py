"""train_step / eval_step factories: grad accumulation, remat, sharding.

The factory closes over a pure loss_fn(params, batch) -> scalar and an
OptimizerConfig; the returned step is jit-able and mesh-agnostic (sharding
comes from in_shardings at jit time, see launch/dryrun.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.config import OptimizerConfig, TrainConfig
from repro.train.optimizer import AdamState, adam_update, init_adam


def apply_remat(loss_fn: Callable, policy: str) -> Callable:
    if policy == "none":
        return loss_fn
    if policy == "full":
        return jax.checkpoint(loss_fn)
    if policy == "dots":
        return jax.checkpoint(
            loss_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    raise ValueError(f"unknown remat policy {policy}")


def make_train_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    opt_cfg: OptimizerConfig,
    train_cfg: TrainConfig | None = None,
    *,
    n_microbatches: int = 1,
):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    n_microbatches > 1 runs sequential grad accumulation via lax.scan — the
    standard memory/batch trade at scale (activations live one microbatch at
    a time).
    """
    remat_policy = train_cfg.remat if train_cfg is not None else "none"
    lfn = apply_remat(loss_fn, remat_policy)

    def grads_of(params, batch):
        return jax.value_and_grad(lfn)(params, batch)

    def step(params, opt_state: AdamState, batch):
        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_microbatches, x.shape[0] // n_microbatches, *x.shape[1:]),
                batch,
            )

            def acc_fn(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                return (loss_acc + loss, jax.tree.map(jnp.add, g_acc, g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros(()), zero), micro)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        params, opt_state, metrics = adam_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_eval_step(loss_fn: Callable):
    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step


def init_train_state(params: Any, opt_cfg: OptimizerConfig) -> AdamState:
    return init_adam(params, opt_cfg)
