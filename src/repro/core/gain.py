"""Eq. (2) storage-gain estimator — the paper's §4 analysis, exactly.

  gain(R, s) = Σ_{t∈R} [size.full.list(t) − size.trunc.list(k)]
               − |R|·|D|·s − |T|

with size.trunc.list(k) estimated as "the average size of compressed lists of
the same length in the complete compressed inverted index" (paper §4), s the
model bits per (doc + term) pair (upper bound s=0, lower bound s=512), and the
final |T| the one replaced-or-not indicator bit per term.

`codec` may be any entry of repro.index.compress.CODECS — including the
learned rank-model codecs "plm"/"rmi" and the per-term "hybrid" selector —
so the Eq. (2) bounds can be evaluated against a learned baseline index.
`learned_storage_fractions` reports the learned-vs-classical split per
correction budget ε (the storage-gain tradeoff the paper's §4 motivates).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.index.build import InvertedIndex
from repro.index.compress import index_size_bits


@dataclass
class GainReport:
    k: int
    n_replaced: int
    index_bits: int  # full compressed index
    gain_upper_bits: int  # s = 0
    gain_lower_bits: int  # s = s_worst
    s_worst_bits: float

    @property
    def gain_upper_frac(self) -> float:
        return self.gain_upper_bits / max(1, self.index_bits)

    @property
    def gain_lower_frac(self) -> float:
        return self.gain_lower_bits / max(1, self.index_bits)


def avg_size_for_length(sizes: np.ndarray, dfs: np.ndarray, k: int) -> float:
    """Average compressed size of lists with length exactly (or nearest) k."""
    exact = dfs == k
    if exact.any():
        return float(sizes[exact].mean())
    # nearest-length fallback (sparse df histogram at large k)
    nz = dfs > 0
    if not nz.any():
        return 0.0
    nearest = np.abs(dfs[nz] - k)
    sel = nearest <= np.quantile(nearest, 0.001) + 1
    return float(sizes[nz][sel].mean())


def estimate_gain(
    inv: InvertedIndex,
    k: int,
    *,
    codec: str = "optpfd",
    eps: int | None = None,
    s_worst_bits: float = 512.0,
    sizes: np.ndarray | None = None,
) -> GainReport:
    dfs = inv.dfs
    if sizes is None:
        sizes = index_size_bits(inv.term_offsets, inv.doc_ids, inv.n_docs, codec, eps=eps)
    replaced = dfs > k  # R = terms whose lists get truncated
    trunc_bits = avg_size_for_length(sizes, dfs, k)
    saved = sizes[replaced].sum() - replaced.sum() * trunc_bits
    n_r = int(replaced.sum())
    model_cost_worst = n_r * inv.n_docs * s_worst_bits
    flag_bits = inv.n_terms  # one replaced-bit per term (paper §4)
    return GainReport(
        k=k,
        n_replaced=n_r,
        index_bits=int(sizes.sum()),
        gain_upper_bits=int(saved - flag_bits),
        gain_lower_bits=int(saved - model_cost_worst - flag_bits),
        s_worst_bits=s_worst_bits,
    )


def gain_curve(
    inv: InvertedIndex,
    ks: list[int],
    *,
    codec: str = "optpfd",
    eps: int | None = None,
    s_worst_bits: float = 512.0,
) -> list[GainReport]:
    sizes = index_size_bits(inv.term_offsets, inv.doc_ids, inv.n_docs, codec, eps=eps)
    return [
        estimate_gain(inv, k, codec=codec, s_worst_bits=s_worst_bits, sizes=sizes)
        for k in ks
    ]


@dataclass
class LearnedStorageReport:
    """Learned-vs-classical storage split at one correction budget ε."""

    eps: int
    classical_bits: int  # whole index under the classical codec
    learned_bits: int  # whole index under the learned codec
    hybrid_bits: int  # per-term min + 1 selector bit/term
    frac_terms_learned: float  # fraction of nonempty terms where learned wins
    frac_bits_saved: float  # 1 - hybrid/classical


def learned_storage_fractions(
    inv: InvertedIndex,
    epsilons: tuple[int, ...] = (7, 15, 63, 255),
    *,
    codec: str = "optpfd",
    learned: str = "plm",
) -> list[LearnedStorageReport]:
    """Per-ε storage split: where does the rank model beat the classical codec?

    For each ε the learned codec stores ⌈log2(2ε+1)⌉-bit corrections, so
    larger ε means fewer segments but wider corrections — this sweep is the
    Eq. (2)-style tradeoff curve for replacing postings with models.  The
    hybrid column charges 1 extra bit per term for the replaced-or-not flag
    (the paper's |T| term).
    """
    classical = index_size_bits(inv.term_offsets, inv.doc_ids, inv.n_docs, codec)
    nz = inv.dfs > 0
    out = []
    for eps in epsilons:
        lrn = index_size_bits(inv.term_offsets, inv.doc_ids, inv.n_docs, learned, eps=eps)
        hybrid = int(np.minimum(lrn, classical)[nz].sum()) + int(nz.sum())
        out.append(
            LearnedStorageReport(
                eps=eps,
                classical_bits=int(classical.sum()),
                learned_bits=int(lrn.sum()),
                hybrid_bits=hybrid,
                frac_terms_learned=float((lrn < classical)[nz].mean()) if nz.any() else 0.0,
                frac_bits_saved=1.0 - hybrid / max(1, int(classical.sum())),
            )
        )
    return out


def storage_fraction_curve(inv: InvertedIndex, codec: str = "optpfd") -> tuple[np.ndarray, np.ndarray]:
    """Fig-1 bottom: min #terms occupying each fraction of compressed storage."""
    sizes = index_size_bits(inv.term_offsets, inv.doc_ids, inv.n_docs, codec)
    order = np.argsort(sizes)[::-1]
    cum = np.cumsum(sizes[order]) / max(1, sizes.sum())
    return cum, np.arange(1, len(order) + 1)
