"""Algorithms 1–3 from the paper, as batched jit-able query evaluators.

All three take a padded query batch (Q, T) of term ids (-1 = pad) and return
a boolean result mask over documents. They differ exactly as the paper's
complexity analysis says:

  * exhaustive  — O(|D|·|q|) model evals, zero postings storage (Alg. 1)
  * two_tier    — evals only on the union of tier-1 truncated lists (Alg. 2)
  * block       — evals only inside blocks surviving bitmap AND (Alg. 3)

Document scoring uses the learned-Bloom thresholds (no false negatives), so
results are supersets of the exact answer; `verified=True` in serve/boolean.py
re-checks survivors against the exact tier-2 index.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import membership
from repro.index.build import InvertedIndex, block_lists, truncate_index
from repro.index.intersect import padded_union


@dataclass
class EngineState:
    """Dense, device-resident state for the three algorithms."""

    params: Any  # membership model
    tau: jax.Array  # (n_terms,) per-term zero-FN thresholds
    n_docs: int
    block_size: int
    truncation_k: int
    tier1: jax.Array  # (n_terms, k) int32 doc ids, padded with n_docs
    tier1_len: jax.Array  # (n_terms,) int32
    dfs: jax.Array  # (n_terms,) int32 full document frequencies
    block_bitmaps: jax.Array  # (n_terms, words) uint32
    n_blocks: int


def build_engine(
    params: Any, tau: np.ndarray, inv: InvertedIndex, *, truncation_k: int, block_size: int
) -> EngineState:
    tr = truncate_index(inv, truncation_k)
    k = truncation_k
    t1 = np.full((inv.n_terms, k), inv.n_docs, dtype=np.int32)
    lens = np.diff(tr.term_offsets).astype(np.int32)
    for t in np.nonzero(lens)[0]:
        t1[t, : lens[t]] = tr.postings(int(t))
    bitmaps, n_blocks = block_lists(inv, block_size)
    return EngineState(
        params=params,
        tau=jnp.asarray(tau),
        n_docs=inv.n_docs,
        block_size=block_size,
        truncation_k=k,
        tier1=jnp.asarray(t1),
        tier1_len=jnp.asarray(lens),
        dfs=jnp.asarray(inv.dfs.astype(np.int32)),
        block_bitmaps=jnp.asarray(bitmaps),
        n_blocks=n_blocks,
    )


def _f_hat_docs(params, tau, terms: jax.Array, doc_ids: jax.Array) -> jax.Array:
    """(T,) terms × (D',) docs -> (T, D') thresholded membership."""
    logits = membership.term_doc_logits(params, terms, doc_ids)
    return logits >= tau[terms][:, None]


# ---------------------------------------------------------------- Algorithm 1
@partial(jax.jit, static_argnames=("n_docs",))
def exhaustive_query(params, tau, queries: jax.Array, *, n_docs: int) -> jax.Array:
    """(Q, T) padded queries -> (Q, n_docs) bool result mask."""
    valid = queries >= 0
    q_safe = jnp.maximum(queries, 0)

    def per_term(carry, xs):
        terms, ok = xs  # (Q,), (Q,)
        logits = membership.term_doc_logits(params, terms)  # (Q, D)
        hit = logits >= tau[terms][:, None]
        return carry & (hit | ~ok[:, None]), None

    init = jnp.ones((queries.shape[0], n_docs), dtype=bool)
    mask, _ = jax.lax.scan(per_term, init, (q_safe.T, valid.T))
    # all-pad queries match nothing
    return mask & valid.any(axis=1)[:, None]


# ---------------------------------------------------------------- Algorithm 2
@partial(jax.jit, static_argnames=("n_docs",))
def two_tier_query(state_tier1, state_len, params, tau, queries: jax.Array, *, n_docs: int):
    """Returns (candidates (Q, T*k), result_mask (Q, T*k)).

    candidates are the union of tier-1 truncated lists (padded with INT32_MAX);
    result_mask[i,j] = candidate j of query i passes ∀t f_hat(t, d).
    """
    valid = queries >= 0
    q_safe = jnp.maximum(queries, 0)

    def per_query(terms, ok):
        lists = jnp.where(ok[:, None], state_tier1[terms], n_docs)  # (T, k)
        lens = jnp.where(ok, state_len[terms], 0)
        cand, count = padded_union(lists, lens)  # (T*k,)
        in_range = jnp.arange(cand.shape[0]) < count
        d_safe = jnp.where(in_range, cand, 0)
        hits = _f_hat_docs(params, tau, terms, d_safe)  # (T, T*k)
        hits = hits | ~ok[:, None]
        passed = hits.all(axis=0) & in_range & ok.any()
        return cand, passed

    return jax.vmap(per_query)(q_safe, valid)


def two_tier_guaranteed(dfs: jax.Array, queries: jax.Array, k: int, *, with_model: bool) -> jax.Array:
    """Fig-3 correctness guarantee per query.

    with model:   ≥1 term has a complete tier-1 list (df ≤ k)     (paper §3.2)
    without:      ALL terms must have complete lists.
    """
    valid = queries >= 0
    complete = dfs[jnp.maximum(queries, 0)] <= k
    if with_model:
        return (complete & valid).any(axis=1)
    return (complete | ~valid).all(axis=1) & valid.any(axis=1)


# ---------------------------------------------------------------- Algorithm 3
@partial(jax.jit, static_argnames=("n_docs", "block_size"))
def block_query(bitmaps, params, tau, queries: jax.Array, *, n_docs: int, block_size: int):
    """(Q, T) -> (Q, n_docs) bool; model evaluated only in surviving blocks."""
    valid = queries >= 0
    q_safe = jnp.maximum(queries, 0)
    qmaps = bitmaps[q_safe]  # (Q, T, W)
    full = jnp.full((), 0xFFFFFFFF, dtype=jnp.uint32)
    qmaps = jnp.where(valid[:, :, None], qmaps, full)
    inter = jax.lax.reduce(
        qmaps, full, jnp.bitwise_and, dimensions=(1,)
    )  # (Q, W)

    # expand block bitmap -> per-doc candidacy
    doc_ids = jnp.arange(n_docs)
    blk = doc_ids // block_size
    word, bit = blk // 32, (blk % 32).astype(jnp.uint32)
    cand = (inter[:, word] >> bit) & jnp.uint32(1)  # (Q, D)
    cand = cand.astype(bool) & valid.any(axis=1)[:, None]

    def per_term(carry, xs):
        terms, ok = xs
        logits = membership.term_doc_logits(params, terms)
        hit = logits >= tau[terms][:, None]
        return carry & (hit | ~ok[:, None]), None

    mask, _ = jax.lax.scan(per_term, cand, (q_safe.T, valid.T))
    return mask


# ---------------------------------------------------------------- dispatch
def run_queries(state: EngineState, queries: np.ndarray, algorithm: str) -> np.ndarray:
    """Convenience host API -> dense (Q, n_docs) bool numpy mask."""
    q = jnp.asarray(queries)
    if algorithm == "exhaustive":
        out = exhaustive_query(state.params, state.tau, q, n_docs=state.n_docs)
    elif algorithm == "block":
        out = block_query(
            state.block_bitmaps, state.params, state.tau, q,
            n_docs=state.n_docs, block_size=state.block_size,
        )
    elif algorithm == "two_tier":
        cand, passed = two_tier_query(
            state.tier1, state.tier1_len, state.params, state.tau, q, n_docs=state.n_docs
        )
        out = np.zeros((queries.shape[0], state.n_docs), dtype=bool)
        cand, passed = np.asarray(cand), np.asarray(passed)
        for i in range(queries.shape[0]):
            ids = cand[i][passed[i]]
            ids = ids[ids < state.n_docs]
            out[i, ids] = True
        return out
    else:
        raise ValueError(f"unknown algorithm {algorithm}")
    return np.asarray(out)
