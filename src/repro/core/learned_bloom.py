"""Learned Bloom filter construction (Kraska et al. §5) over (term, doc) pairs.

The paper leans on Kraska's observation that a learned structure can "fallback
on traditional structures for sub-cases where a learned model performs poorly",
restoring exact guarantees. We implement that construction:

  1. fit a per-term threshold τ_t = min logit over indexed positives of t
     (so the model alone has ZERO false negatives on the collection);
  2. positives whose margin is degenerate (τ_t would admit too many false
     positives) spill into an exact backup set (sorted (t,d) key array —
     the traditional structure);
  3. query: f_hat(t,d) = logit(t,d) ≥ τ_t  OR  (t,d) ∈ backup.

τ carries a small numerical margin (NUMERIC_MARGIN): XLA fusion reorders
float reductions, so the same logit can differ by a few ulp between the
fitting pass and a later jitted query program. The margin makes the zero-FN
guarantee robust to that drift at negligible false-positive cost.

No false negatives ⇒ Boolean results are supersets; `verified` mode
re-checks survivors against tier-2 for exactness (see algorithms.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import membership
from repro.index.build import InvertedIndex

# absolute + relative slack applied below the fitted min-positive logit
NUMERIC_MARGIN = 1e-5


@dataclass
class LearnedBloom:
    params: Any
    tau: np.ndarray  # (n_terms,) float32 per-term zero-FN threshold
    backup_keys: np.ndarray  # sorted int64 keys t*n_docs+d spilled to exact storage
    n_docs: int

    def size_bits(self, embed_bits: int = 32) -> int:
        te = self.params["term_embed"]["table"]
        de = self.params["doc_embed"]["table"]
        return int(
            (te.size + de.size) * embed_bits
            + self.tau.size * 32
            + self.backup_keys.size * 64
        )


def fit_thresholds(
    params: Any,
    inv: InvertedIndex,
    *,
    terms: np.ndarray | None = None,
    backup_quantile: float = 0.0,
    batch_docs: int = 8192,
) -> LearnedBloom:
    """Scan indexed positives per term; τ_t = quantile of positive logits.

    backup_quantile=0 → τ is the exact min (no backup needed). Larger values
    trade backup storage for higher τ (fewer false positives): positives below
    τ_t spill to the exact backup set.
    """
    n_terms, n_docs = inv.n_terms, inv.n_docs
    all_terms = np.arange(n_terms) if terms is None else np.asarray(terms)
    tau = np.full(n_terms, np.inf, dtype=np.float32)
    backup: list[np.ndarray] = []

    logit_fn = jax.jit(membership.pair_logits)
    for t in all_terms:
        docs = inv.postings(int(t))
        if len(docs) == 0:
            tau[t] = np.inf  # never fires; exhaustive scans treat as no match
            continue
        logits = np.asarray(
            logit_fn(params, jnp.full(len(docs), t, jnp.int32), jnp.asarray(docs))
        )
        if backup_quantile > 0.0 and len(docs) > 8:
            q = float(np.quantile(logits, backup_quantile))
            spill = docs[logits < q]
            if len(spill):
                backup.append(t * np.int64(n_docs) + spill.astype(np.int64))
            tau[t] = q
        else:
            tau[t] = float(logits.min())
    finite = np.isfinite(tau)
    tau[finite] -= NUMERIC_MARGIN * (1.0 + np.abs(tau[finite]))
    keys = np.sort(np.concatenate(backup)) if backup else np.zeros(0, np.int64)
    return LearnedBloom(params=params, tau=tau, backup_keys=keys, n_docs=n_docs)


def bloom_predict(
    lb: LearnedBloom, terms: jax.Array, docs: jax.Array
) -> jax.Array:
    """Vectorized f_hat with guarantee: logit ≥ τ_t OR exact-backup hit."""
    logits = membership.pair_logits(lb.params, terms, docs)
    tau = jnp.take(jnp.asarray(lb.tau), terms)
    hit = logits >= tau
    if len(lb.backup_keys):
        keys = terms.astype(jnp.int64) * lb.n_docs + docs.astype(jnp.int64)
        bk = jnp.asarray(lb.backup_keys)
        idx = jnp.clip(jnp.searchsorted(bk, keys), 0, len(lb.backup_keys) - 1)
        hit = hit | (jnp.take(bk, idx) == keys)
    return hit


def false_negative_rate(lb: LearnedBloom, inv: InvertedIndex, sample: int = 20000, seed: int = 0) -> float:
    """Must be exactly 0.0 on indexed pairs — property-tested."""
    rng = np.random.default_rng(seed)
    term_of = np.repeat(np.arange(inv.n_terms, dtype=np.int64), inv.dfs)
    idx = rng.integers(0, inv.n_postings, size=min(sample, inv.n_postings))
    t, d = term_of[idx].astype(np.int32), inv.doc_ids[idx]
    pred = np.asarray(bloom_predict(lb, jnp.asarray(t), jnp.asarray(d)))
    return float(1.0 - pred.mean())


def false_positive_rate(lb: LearnedBloom, inv: InvertedIndex, sample: int = 20000, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    t = rng.integers(0, inv.n_terms, size=sample).astype(np.int32)
    d = rng.integers(0, inv.n_docs, size=sample).astype(np.int32)
    pred = np.asarray(bloom_predict(lb, jnp.asarray(t), jnp.asarray(d)))
    # remove true positives from the sample
    truth = np.zeros(sample, dtype=bool)
    for i in range(sample):
        p = inv.postings(int(t[i]))
        j = np.searchsorted(p, d[i])
        truth[i] = j < len(p) and p[j] == d[i]
    neg = ~truth
    return float(pred[neg].mean()) if neg.any() else 0.0
