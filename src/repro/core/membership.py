"""The learned membership function f(t, d) — the paper's central object.

The paper assumes a model f(t,d) ∈ {0,1} with f(t,d)=1 iff t ∈ d (Eq. 1) and
explicitly sizes its worst case as "a compressed 128 unit embedding for every
document and for every term" (s = 512 bits, §4). We realize exactly that
family: term/doc embedding tables + dot product (+ optional MLP head), scored
on the MXU as tiled matmuls.

Params are a plain pytree; `axes` is the twin logical-sharding pytree:
  term table  -> ("terms",  None)   sharded over `model`
  doc table   -> ("docs",   None)   sharded over `data` (+pod)
so scoring f(q, all docs) is doc-parallel with a bitmap all-gather at the end.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common.config import LearnedIndexConfig
from repro.common import nn


def init_membership(
    key: jax.Array, cfg: LearnedIndexConfig, n_terms: int, n_docs: int, dtype=jnp.float32
) -> tuple[Any, Any]:
    k_t, k_d, k_m, k_b = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["term_embed"], axes["term_embed"] = nn.embedding_init(
        k_t, n_terms, cfg.embed_dim, axes=("terms", None), dtype=dtype
    )
    params["doc_embed"], axes["doc_embed"] = nn.embedding_init(
        k_d, n_docs, cfg.embed_dim, axes=("docs", None), dtype=dtype
    )
    params["bias"] = jnp.zeros((), dtype)
    axes["bias"] = ()
    if cfg.mlp_hidden:
        dims = [2 * cfg.embed_dim, *cfg.mlp_hidden, 1]
        params["mlp"], axes["mlp"] = nn.mlp_init(k_m, dims, dtype=dtype)
    return params, axes


def pair_logits(params: Any, terms: jax.Array, docs: jax.Array) -> jax.Array:
    """f-logit for aligned (term, doc) id vectors — the training path."""
    te = nn.embed(params["term_embed"], terms)
    de = nn.embed(params["doc_embed"], docs)
    if "mlp" in params:
        h = jnp.concatenate([te, de], axis=-1)
        return nn.mlp(params["mlp"], h, act=jax.nn.gelu)[..., 0] + params["bias"]
    return jnp.sum(te * de, axis=-1) + params["bias"]


def term_doc_logits(params: Any, terms: jax.Array, doc_tile: jax.Array | None = None) -> jax.Array:
    """Logits of f(t, ·) for every doc (or a doc-id tile): (Q, D) matmul.

    This is the Algorithm-1/3 hot loop; on TPU it lowers to an MXU matmul
    against the (doc-sharded) embedding table. kernels/membership provides the
    fused Pallas version that also packs the thresholded bitmask.
    """
    te = nn.embed(params["term_embed"], terms)  # (Q, E)
    dt = params["doc_embed"]["table"]
    if doc_tile is not None:
        dt = jnp.take(dt, doc_tile, axis=0)
    if "mlp" in params:
        # MLP head: broadcast pairing (Q, D, 2E) — only viable on doc tiles
        q, d = te.shape[0], dt.shape[0]
        h = jnp.concatenate(
            [jnp.broadcast_to(te[:, None, :], (q, d, te.shape[-1])),
             jnp.broadcast_to(dt[None, :, :], (q, d, dt.shape[-1]))],
            axis=-1,
        )
        return nn.mlp(params["mlp"], h, act=jax.nn.gelu)[..., 0] + params["bias"]
    return te @ dt.T + params["bias"]


def membership_loss(params: Any, batch: dict[str, jax.Array]) -> jax.Array:
    """Weighted BCE; positives upweighted so the zero-FN threshold stays tight."""
    logits = pair_logits(params, batch["terms"], batch["docs"])
    labels = batch["labels"]
    per = -(labels * jax.nn.log_sigmoid(logits) + (1 - labels) * jax.nn.log_sigmoid(-logits))
    w = jnp.where(labels > 0.5, 2.0, 1.0)
    return jnp.sum(per * w) / jnp.sum(w)


def predict(params: Any, terms: jax.Array, docs: jax.Array, threshold: float = 0.0) -> jax.Array:
    return pair_logits(params, terms, docs) >= threshold
