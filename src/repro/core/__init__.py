"""The paper's contribution: learned index structures for inverted-index
compression, as a composable JAX module.

  membership     — the learned f(t, d) (embedding-dot / MLP family)
  learned_bloom  — zero-false-negative thresholds + exact backup (guarantees)
  algorithms     — Algorithms 1 (exhaustive), 2 (two-tier), 3 (block-based)
  gain           — Eq. (2) storage-gain bounds and Fig-1/2/3 analyses
"""
from repro.core.membership import (
    init_membership,
    membership_loss,
    pair_logits,
    predict,
    term_doc_logits,
)
from repro.core.learned_bloom import (
    LearnedBloom,
    bloom_predict,
    false_negative_rate,
    false_positive_rate,
    fit_thresholds,
)
from repro.core.algorithms import (
    EngineState,
    block_query,
    build_engine,
    exhaustive_query,
    run_queries,
    two_tier_guaranteed,
    two_tier_query,
)
from repro.core.gain import (
    GainReport,
    LearnedStorageReport,
    estimate_gain,
    gain_curve,
    learned_storage_fractions,
    storage_fraction_curve,
)

__all__ = [
    "init_membership",
    "membership_loss",
    "pair_logits",
    "predict",
    "term_doc_logits",
    "LearnedBloom",
    "bloom_predict",
    "false_negative_rate",
    "false_positive_rate",
    "fit_thresholds",
    "EngineState",
    "block_query",
    "build_engine",
    "exhaustive_query",
    "run_queries",
    "two_tier_guaranteed",
    "two_tier_query",
    "GainReport",
    "LearnedStorageReport",
    "estimate_gain",
    "gain_curve",
    "learned_storage_fractions",
    "storage_fraction_curve",
]
