from repro.data.corpus import Corpus, synthesize_corpus
from repro.data.queries import sample_queries
from repro.data.loader import PrefetchLoader, membership_batches, lm_token_batches

__all__ = [
    "Corpus",
    "synthesize_corpus",
    "sample_queries",
    "PrefetchLoader",
    "membership_batches",
    "lm_token_batches",
]
