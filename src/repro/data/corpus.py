"""Synthetic document collections with TREC-matched statistics.

The paper's collections (Robust05, GOV2, ClueWeb09B) are license-gated; its
analysis depends only on the document-frequency distribution, which is closely
Zipf-Mandelbrot in all three (Fig 1 of the paper). We synthesize collections
whose df-curves match that family, calibrated to each target's scale.

Representation: a corpus is stored as a CSR-like pair (doc_offsets, term_ids)
of the *deduplicated* doc->terms incidence, plus the transposed postings
(term_offsets, doc_ids) built by index/build.py.  Deduplication keeps the
within-doc occurrence counts as ``term_freqs`` (aligned with ``term_ids``):
Boolean retrieval ignores them, but the ranked tier scores BM25 from exactly
these tf payloads, so the synthesizer's i.i.d. Zipf draws double as a
realistic tf distribution for free.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.common.config import CorpusConfig


@dataclass
class Corpus:
    cfg: CorpusConfig
    doc_offsets: np.ndarray  # (n_docs+1,) int64 into term_ids
    term_ids: np.ndarray  # (total_postings,) int32, sorted within each doc
    term_freqs: np.ndarray | None = None  # (total_postings,) int32 tf >= 1

    @property
    def n_docs(self) -> int:
        return len(self.doc_offsets) - 1

    @property
    def n_terms(self) -> int:
        return int(self.cfg.n_terms)

    @property
    def n_postings(self) -> int:
        return int(self.term_ids.shape[0])

    def doc_terms(self, d: int) -> np.ndarray:
        return self.term_ids[self.doc_offsets[d] : self.doc_offsets[d + 1]]

    def contains(self, t: int, d: int) -> bool:
        terms = self.doc_terms(d)
        i = np.searchsorted(terms, t)
        return bool(i < len(terms) and terms[i] == t)


def zipf_mandelbrot_probs(n_terms: int, a: float, b: float) -> np.ndarray:
    ranks = np.arange(1, n_terms + 1, dtype=np.float64)
    w = 1.0 / np.power(ranks + b, a)
    return w / w.sum()


def synthesize_corpus(cfg: CorpusConfig) -> Corpus:
    """Draw each document's terms i.i.d. from a Zipf-Mandelbrot unigram model.

    Vectorized: one big multinomial draw for all documents at once. Doc lengths
    are log-normal around avg_doc_len (web-like skew).
    """
    rng = np.random.default_rng(cfg.seed)
    probs = zipf_mandelbrot_probs(cfg.n_terms, cfg.zipf_a, cfg.zipf_b)

    # log-normal doc lengths, mean ≈ avg_doc_len
    sigma = 0.6
    mu = np.log(cfg.avg_doc_len) - 0.5 * sigma**2
    lengths = np.maximum(8, rng.lognormal(mu, sigma, size=cfg.n_docs).astype(np.int64))
    total = int(lengths.sum())

    draws = rng.choice(cfg.n_terms, size=total, p=probs).astype(np.int32)

    # dedupe + sort within each doc (vectorized via per-doc keying); the
    # multiplicity of each (doc, term) pair is its term frequency
    doc_of = np.repeat(np.arange(cfg.n_docs, dtype=np.int64), lengths)
    key = doc_of * np.int64(cfg.n_terms) + draws
    key, tf = np.unique(key, return_counts=True)  # sorts + dedupes jointly
    doc_of_u = (key // cfg.n_terms).astype(np.int64)
    term_u = (key % cfg.n_terms).astype(np.int32)
    counts = np.bincount(doc_of_u, minlength=cfg.n_docs)
    offsets = np.zeros(cfg.n_docs + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return Corpus(cfg=cfg, doc_offsets=offsets, term_ids=term_u,
                  term_freqs=tf.astype(np.int32))


def document_frequencies(corpus: Corpus) -> np.ndarray:
    """df(t) for every term (0 for terms never drawn)."""
    return np.bincount(corpus.term_ids, minlength=corpus.n_terms).astype(np.int64)
