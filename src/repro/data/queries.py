"""TREC Million-Query-Track-style query sampler.

MQT queries are short (1-5 terms) keyword queries whose terms are biased
toward *frequent* vocabulary (people search with common words). We sample
term ids df-biased with a temperature, matching the paper's Fig-3 setup of
40k queries evaluated for tier-1 correctness guarantees.
"""
from __future__ import annotations

import numpy as np

from repro.data.corpus import Corpus, document_frequencies


def sample_queries(
    corpus: Corpus,
    n_queries: int,
    *,
    max_terms: int = 5,
    df_temperature: float = 0.55,
    seed: int = 13,
) -> np.ndarray:
    """Returns (n_queries, max_terms) int32; -1 pads short queries."""
    rng = np.random.default_rng(seed)
    df = document_frequencies(corpus).astype(np.float64)
    w = np.power(np.maximum(df, 1.0), df_temperature)
    w[df == 0] = 0.0
    p = w / w.sum()

    lengths = rng.integers(1, max_terms + 1, size=n_queries)
    out = np.full((n_queries, max_terms), -1, dtype=np.int32)
    flat = rng.choice(corpus.n_terms, size=int(lengths.sum()), p=p).astype(np.int32)
    pos = 0
    for i, L in enumerate(lengths):
        out[i, :L] = flat[pos : pos + L]
        pos += L
    return out


def brute_force_answers(corpus: Corpus, queries: np.ndarray) -> list[np.ndarray]:
    """Exact conjunctive Boolean answers (oracle for tests/benchmarks)."""
    from repro.index.build import build_inverted_index

    inv = build_inverted_index(corpus)
    answers = []
    for q in queries:
        terms = [int(t) for t in q if t >= 0]
        if not terms:
            answers.append(np.empty(0, dtype=np.int32))
            continue
        cur = inv.postings(terms[0])
        for t in terms[1:]:
            cur = np.intersect1d(cur, inv.postings(t), assume_unique=True)
            if cur.size == 0:
                break
        answers.append(cur.astype(np.int32))
    return answers
