"""TREC Million-Query-Track-style query sampler.

MQT queries are short (1-5 terms) keyword queries whose terms are biased
toward *frequent* vocabulary (people search with common words). We sample
term ids df-biased with a temperature, matching the paper's Fig-3 setup of
40k queries evaluated for tier-1 correctness guarantees.
"""
from __future__ import annotations

import numpy as np

from repro.data.corpus import Corpus, document_frequencies


def sample_queries(
    corpus: Corpus,
    n_queries: int,
    *,
    max_terms: int = 5,
    df_temperature: float = 0.55,
    seed: int = 13,
) -> np.ndarray:
    """Returns (n_queries, max_terms) int32; -1 pads short queries."""
    rng = np.random.default_rng(seed)
    df = document_frequencies(corpus).astype(np.float64)
    w = np.power(np.maximum(df, 1.0), df_temperature)
    w[df == 0] = 0.0
    p = w / w.sum()

    lengths = rng.integers(1, max_terms + 1, size=n_queries)
    out = np.full((n_queries, max_terms), -1, dtype=np.int32)
    flat = rng.choice(corpus.n_terms, size=int(lengths.sum()), p=p).astype(np.int32)
    pos = 0
    for i, L in enumerate(lengths):
        out[i, :L] = flat[pos : pos + L]
        pos += L
    return out


def _zipf_term_queries(
    dfs: np.ndarray,
    n_queries: int,
    min_terms: int,
    max_terms: int,
    zipf_a: float,
    seed: int,
) -> np.ndarray:
    """Shared Zipf workload core: df-ranked vocabulary, truncated-Zipf term
    ranks, distinct nonzero-df terms per query, -1 padded rows."""
    if not 1 <= min_terms <= max_terms:
        raise ValueError(f"need 1 <= min_terms <= max_terms, got {min_terms}..{max_terms}")
    rng = np.random.default_rng(seed)
    dfs = np.asarray(dfs)
    by_df = np.argsort(-dfs, kind="stable")  # rank 0 = most frequent term
    vocab = by_df[dfs[by_df] > 0]
    if len(vocab) < max_terms:
        raise ValueError(f"only {len(vocab)} nonempty terms < max_terms={max_terms}")
    ranks = np.arange(1, len(vocab) + 1, dtype=np.float64)
    p = ranks ** -zipf_a
    p /= p.sum()
    out = np.full((n_queries, max_terms), -1, dtype=np.int32)
    lengths = rng.integers(min_terms, max_terms + 1, size=n_queries)
    for i, L in enumerate(lengths):
        picks = rng.choice(len(vocab), size=int(L), replace=False, p=p)
        out[i, :L] = vocab[picks]
    return out


def zipf_conjunctions(
    dfs: np.ndarray,
    n_queries: int,
    *,
    min_terms: int = 2,
    max_terms: int = 5,
    zipf_a: float = 1.2,
    seed: int = 29,
) -> np.ndarray:
    """Conjunctive query workload: Zipf term draws, 2-5 term AND queries.

    Term *ranks* are drawn from a truncated Zipf(a) and mapped onto the
    vocabulary ordered by descending document frequency, so frequent terms
    dominate queries (the conjunctive-serving stress case: long posting
    lists, small intersections).  Terms are distinct within a query and only
    terms with nonzero df are drawn.  Returns (n_queries, max_terms) int32,
    -1 padded.
    """
    return _zipf_term_queries(dfs, n_queries, min_terms, max_terms, zipf_a, seed)


def zipf_disjunctions(
    dfs: np.ndarray,
    n_queries: int,
    *,
    min_terms: int = 2,
    max_terms: int = 6,
    zipf_a: float = 1.0,
    n_required: int = 0,
    seed: int = 41,
) -> tuple[np.ndarray, np.ndarray]:
    """Graded (ranked) query workload: Zipf term draws, 2-6 term OR queries.

    The ranked-serving stress case: frequent low-idf terms contribute long
    posting lists with small score upper bounds — exactly what MaxScore
    prunes — while the flatter zipf_a mixes in mid-frequency terms whose
    bounds keep them essential.  ``n_required`` marks the first
    min(n_required, length) drawn terms of each query as required (mixed
    AND/OR grading); 0 is the pure disjunctive workload.

    Returns (queries, required): (n_queries, max_terms) int32 -1-padded term
    ids and a same-shape bool mask of the required positions.
    """
    q = _zipf_term_queries(dfs, n_queries, min_terms, max_terms, zipf_a, seed)
    required = np.zeros(q.shape, dtype=bool)
    if n_required > 0:
        required[:, :n_required] = q[:, :n_required] >= 0
    return q, required


def brute_force_answers(corpus: Corpus, queries: np.ndarray) -> list[np.ndarray]:
    """Exact conjunctive Boolean answers (oracle for tests/benchmarks)."""
    from repro.index.build import build_inverted_index

    inv = build_inverted_index(corpus)
    answers = []
    for q in queries:
        terms = [int(t) for t in q if t >= 0]
        if not terms:
            answers.append(np.empty(0, dtype=np.int32))
            continue
        cur = inv.postings(terms[0])
        for t in terms[1:]:
            cur = np.intersect1d(cur, inv.postings(t), assume_unique=True)
            if cur.size == 0:
                break
        answers.append(cur.astype(np.int32))
    return answers
