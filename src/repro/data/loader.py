"""Batched data loading with background prefetch.

Two producers:
  * membership_batches — (term, doc, label) triples for training f(t,d):
    positives streamed from postings, negatives rejection-sampled.
  * lm_token_batches — synthetic token streams for LM smoke training.

PrefetchLoader runs the producer in a thread with a bounded queue — the
straggler-mitigation hook in launch/train.py raises the depth when the step
watchdog sees data stalls.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import numpy as np

from repro.data.corpus import Corpus


class PrefetchLoader:
    """Wrap an iterator with a daemon-thread prefetch queue."""

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: BaseException | None = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        except BaseException as e:  # surfaced on next()
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def membership_batches(
    corpus: Corpus,
    *,
    batch_size: int,
    negatives_per_positive: int = 4,
    replaced_terms: np.ndarray | None = None,
    seed: int = 0,
) -> Iterator[dict[str, np.ndarray]]:
    """Yield {'terms','docs','labels'} batches for training f(t,d).

    If replaced_terms is given (two-tier mode), only those terms are sampled —
    the paper notes f "only has to consider terms for which not all documents
    are stored" (§4).
    """
    rng = np.random.default_rng(seed)
    n_pos = max(1, batch_size // (1 + negatives_per_positive))
    n_neg = batch_size - n_pos

    doc_of = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int64),
        np.diff(corpus.doc_offsets),
    )
    if replaced_terms is not None and len(replaced_terms) > 0:
        replaced = np.zeros(corpus.n_terms, dtype=bool)
        replaced[replaced_terms] = True
        keep = replaced[corpus.term_ids]
        pos_terms_all = corpus.term_ids[keep]
        pos_docs_all = doc_of[keep]
        term_pool = np.asarray(replaced_terms, dtype=np.int32)
    else:
        pos_terms_all = corpus.term_ids
        pos_docs_all = doc_of
        term_pool = None

    n_pairs = len(pos_terms_all)
    while True:
        idx = rng.integers(0, n_pairs, size=n_pos)
        pt, pd = pos_terms_all[idx], pos_docs_all[idx].astype(np.int32)
        if term_pool is not None:
            nt = term_pool[rng.integers(0, len(term_pool), size=n_neg)]
        else:
            nt = rng.integers(0, corpus.n_terms, size=n_neg).astype(np.int32)
        nd = rng.integers(0, corpus.n_docs, size=n_neg).astype(np.int32)
        # negatives may collide with positives; label them correctly
        neg_labels = np.fromiter(
            (corpus.contains(int(t), int(d)) for t, d in zip(nt, nd)),
            dtype=np.float32,
            count=n_neg,
        )
        yield {
            "terms": np.concatenate([pt, nt]).astype(np.int32),
            "docs": np.concatenate([pd, nd]).astype(np.int32),
            "labels": np.concatenate([np.ones(n_pos, np.float32), neg_labels]),
        }


def lm_token_batches(
    *, vocab_size: int, batch: int, seq_len: int, seed: int = 0
) -> Iterator[dict[str, np.ndarray]]:
    """Zipfian synthetic token stream for LM smoke/e2e training."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    p = 1.0 / ranks**1.1
    p /= p.sum()
    while True:
        toks = rng.choice(vocab_size, size=(batch, seq_len + 1), p=p).astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
