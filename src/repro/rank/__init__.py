"""Ranked top-k retrieval over the learned postings store.

The paper stores "auxiliary information such as term frequency" next to each
posting; this subsystem turns that payload into a ranked tier:

score  — BM25 -> quantized-impact mapping (ImpactModel), computed once over
         the global collection so every shard quantizes identically, plus the
         brute-force oracle used by tests/benchmarks
topk   — MaxScore-style dynamic pruning for disjunctive / conjunctive /
         mixed queries over a RankedSource (full decodes + guided payload
         probes + segment-granularity score upper bounds)

Scores are integer sums of quantized impacts, so every path — host numpy,
the Pallas bm25_score kernel, sharded serving with forwarded floors, and the
brute-force oracle — agrees bit-for-bit, ties broken by ascending doc id.
"""
from repro.rank.score import (
    BM25Params,
    ImpactModel,
    TopKResult,
    brute_force_topk,
    dequantize_scores,
)
from repro.rank.topk import RankedStats, topk_query

__all__ = [
    "BM25Params",
    "ImpactModel",
    "RankedStats",
    "TopKResult",
    "brute_force_topk",
    "dequantize_scores",
    "topk_query",
]
