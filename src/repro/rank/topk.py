"""MaxScore-style dynamic pruning over a learned postings source.

One query's top-k is computed against a ``RankedSource`` — the per-shard
accessor that can fully decode a term (postings + quantized impacts), probe
a sorted candidate set through the guided ε-window rank models, and report
score upper bounds at term and *segment* granularity (the learned segment
models double as block-max tables: each PLA segment's max quantized impact
is a bound on any candidate whose rank bracket falls inside it).

The algorithm is the batch form of MaxScore [Turtle & Flood '95], exact to
the brute-force oracle by construction:

  1. terms sort by descending upper bound; a running threshold θ is the kth
     largest *partial* score (a lower bound on the kth best final score —
     impacts are nonnegative, partial sums only grow);
  2. while a new document could still reach θ (suffix-of-bounds > θ), terms
     are fully decoded and merged into the candidate set (essential terms);
  3. once no unseen document can qualify, the remaining terms only *probe*
     surviving candidates: a candidate stays alive while
     partial + remaining-bound clears θ, with the remaining bound sharpened
     per candidate by its segment's block-max before paying for a probe;
  4. final selection keeps score > floor, ordered (score desc, id asc).

Tie discipline makes sharding exact: candidates merge in ascending doc id,
doc ranges ascend across shards, and every tie breaks toward the smaller id
— so a shard may prune anything that cannot *strictly* beat the floor
forwarded from earlier shards, while intra-shard pruning keeps ties (>= θ).
Scores are integer impact sums, so θ/floor comparisons never round.

Queries whose total postings are below ``exhaustive_cutoff`` skip pruning:
every term is decoded and scored in one batch (optionally on the Pallas
bm25_score kernel) — at that size the bookkeeping costs more than it saves.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol, Sequence

import numpy as np

from repro.obs import trace
from repro.rank.score import TopKResult, select_topk


class RankedSource(Protocol):
    """What topk_query needs from a (shard-local) postings store."""

    def n(self, t: int) -> int: ...

    def ub(self, t: int) -> int: ...

    def full(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """-> (sorted doc ids int32, quantized impacts int64), full decode."""
        ...

    def probe(self, t: int, cands: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """-> (found bool, impacts int64 — 0 where absent) per sorted candidate."""
        ...

    def seg_ub(self, t: int, cands: np.ndarray) -> np.ndarray:
        """Per-candidate score bound at segment granularity (<= ub(t))."""
        ...


@dataclass
class RankedStats:
    """Postings accounting for the pruned-vs-exhaustive comparison."""

    queries: int = 0
    exhaustive_queries: int = 0  # served by the no-pruning batch path
    scored_postings: int = 0  # postings decoded + scored in full
    probed_postings: int = 0  # candidate probes into non-essential terms
    exhaustive_postings: int = 0  # what exhaustive scoring would have touched
    # fused-kernel accounting (kernels.fused_query): queries whose probe tail
    # went through the one-dispatch path, its probe lanes, the packed stream
    # bytes those lanes touched, and the dispatch's device array traffic —
    # the inputs to the benchmarks' inverted-index roofline model
    fused_queries: int = 0
    fused_lanes: int = 0
    fused_stream_bytes: int = 0
    fused_device_bytes: int = 0
    # wall split of the fused bridge: ns spent blocked on device execution
    # (materializing dispatch outputs) vs ns of host plan/pack/merge — the
    # kernel_seconds / bridge_seconds inputs of the roofline accounting
    fused_kernel_ns: int = 0
    fused_bridge_ns: int = 0

    def touched(self) -> int:
        return self.scored_postings + self.probed_postings

    def as_dict(self) -> dict[str, int | float]:
        d = {k: int(getattr(self, k)) for k in (
            "queries", "exhaustive_queries", "scored_postings",
            "probed_postings", "exhaustive_postings", "fused_queries",
            "fused_lanes", "fused_stream_bytes", "fused_device_bytes",
            "fused_kernel_ns", "fused_bridge_ns",
        )}
        d["touched_postings"] = self.touched()
        d["scored_fraction"] = (
            self.touched() / self.exhaustive_postings if self.exhaustive_postings else 0.0
        )
        return d


_EMPTY = TopKResult(ids=np.zeros(0, np.int32), scores=np.zeros(0, np.int64))


def _merge_add(
    ids: np.ndarray, scores: np.ndarray, new_ids: np.ndarray, new_q: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Union of two sorted (id, score) sets, scores added where ids collide."""
    if len(ids) == 0:
        return new_ids.astype(np.int32), new_q.astype(np.int64)
    cat = np.concatenate([ids, new_ids])
    uids, inv_idx = np.unique(cat, return_inverse=True)
    out = np.zeros(len(uids), np.int64)
    np.add.at(out, inv_idx, np.concatenate([scores, new_q]))
    return uids.astype(np.int32), out


def _kth_partial(scores: np.ndarray, k: int) -> int:
    """kth largest partial score — a valid θ (impacts only ever add)."""
    if len(scores) < k:
        return 0
    return int(np.partition(scores, len(scores) - k)[len(scores) - k])


def topk_query(
    src: RankedSource,
    terms: Sequence[int],
    k: int,
    *,
    required: Sequence[int] = (),
    floor: int = 0,
    exhaustive_cutoff: int = 2048,
    stats: RankedStats | None = None,
    batch_scorer: Callable[[np.ndarray], np.ndarray] | None = None,
) -> TopKResult:
    """Exact top-k of one query against a shard-local RankedSource.

    ``terms`` are the deduped query terms; ``required`` the conjunctive
    subset (empty = disjunctive, all = conjunctive, in between = mixed).
    ``floor`` is the score a result must strictly beat (the k-th best score
    of earlier shards); results are (score desc, id asc) like the oracle.
    """
    if k <= 0:
        return _EMPTY
    stats = stats if stats is not None else RankedStats()
    stats.queries += 1
    terms = sorted({int(t) for t in terms if src.n(int(t)) > 0})
    req_all = {int(r) for r in required}
    req = [t for t in sorted(req_all) if src.n(t) > 0]
    if len(req) < len(req_all):
        return _EMPTY  # a required term absent on this shard: empty AND
    if not terms:
        return _EMPTY
    stats.exhaustive_postings += sum(src.n(t) for t in terms)

    if not req and sum(src.n(t) for t in terms) <= exhaustive_cutoff:
        stats.exhaustive_queries += 1
        return _exhaustive(src, terms, k, floor, stats, batch_scorer)

    # ---- conjunctive seed: required terms filter candidates by probe
    optional = [t for t in terms if t not in set(req)]
    if req:
        req = sorted(req, key=src.n)  # smallest list first shrinks fastest
        cands, partial = src.full(req[0])
        partial = partial.astype(np.int64)
        stats.scored_postings += len(cands)
        for t in req[1:]:
            if len(cands) == 0:
                return _EMPTY
            found, q = src.probe(t, cands)
            stats.probed_postings += len(cands)
            cands, partial = cands[found], partial[found] + q[found]
        if len(cands) == 0:
            return _EMPTY
        accepting_new = False
    else:
        cands = np.zeros(0, np.int32)
        partial = np.zeros(0, np.int64)
        accepting_new = True

    # ---- MaxScore peel: optional terms by descending upper bound
    optional.sort(key=lambda t: (-src.ub(t), t))
    ubs = np.array([src.ub(t) for t in optional], np.int64)
    suffix = np.concatenate([np.cumsum(ubs[::-1])[::-1], [0]])
    theta = _kth_partial(partial, k)
    with trace.span("score.maxscore", terms=len(optional), k=int(k)) as sp:
        for j, t in enumerate(optional):
            alive_min = max(floor + 1, theta)
            if accepting_new and suffix[j] >= alive_min:
                ids, q = src.full(t)
                stats.scored_postings += len(ids)
                cands, partial = _merge_add(cands, partial, ids, q)
            else:
                accepting_new = False
                potential = partial + suffix[j]
                alive = potential >= alive_min
                cands, partial = cands[alive], partial[alive]
                if len(cands) == 0:
                    break
                # block-max refinement: this term's contribution is bounded by
                # the candidate's *segment* max, not the whole-list max
                bound = partial + suffix[j + 1] + src.seg_ub(t, cands)
                maybe = bound >= alive_min
                if maybe.any():
                    sel = np.nonzero(maybe)[0]
                    found, q = src.probe(t, cands[sel])
                    stats.probed_postings += len(sel)
                    partial[sel[found]] += q[found]
            theta = max(theta, _kth_partial(partial, k))
        sp.set(candidates=int(len(cands)))
    return select_topk(cands, partial, k, floor)


def _exhaustive(
    src: RankedSource,
    terms: Sequence[int],
    k: int,
    floor: int,
    stats: RankedStats,
    batch_scorer: Callable[[np.ndarray], np.ndarray] | None,
) -> TopKResult:
    """Decode every term, score the candidate union in one batch.

    With a ``batch_scorer`` the (candidate, term) impact matrix reduces on
    the Pallas bm25_score kernel; integer sums make both paths bit-equal.
    """
    with trace.span("score.exhaustive", terms=len(tuple(terms)), k=int(k)) as sp:
        decoded = [src.full(t) for t in terms]
        stats.scored_postings += sum(len(ids) for ids, _ in decoded)
        uids = np.unique(np.concatenate([ids for ids, _ in decoded]))
        sp.set(candidates=int(len(uids)))
        if len(uids) == 0:
            return _EMPTY
        if batch_scorer is None:
            scores = np.zeros(len(uids), np.int64)
            for ids, q in decoded:
                scores[np.searchsorted(uids, ids)] += q
        else:
            imp = np.zeros((len(uids), len(terms)), np.int32)
            for j, (ids, q) in enumerate(decoded):
                imp[np.searchsorted(uids, ids), j] = q
            scores = np.asarray(batch_scorer(imp), np.int64)
    return select_topk(uids.astype(np.int32), scores, k, floor)
