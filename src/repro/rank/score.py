"""BM25 -> quantized-impact scoring for the ranked tier.

The serving stack scores documents with *quantized impacts*: BM25's per-
posting contribution

  impact(t, d) = idf(t) * tf * (k1 + 1) / (tf + k1 * (1 - b + b * dl_d / avgdl))

is computed once over the global collection in float64 and linearly quantized
to ``bits``-bit integers (1 .. 2^bits - 1; a present posting never scores 0).
A document's score is then the *integer* sum of its matched impacts, which
buys exactness everywhere floats would wobble: integer addition is
associative, so MaxScore partial sums, shard-forwarded floors, the Pallas
scoring kernel, and the brute-force oracle all agree bit-for-bit, and ties
are broken deterministically by ascending doc id.

``ImpactModel`` is the global quantizer.  It must be built from the *global*
index (idf, avgdl, the quantization scale are collection statistics); shards
then quantize their local postings through the same model, which makes
per-shard payloads bit-identical to slices of the global payload stream —
the property the K=1 vs K>1 equality assertions rest on.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class BM25Params:
    k1: float = 0.9
    b: float = 0.4
    bits: int = 8  # payload quantization width (impacts in 1 .. 2^bits - 1)


@dataclass
class ImpactModel:
    """Global BM25 statistics + the impact quantizer derived from them."""

    params: BM25Params
    n_docs: int
    doc_lens: np.ndarray  # (n_docs,) float64 — global token counts per doc
    avg_len: float
    idf: np.ndarray  # (n_terms,) float64
    scale: float  # max float impact over the collection (the quant scale)

    @classmethod
    def build(cls, inv, params: BM25Params | None = None) -> "ImpactModel":
        """Fit the quantizer to a *global* InvertedIndex carrying tfs."""
        if inv.tfs is None:
            raise ValueError("ranked scoring needs an index with term frequencies")
        params = params or BM25Params()
        tfs = inv.tfs.astype(np.float64)
        doc_lens = np.bincount(inv.doc_ids, weights=tfs, minlength=inv.n_docs)
        avg_len = float(doc_lens.mean()) if inv.n_docs else 1.0
        dfs = inv.dfs.astype(np.float64)
        idf = np.log1p((inv.n_docs - dfs + 0.5) / (dfs + 0.5))
        model = cls(
            params=params,
            n_docs=inv.n_docs,
            doc_lens=doc_lens,
            avg_len=max(avg_len, 1e-9),
            idf=idf,
            scale=1.0,
        )
        term_of = np.repeat(np.arange(inv.n_terms, dtype=np.int64), inv.dfs)
        impacts = model.float_impacts(term_of, inv.tfs, doc_lens[inv.doc_ids])
        model.scale = float(impacts.max()) if impacts.size else 1.0
        # the fitting pass already computed every global impact — quantize in
        # place and memo so quantize_index(global) needn't repeat the
        # O(n_postings) float64 pass (keyed on the tfs array itself: shard
        # slices allocate new arrays and correctly miss, and holding the
        # reference keeps `is` comparisons safe from id() reuse)
        model._quant_memo = (inv.tfs, model._quantize_impacts(impacts))
        return model

    # --------------------------------------------------------------- mapping
    def float_impacts(
        self, term_of: np.ndarray, tfs: np.ndarray, dls: np.ndarray
    ) -> np.ndarray:
        """Exact float64 BM25 impact per posting (pre-quantization)."""
        k1, b = self.params.k1, self.params.b
        tf = np.asarray(tfs, np.float64)
        norm = tf + k1 * (1.0 - b + b * np.asarray(dls, np.float64) / self.avg_len)
        return self.idf[np.asarray(term_of, np.int64)] * tf * (k1 + 1.0) / norm

    @property
    def max_quant(self) -> int:
        return (1 << self.params.bits) - 1

    def _quantize_impacts(self, imp: np.ndarray) -> np.ndarray:
        q = np.ceil(imp / self.scale * self.max_quant)
        return np.clip(q, 1, self.max_quant).astype(np.uint32)

    def quantize(
        self, term_of: np.ndarray, tfs: np.ndarray, dls: np.ndarray
    ) -> np.ndarray:
        """Per-posting quantized impacts (uint32 in 1 .. max_quant).

        ceil keeps every present posting's impact >= 1; the computation is
        pure float64 elementwise, so slicing the posting set (doc-partitioned
        shards) cannot change any value.
        """
        return self._quantize_impacts(self.float_impacts(term_of, tfs, dls))

    def quantize_index(self, inv, lo: int = 0) -> np.ndarray:
        """Flat quantized impacts aligned with ``inv.doc_ids``.

        ``lo`` rebases a doc-partitioned shard's local ids into the global
        doc-length table, so a shard's payloads equal the global slice.
        The index this model was fitted on answers from the build-time memo
        without repeating the impact pass.
        """
        if inv.tfs is None:
            raise ValueError("index carries no term frequencies")
        memo = getattr(self, "_quant_memo", None)
        if lo == 0 and memo is not None and memo[0] is inv.tfs:
            return memo[1]
        term_of = np.repeat(np.arange(inv.n_terms, dtype=np.int64), inv.dfs)
        dls = self.doc_lens[inv.doc_ids.astype(np.int64) + lo]
        return self.quantize(term_of, inv.tfs, dls)

    def weight_f32(self) -> np.float32:
        """Dequantization scale: float_score ≈ int_score * weight_f32()."""
        return np.float32(self.scale / self.max_quant)


def dequantize_scores(scores: np.ndarray, im: ImpactModel) -> np.ndarray:
    """Integer impact sums -> approximate float BM25 scores (reporting only;
    ranking happens on the exact integer scores)."""
    return np.asarray(scores, np.float64) * (im.scale / im.max_quant)


# ------------------------------------------------------------------- oracle
@dataclass
class TopKResult:
    """One query's ranked answer — the single result type every path shares
    (executor, shard merge, brute-force oracle), so bit-equality checks
    compare like with like."""

    ids: np.ndarray  # (<=k,) int32, descending score then ascending id
    scores: np.ndarray  # (<=k,) int64 integer impact sums


def select_topk(ids: np.ndarray, scores: np.ndarray, k: int, floor: int = 0) -> TopKResult:
    """Exact (score desc, id asc) top-k of candidates scoring above ``floor``."""
    ids = np.asarray(ids, np.int32)
    scores = np.asarray(scores, np.int64)
    keep = scores > floor
    ids, scores = ids[keep], scores[keep]
    order = np.lexsort((ids, -scores))[:k]
    return TopKResult(ids=ids[order], scores=scores[order])


def brute_force_topk(
    inv,
    im: ImpactModel,
    queries: np.ndarray,
    k: int,
    *,
    mode: str = "or",
    required: np.ndarray | None = None,
) -> list[TopKResult]:
    """Exhaustive quantized-BM25 oracle over decoded postings.

    Scores every posting of every query term into a dense accumulator and
    takes the exact top-k; the serving path (MaxScore pruning, guided probes,
    sharded floors) must reproduce it bit-for-bit.  ``mode`` is "or"
    (disjunctive) or "and" (all terms required); ``required`` marks a
    per-position required subset for mixed queries (overrides mode).
    """
    queries = np.asarray(queries)
    if required is not None and np.asarray(required).shape != queries.shape:
        raise ValueError(
            f"required mask shape {np.asarray(required).shape} != queries {queries.shape}"
        )
    quants = im.quantize_index(inv).astype(np.int64)
    answers = []
    for qi, row in enumerate(queries):
        if required is not None:
            req = {int(t) for t, r in zip(row, required[qi]) if t >= 0 and r}
        else:
            req = {int(t) for t in row if t >= 0} if mode == "and" else set()
        terms = sorted({int(t) for t in row if t >= 0})
        score = np.zeros(inv.n_docs, np.int64)
        hit = np.zeros(inv.n_docs, np.int64)
        for t in terms:
            lo, hi = int(inv.term_offsets[t]), int(inv.term_offsets[t + 1])
            ids = inv.doc_ids[lo:hi]
            score[ids] += quants[lo:hi]
            if t in req:
                hit[ids] += 1
        if req:
            score[hit < len(req)] = 0
        docs = np.nonzero(score)[0].astype(np.int32)
        answers.append(select_topk(docs, score[docs], k))
    return answers
