"""Cell factory: (ArchConfig × ShapeSpec) -> step fn + abstract inputs + shardings.

This is the single source of truth used by the dry-run, the smoke tests and
the real launchers: every cell in the 40-cell assignment grid resolves here.

A cell bundle contains:
  step          — jittable function (params, *inputs) -> outputs
  param_specs   — ShapeDtypeStruct pytree for params (via jax.eval_shape)
  param_axes    — logical-axis pytree (for in_shardings)
  input_specs   — ShapeDtypeStruct pytree for the data inputs
  input_axes    — logical axes for the data inputs
  kind          — train | prefill | decode | serve | retrieval

Axes trees are obtained by running the real init on a structure-preserving
SKELETON config (tiny dims, same layer/table/feature structure) — axes depend
only on structure, never on dims, so this is exact and allocation-free at
full scale (full-scale params exist only as ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig, OptimizerConfig, ShapeSpec, TrainConfig
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import sampler as sampler_mod
from repro.models import transformer as tf_mod
from repro.models.attention import KVCache
from repro.train import init_train_state, make_train_step

# per-shape feature dims where the assignment leaves them open (documented)
MINIBATCH_D_FEAT = 602  # Reddit-scale node features
MOLECULE_D_FEAT = 32


@dataclass
class CellBundle:
    arch: ArchConfig
    shape: ShapeSpec
    kind: str
    step: Callable
    init_fn: Callable  # key -> params (real arrays; smoke-scale only!)
    param_specs: Any
    param_axes: Any
    input_specs: Any  # pytree of ShapeDtypeStruct
    input_axes: Any
    opt_cfg: OptimizerConfig | None = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def skeleton(cfg: ArchConfig) -> ArchConfig:
    """Structure-preserving tiny config (same pytree structure, tiny dims)."""
    kw: dict = {}
    if cfg.family == "lm":
        kw = dict(d_model=16, n_heads=2, n_kv_heads=min(cfg.n_kv_heads, 2),
                  head_dim=8, d_ff=16, vocab_size=32)
        if cfg.use_mla:
            kw.update(kv_lora_rank=8, qk_nope_head_dim=8, qk_rope_head_dim=4,
                      v_head_dim=8, q_lora_rank=8 if cfg.q_lora_rank else None)
        if cfg.use_moe:
            kw.update(n_routed_experts=max(2, min(cfg.n_routed_experts, 4)),
                      top_k=min(cfg.top_k, 2), moe_d_ff=8)
    elif cfg.family == "gnn":
        kw = dict(gnn_hidden=8, node_feat_dim=4, edge_feat_dim=cfg.edge_feat_dim,
                  gnn_out_dim=cfg.gnn_out_dim)
    elif cfg.family == "recsys":
        kw = dict(vocab_sizes=tuple(8 for _ in cfg.vocab_sizes), embed_dim=4,
                  bot_mlp=tuple(8 for _ in cfg.bot_mlp),
                  top_mlp=tuple(8 for _ in cfg.top_mlp[:-1]) + cfg.top_mlp[-1:]
                  if cfg.top_mlp else cfg.top_mlp)
    return dataclasses.replace(cfg, **kw)


# ===================================================================== LM
def _lm_param_dtype(cfg: ArchConfig):
    # 671B-scale params train in bf16 (+int8 moments) to fit v5e HBM
    return jnp.bfloat16 if cfg.name.startswith("deepseek-v3") else jnp.float32


def _lm_opt_cfg(cfg: ArchConfig) -> OptimizerConfig:
    return OptimizerConfig(
        moment_dtype="int8" if cfg.name.startswith("deepseek-v3") else "fp32"
    )


def _cache_axes(cfg: ArchConfig, cache_struct) -> Any:
    """Build the logical-axes pytree matching init_cache's structure.

    Decode caches shard batch over data and the sequence axis over model
    (SP — see DESIGN.md §7); stacked groups carry a leading 'layers' axis.
    """
    def kv_axes(kv: KVCache, stacked: bool):
        def one(leaf):
            base = ["batch", "seq_sharded"] + [None] * (leaf.ndim - 2 - (1 if stacked else 0))
            return ("layers", *base) if stacked else tuple(base)
        return KVCache(one(kv.k), one(kv.v))

    out = []
    for entry in cache_struct:
        if isinstance(entry, KVCache):
            out.append(kv_axes(entry, stacked=False))
        else:
            out.append([kv_axes(kv, stacked=True) for kv in entry])
    return out


def lm_cell(cfg: ArchConfig, shape: ShapeSpec, *, remat: str = "dots") -> CellBundle:
    pdtype = _lm_param_dtype(cfg)

    def init_fn(key):
        return tf_mod.init_lm(key, cfg, pdtype)[0]

    axes = tf_mod.init_lm(jax.random.key(0), skeleton(cfg), pdtype)[1]
    param_specs = jax.eval_shape(init_fn, jax.random.key(0))

    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        # remat is applied per-block INSIDE the layer scan (see _scan_groups)
        loss_fn = lambda p, batch: tf_mod.lm_loss(p, cfg, batch, remat=remat)
        opt_cfg = _lm_opt_cfg(cfg)
        train_step = make_train_step(loss_fn, opt_cfg, TrainConfig(remat="none"))
        inputs = {"tokens": _sds((b, s), jnp.int32), "labels": _sds((b, s), jnp.int32)}
        in_axes = {"tokens": ("batch", None), "labels": ("batch", None)}
        return CellBundle(cfg, shape, "train", train_step, init_fn, param_specs, axes,
                          inputs, in_axes, opt_cfg=opt_cfg)

    if shape.kind == "prefill":
        def step(params, tokens):
            caches = tf_mod.init_cache(cfg, b, s, jnp.bfloat16)
            return tf_mod.lm_prefill(params, cfg, tokens, caches)

        inputs = {"tokens": _sds((b, s), jnp.int32)}
        return CellBundle(cfg, shape, "prefill", step, init_fn, param_specs, axes,
                          inputs, {"tokens": ("batch", None)})

    # decode: one new token against a seq_len-deep cache
    cache_struct = jax.eval_shape(lambda: tf_mod.init_cache(cfg, b, s, jnp.bfloat16))

    def step(params, token, pos, caches):
        return tf_mod.lm_decode_step(params, cfg, token, pos, caches)

    inputs = {
        "token": _sds((b, 1), jnp.int32),
        "pos": _sds((b, 1), jnp.int32),
        "caches": cache_struct,
    }
    in_axes = {
        "token": ("batch", None),
        "pos": ("batch", None),
        "caches": _cache_axes(cfg, cache_struct),
    }
    return CellBundle(cfg, shape, "decode", step, init_fn, param_specs, axes, inputs, in_axes)


# ===================================================================== GNN
GNN_PAD = 512  # pad node/edge counts to a multiple of every mesh size —
# 61,859,140 edges % 256 != 0 would silently fall back to REPLICATED edge
# arrays (measured: 2.2 TB/dev temp on ogb_products; §Perf iteration 2)


def _pad_up(n: int, m: int = GNN_PAD) -> int:
    return -(-n // m) * m


def gnn_graph_dims(shape: ShapeSpec) -> tuple[int, int, int]:
    """(n_nodes, n_edges, d_feat) after padding/flattening rules."""
    if shape.name == "minibatch_lg":
        n, e = sampler_mod.subgraph_budget(shape.batch_nodes, shape.fanout)
        return _pad_up(n), _pad_up(e), MINIBATCH_D_FEAT
    if shape.name == "molecule":
        return (
            _pad_up(shape.n_nodes * shape.n_graphs),
            _pad_up(shape.n_edges * shape.n_graphs),
            MOLECULE_D_FEAT,
        )
    return _pad_up(shape.n_nodes), _pad_up(shape.n_edges), shape.d_feat


def gnn_cell(cfg: ArchConfig, shape: ShapeSpec) -> CellBundle:
    n, e, d_feat = gnn_graph_dims(shape)
    cfg = cfg.replace(node_feat_dim=d_feat)

    def init_fn(key):
        return gnn_mod.init_mgn(key, cfg)[0]

    axes = gnn_mod.init_mgn(jax.random.key(0), skeleton(cfg))[1]
    param_specs = jax.eval_shape(init_fn, jax.random.key(0))
    big = n > 500_000  # full-batch giants get per-layer remat (§Perf iter 2)
    loss_fn = lambda p, batch: gnn_mod.mgn_loss(p, cfg, batch, remat=big)
    train_step = make_train_step(loss_fn, OptimizerConfig())
    inputs = {
        "node_feat": _sds((n, d_feat), jnp.float32),
        "edge_feat": _sds((e, cfg.edge_feat_dim), jnp.float32),
        "senders": _sds((e,), jnp.int32),
        "receivers": _sds((e,), jnp.int32),
        "node_mask": _sds((n,), jnp.float32),
        "edge_mask": _sds((e,), jnp.float32),
        "node_targets": _sds((n, cfg.gnn_out_dim), jnp.float32),
    }
    # small graphs: 256-way sharding costs more in collectives than it saves
    # in HBM (§Perf iteration 4) — shard over data only below ~1M edges
    nd, ed = ("nodes", "edges") if e >= 1_000_000 else ("nodes_sm", "edges_sm")
    in_axes = {
        "node_feat": (nd, None),
        "edge_feat": (ed, None),
        "senders": (ed,),
        "receivers": (ed,),
        "node_mask": (nd,),
        "edge_mask": (ed,),
        "node_targets": (nd, None),
    }
    return CellBundle(cfg, shape, "train", train_step, init_fn, param_specs, axes,
                      inputs, in_axes, opt_cfg=OptimizerConfig())


# ===================================================================== RecSys
def recsys_batch_specs(cfg: ArchConfig, b: int) -> tuple[dict, dict]:
    if cfg.name == "dlrm-mlperf":
        sp = {
            "dense": _sds((b, cfg.n_dense), jnp.float32),
            "sparse": _sds((b, cfg.n_sparse), jnp.int32),
            "label": _sds((b,), jnp.float32),
        }
        ax = {"dense": ("batch", None), "sparse": ("batch", None), "label": ("batch",)}
    elif cfg.name == "fm":
        sp = {"sparse": _sds((b, cfg.n_sparse), jnp.int32), "label": _sds((b,), jnp.float32)}
        ax = {"sparse": ("batch", None), "label": ("batch",)}
    else:  # bst, mind
        sp = {
            "hist": _sds((b, cfg.hist_len), jnp.int32),
            "target": _sds((b,), jnp.int32),
            "label": _sds((b,), jnp.float32),
        }
        ax = {"hist": ("batch", None), "target": ("batch",), "label": ("batch",)}
    return sp, ax


def recsys_cell(cfg: ArchConfig, shape: ShapeSpec) -> CellBundle:
    def init_fn(key):
        return rec_mod.INIT[cfg.name](key, cfg)[0]

    axes = rec_mod.INIT[cfg.name](jax.random.key(0), skeleton(cfg))[1]
    param_specs = jax.eval_shape(init_fn, jax.random.key(0))
    b = shape.global_batch

    if shape.kind == "train":
        loss_fn = lambda p, batch: rec_mod.recsys_loss(p, cfg, batch)
        train_step = make_train_step(loss_fn, OptimizerConfig())
        sp, ax = recsys_batch_specs(cfg, b)
        return CellBundle(cfg, shape, "train", train_step, init_fn, param_specs, axes,
                          sp, ax, opt_cfg=OptimizerConfig())

    if shape.kind == "serve":
        sp, ax = recsys_batch_specs(cfg, b)
        sp.pop("label"); ax.pop("label")

        def step(params, batch):
            return rec_mod.FORWARD[cfg.name](params, cfg, batch)

        return CellBundle(cfg, shape, "serve", step, init_fn, param_specs, axes, sp, ax)

    # retrieval: one user context x n_candidates, return top-100
    sp, ax = recsys_batch_specs(cfg, max(1, b))
    for k in ("label", "target"):
        sp.pop(k, None); ax.pop(k, None)
    sp["candidates"] = _sds((shape.n_candidates,), jnp.int32)
    ax["candidates"] = ("candidates",)

    def step(params, batch):
        cand = batch["candidates"]
        rest = {k: v for k, v in batch.items() if k != "candidates"}
        scores = rec_mod.RETRIEVAL[cfg.name](params, cfg, rest, cand)
        return jax.lax.top_k(scores, 100)

    return CellBundle(cfg, shape, "retrieval", step, init_fn, param_specs, axes, sp, ax)


# ===================================================================== entry
def build_cell(cfg: ArchConfig, shape: ShapeSpec, **kw) -> CellBundle:
    if cfg.family == "lm":
        return lm_cell(cfg, shape, **kw)
    if cfg.family == "gnn":
        return gnn_cell(cfg, shape)
    if cfg.family == "recsys":
        return recsys_cell(cfg, shape)
    raise ValueError(cfg.family)
