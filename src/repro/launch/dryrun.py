import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.
# (No `from __future__` here — it must be line 1, and XLA_FLAGS must come first;
#  this module targets py3.10+ where the annotations it needs are native.)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:    (see DESIGN.md §7, EXPERIMENTS.md §Dry-run)
  * build the step fn + abstract inputs from launch/steps.py
  * jit with in_shardings resolved from logical axes over the target mesh
  * .lower().compile() — proves the distribution config is coherent
  * record memory_analysis() + cost_analysis() + collective byte counts
    parsed from the optimized HLO (for §Roofline)

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ArchConfig, ShapeSpec
from repro.common.sharding import mesh_context, sharding_for_shape
from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import CellBundle, build_cell
from repro.train import init_train_state

# ------------------------------------------------------------ HLO parsing
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*([^\s]+)"
)
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|s16|u16)\[([\d,]*)\]")

_BYTES = {
    "f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _parse_result_bytes(type_str: str) -> int:
    """Sum the element bytes of an HLO result type (tuples summed)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind output bytes summed over the module."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # result type precedes the '=': e.g.  %ag = bf16[8,128]{...} all-gather(...)
        lhs = line.split("=", 1)
        type_part = lhs[1] if len(lhs) > 1 else line
        b = _parse_result_bytes(type_part.split(m.group(1))[0])
        out[kind] = out.get(kind, 0) + b
    return out


# ------------------------------------------------------------ dry-run core
def shardings_for(tree_axes: Any, tree_specs: Any, mesh) -> Any:
    """Map (logical-axes pytree, ShapeDtypeStruct pytree) -> NamedSharding pytree.

    Divisibility-aware: mesh axes that don't divide a dim fall back to
    replicated (e.g. MQA kv_heads=1, batch=1 decode)."""
    is_ax = lambda x: (isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x))
    return jax.tree.map(
        lambda ax, spec: sharding_for_shape(ax, spec.shape, mesh),
        tree_axes,
        tree_specs,
        is_leaf=is_ax,
    )


def dryrun_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                compile_only: bool = True) -> dict[str, Any]:
    cfg, shapes, skips = get_arch(arch_id)
    if shape_name in skips:
        return {
            "arch": arch_id, "shape": shape_name, "status": "skipped",
            "reason": skips[shape_name],
        }
    shape = next(s for s in shapes if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(cfg, shape)

    param_sh = shardings_for(cell.param_axes, cell.param_specs, mesh)
    input_sh = shardings_for(cell.input_axes, cell.input_specs, mesh)

    with mesh_context(mesh):
        if cell.kind == "train":
            opt_specs = jax.eval_shape(lambda p: init_train_state(p, cell.opt_cfg),
                                       cell.param_specs)
            opt_axes = _opt_axes_like(cell.param_axes, opt_specs)
            opt_sh = shardings_for(opt_axes, opt_specs, mesh)
            jitted = jax.jit(cell.step, in_shardings=(param_sh, opt_sh, input_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(cell.param_specs, opt_specs, cell.input_specs)
        elif cell.kind == "decode":
            jitted = jax.jit(
                cell.step,
                in_shardings=(param_sh, input_sh["token"], input_sh["pos"], input_sh["caches"]),
                donate_argnums=(3,),
            )
            lowered = jitted.lower(
                cell.param_specs, cell.input_specs["token"], cell.input_specs["pos"],
                cell.input_specs["caches"],
            )
        elif cell.kind == "prefill":
            jitted = jax.jit(cell.step, in_shardings=(param_sh, input_sh["tokens"]))
            lowered = jitted.lower(cell.param_specs, cell.input_specs["tokens"])
        else:  # serve / retrieval
            jitted = jax.jit(cell.step, in_shardings=(param_sh, input_sh))
            lowered = jitted.lower(cell.param_specs, cell.input_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    n_dev = mesh.devices.size
    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "kind": cell.kind,
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": colls,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }
    print(f"[dryrun] {arch_id} × {shape_name} × {result['mesh']}: OK "
          f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
          f"flops/dev {result['flops_per_device']:.3g}, "
          f"temp/dev {mem.temp_size_in_bytes/2**30:.2f} GiB)")
    print(f"  memory_analysis: {mem}")
    return result


def _opt_axes_like(param_axes: Any, opt_specs: Any) -> Any:
    """Optimizer-state axes: moments inherit the param's logical axes; the
    int8 'q'/'scale' blocks are replicated (they are 1-D reshapes)."""
    is_ax = lambda x: (isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x))

    def like(ax, spec):
        if isinstance(spec, dict) and "q" in spec:  # quantized moment mirrors
            # the PARAM's sharding exactly (q is param-shaped; scale drops the
            # last axis) — anything else forces involuntary resharding in the
            # Adam update (EXPERIMENTS.md §Perf iter 1).
            return {"q": ax, "scale": tuple(ax[:-1]) + (None,)}
        return ax

    from repro.train.optimizer import AdamState
    m_axes = jax.tree.map(like, param_axes,
                          opt_specs.m, is_leaf=lambda x: is_ax(x) or (isinstance(x, dict) and "q" in x))
    v_axes = jax.tree.map(like, param_axes,
                          opt_specs.v, is_leaf=lambda x: is_ax(x) or (isinstance(x, dict) and "q" in x))
    return AdamState(step=(), m=m_axes, v=v_axes)


def run_all(arch_ids, *, multi_pod: bool, out_path: str | None) -> list[dict]:
    results = []
    for arch_id in arch_ids:
        _, shapes, _ = get_arch(arch_id)
        for shape in shapes:
            try:
                results.append(dryrun_cell(arch_id, shape.name, multi_pod=multi_pod))
            except Exception as e:  # a failing cell is a bug — surface it loudly
                traceback.print_exc()
                results.append({
                    "arch": arch_id, "shape": shape.name,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                })
            if out_path:
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n[dryrun] {n_ok} ok / {n_skip} documented skips / {n_err} errors")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.all:
        results = run_all(ARCH_IDS, multi_pod=args.multi_pod, out_path=args.out)
        sys.exit(1 if any(r["status"] == "error" for r in results) else 0)
    res = dryrun_cell(args.arch, args.shape, multi_pod=args.multi_pod)
    print(json.dumps(res, indent=1))
    sys.exit(1 if res["status"] == "error" else 0)


if __name__ == "__main__":
    main()
