"""Training launcher: any --arch at any scale, with checkpoint/restart,
straggler watchdog, and optional int8-compressed DP gradients.

CPU-scale example (reduced config, synthetic data):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 20 --batch 8 --seq 64

On a real pod the same entrypoint runs under the production mesh
(--mesh pod) with per-arch sharding from launch/steps.py.
"""
from __future__ import annotations

import argparse
import os
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.common.config import ShapeSpec, TrainConfig
from repro.configs import get_arch, reduce_config
from repro.data.loader import PrefetchLoader, lm_token_batches
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_cell
from repro.train import init_train_state


def synthetic_batches(cell, seed=0):
    """Spec-shaped random batches for any family (host-side producer)."""
    rng = np.random.default_rng(seed)

    def one():
        def mk(path, s):
            name = "/".join(str(getattr(p, "key", "")) for p in path)
            if s.dtype == jnp.int32:
                return rng.integers(0, 3, size=s.shape).astype(np.int32)
            if "mask" in name:
                return np.ones(s.shape, np.float32)
            if "label" in name:
                return rng.integers(0, 2, size=s.shape).astype(np.float32)
            return rng.standard_normal(s.shape).astype(np.float32)

        return jax.tree_util.tree_map_with_path(mk, cell.input_specs)

    while True:
        yield one()


def train_loop(cell, cfg: TrainConfig, *, data_it=None):
    params = cell.init_fn(jax.random.key(cfg.seed))
    opt_state = init_train_state(params, cell.opt_cfg)
    ckpt = CheckpointManager(cfg.checkpoint_dir)

    restored = ckpt.restore_latest({"params": params, "opt": opt_state})
    start = 0
    if restored is not None:
        start, tree = restored
        params, opt_state = tree["params"], tree["opt"]
        print(f"[train] resumed from step {start}")

    step_fn = jax.jit(cell.step, donate_argnums=(0, 1))
    data = PrefetchLoader(data_it or synthetic_batches(cell), depth=2)
    times: deque[float] = deque(maxlen=20)
    metrics = {}
    for step, batch in zip(range(start, cfg.steps), data):
        t0 = time.time()
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.time() - t0
        # straggler watchdog: flag steps far beyond the trailing median
        if len(times) >= 5 and dt > cfg.straggler_factor * float(np.median(times)):
            print(f"[watchdog] step {step} took {dt:.2f}s "
                  f"(median {float(np.median(times)):.2f}s) — raising prefetch")
            data = PrefetchLoader(data_it or synthetic_batches(cell), depth=4)
        times.append(dt)
        if step % cfg.log_every == 0:
            print(f"[train] step {step} loss {float(metrics['loss']):.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms")
        if cfg.checkpoint_every and (step + 1) % cfg.checkpoint_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    return params, opt_state, metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="CPU-scale config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    args = ap.parse_args()

    arch, shapes, _ = get_arch(args.arch)
    if args.reduced:
        arch = reduce_config(arch)
    if arch.family == "lm":
        shape = ShapeSpec(name="train", kind="train", seq_len=args.seq, global_batch=args.batch)
    elif arch.family == "gnn":
        shape = ShapeSpec(name="train", kind="train", n_nodes=args.batch * 16,
                          n_edges=args.batch * 64, d_feat=16)
    else:
        shape = ShapeSpec(name="train", kind="train", global_batch=args.batch)
    cell = build_cell(arch, shape)
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=args.ckpt_dir,
                       checkpoint_every=args.checkpoint_every, log_every=5)
    train_loop(cell, tcfg)


if __name__ == "__main__":
    main()
