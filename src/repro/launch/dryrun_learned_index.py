import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ must precede any jax import (see dryrun.py)

"""Dry-run of the PAPER'S OWN system at web scale (bonus beyond the 40-cell
grid): batched conjunctive Boolean serving over a ClueWeb09B-sized collection
(|D| = 50.2M docs, 128-dim embeddings — the paper's s=512-bit model), on the
production mesh.

Two cells (configs/learned_index.py):
  serve_queries — Algorithm 1 exhaustive scan: 4096 queries × 8 terms against
                  ALL docs -> packed result bitmaps (doc-sharded)
  serve_block   — Algorithm 3: block-bitmap AND + scan of a fixed candidate
                  budget (64 blocks x 1024 docs per query)

  python -m repro.launch.dryrun_learned_index [--multi-pod]
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.common.sharding import mesh_context, sharding_for_shape
from repro.launch.dryrun import collective_bytes, shardings_for
from repro.launch.mesh import make_production_mesh

N_DOCS = 50_220_423  # ClueWeb09B
N_DOCS_PAD = -(-N_DOCS // 2048) * 2048  # shardable over any mesh axis product
N_TERMS = 960_000  # scaled vocab (full ClueWeb vocab is table-sharded the same way)
EMBED = 128
Q_EXH, Q_BLK, T = 4096, 1024, 8
BLOCK_SIZE = 1024
N_BLOCKS = -(-N_DOCS_PAD // BLOCK_SIZE)
CAND_BLOCKS = 64  # per-query candidate-block budget for Algorithm 3


def param_specs():
    return {
        "term_embed": jax.ShapeDtypeStruct((N_TERMS, EMBED), jnp.bfloat16),
        "doc_embed": jax.ShapeDtypeStruct((N_DOCS_PAD, EMBED), jnp.bfloat16),
        "tau": jax.ShapeDtypeStruct((N_TERMS,), jnp.float32),
    }


PARAM_AXES = {
    "term_embed": ("terms", None),
    "doc_embed": ("docs", None),
    "tau": ("terms",),
}


def exhaustive_step(params, queries):
    """(Q,T) -> (Q, D/32) packed result bitmaps (Algorithm 1 on the mesh)."""
    valid = queries >= 0
    q = jnp.maximum(queries, 0)
    te = jnp.take(params["term_embed"], q, axis=0).astype(jnp.float32)  # (Q,T,E)
    tau = jnp.take(params["tau"], q)
    de = params["doc_embed"].astype(jnp.float32)  # (D,E) doc-sharded

    def per_term(carry, xs):
        e_t, tau_t, ok = xs  # (Q,E),(Q,),(Q,)
        logits = e_t @ de.T  # (Q, D) — MXU scan over the doc shard
        hit = (logits >= tau_t[:, None]) | ~ok[:, None]
        return carry & hit, None

    init = jnp.ones((queries.shape[0], N_DOCS_PAD), bool)
    mask, _ = jax.lax.scan(
        per_term, init, (te.transpose(1, 0, 2), tau.T, valid.T)
    )
    packed = mask.reshape(queries.shape[0], -1, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (packed * weights).sum(-1).astype(jnp.uint32)


def block_step(params, queries, block_maps, cand_docs):
    """Algorithm 3: bitmap AND -> scan candidate budget with f."""
    valid = queries >= 0
    q = jnp.maximum(queries, 0)
    qmaps = jnp.take(block_maps, q, axis=0)  # (Q,T,W)
    full = jnp.uint32(0xFFFFFFFF)
    qmaps = jnp.where(valid[:, :, None], qmaps, full)
    anded = jax.lax.reduce(qmaps, full, jnp.bitwise_and, dimensions=(1,))  # (Q,W)
    # score the fixed candidate budget with f (cand ids provided by the host
    # block-ranker; data-dependent gather is padded to the static budget)
    te = jnp.take(params["term_embed"], q, axis=0).astype(jnp.float32)  # (Q,T,E)
    tau = jnp.take(params["tau"], q)
    ce = jnp.take(params["doc_embed"], cand_docs, axis=0).astype(jnp.float32)  # (Q,C,E)
    logits = jnp.einsum("qte,qce->qtc", te, ce)
    hits = (logits >= tau[:, :, None]) | ~valid[:, :, None]
    return anded, hits.all(axis=1)  # (Q,W), (Q,C)


def run(multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    results = []
    with mesh_context(mesh):
        # --- Algorithm 1 cell
        p_sh = shardings_for(PARAM_AXES, param_specs(), mesh)
        q_spec = jax.ShapeDtypeStruct((Q_EXH, T), jnp.int32)
        q_sh = sharding_for_shape(("batch", None), q_spec.shape, mesh)
        comp = (
            jax.jit(exhaustive_step, in_shardings=(p_sh, q_sh))
            .lower(param_specs(), q_spec)
            .compile()
        )
        results.append(_record("learned-index", "serve_queries", comp, mesh))

        # --- Algorithm 3 cell
        bm_spec = jax.ShapeDtypeStruct((N_TERMS, -(-N_BLOCKS // 32)), jnp.uint32)
        cd_spec = jax.ShapeDtypeStruct((Q_BLK, CAND_BLOCKS * BLOCK_SIZE), jnp.int32)
        q2_spec = jax.ShapeDtypeStruct((Q_BLK, T), jnp.int32)
        comp2 = (
            jax.jit(
                block_step,
                in_shardings=(
                    p_sh,
                    sharding_for_shape(("batch", None), q2_spec.shape, mesh),
                    sharding_for_shape(("terms", None), bm_spec.shape, mesh),
                    sharding_for_shape(("batch", None), cd_spec.shape, mesh),
                ),
            )
            .lower(param_specs(), q2_spec, bm_spec, cd_spec)
            .compile()
        )
        results.append(_record("learned-index", "serve_block", comp2, mesh))
    return results


def _record(arch, shape, compiled, mesh):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "status": "ok",
        "kind": "serve",
        "n_devices": int(mesh.devices.size),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": collective_bytes(compiled.as_text()),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
        },
    }
    print(f"[dryrun-li] {shape}: OK flops/dev {rec['flops_per_device']:.3g} "
          f"temp/dev {mem.temp_size_in_bytes/2**30:.2f} GiB "
          f"coll/dev {sum(rec['collective_bytes_per_device'].values())/2**30:.2f} GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="/root/repo/dryrun_learned_index.json")
    args = ap.parse_args()
    res = run(args.multi_pod)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
