"""Serving launcher for the paper's Boolean-query engine.

Builds a synthetic collection, trains the membership model briefly, fits
zero-FN thresholds, and serves batched conjunctive queries with the chosen
algorithm. --verified re-checks against tier-2 for exact results.

--shards K serves through K document partitions (planner/executor fan-out);
--index-dir DIR persists the sharded index (index/store.py) and then serves
from the reloaded store — the build-then-serve round trip that proves a
restart needs no re-encoding.

--topk K additionally serves a ranked (BM25 top-K) disjunctive batch over
the tier-2 payload streams, checked bit-exact against brute-force scoring.

--trace-out FILE records every served batch as Chrome-trace JSON (open in
chrome://tracing or https://ui.perfetto.dev); --probe-log FILE streams one
JSONL record per routed probe with its route decision and bytes touched.

--replicas R additionally drives the same batch through the continuous-
batching scheduler (serve/sched.Session.submit): R=0 serves inline on the
facade's own shards, R>0 spawns R process replicas per shard over the
persistent store; --deadline-ms bounds each request's queue wait (late
requests come back as typed Rejected, never silently dropped).

With --replicas and --trace-out together the trace is *distributed*: worker
replicas ship their span buffers back with every response and the launcher
exports one timeline where each process replica renders as its own named
pid lane next to the host scheduler.  --slo prints the per-tenant rolling
SLO report (deadline-hit-rate, p99, burn-rate), a per-request latency
autopsy (queue/dispatch/execute/merge), and a Prometheus rendering of the
scheduler metrics; --probe-log-max-bytes size-caps the probe JSONL sink.

  PYTHONPATH=src python -m repro.launch.serve --algorithm block --queries 64
  PYTHONPATH=src python -m repro.launch.serve --shards 4 --index-dir /tmp/idx
  PYTHONPATH=src python -m repro.launch.serve --shards 4 --topk 10
  PYTHONPATH=src python -m repro.launch.serve --shards 4 --topk 10 --fused
  PYTHONPATH=src python -m repro.launch.serve --shards 4 --replicas 1 \\
      --deadline-ms 100 --slo
  PYTHONPATH=src python -m repro.launch.serve --shards 2 --replicas 1 \\
      --trace-out serve.trace.json  # end-to-end distributed trace
  PYTHONPATH=src python -m repro.launch.serve --trace-out serve.trace.json \\
      --probe-log probes.jsonl --probe-log-max-bytes 1048576
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import CorpusConfig, LearnedIndexConfig, OptimizerConfig
from repro.core import fit_thresholds, init_membership, membership_loss
from repro.data.corpus import synthesize_corpus
from repro.data.loader import membership_batches
from repro.data.queries import brute_force_answers, sample_queries, zipf_disjunctions
from repro.index.build import build_inverted_index
from repro.obs import ProbeLog, Tracer
from repro.serve import BooleanEngine, RankedConfig, ServeConfig
from repro.train import init_train_state, make_train_step


def train_membership(corpus, inv, li_cfg: LearnedIndexConfig, steps=300, lr=0.05):
    params, _ = init_membership(
        jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs
    )
    replaced = np.nonzero(inv.dfs > li_cfg.truncation_k)[0]
    it = membership_batches(
        corpus, batch_size=2048,
        negatives_per_positive=li_cfg.train_negatives_per_positive,
        replaced_terms=replaced if len(replaced) else None,
    )
    ocfg = OptimizerConfig(lr=lr, warmup_steps=20, total_steps=steps, weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b), ocfg))
    st = init_train_state(params, ocfg)
    for i, batch in zip(range(steps), it):
        params, st, m = step(params, st, {k: jnp.asarray(v) for k, v in batch.items()})
        if i % 100 == 0:
            print(f"[serve] membership train step {i} loss {float(m['loss']):.4f}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="block",
                    choices=["exhaustive", "two_tier", "block"])
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--terms", type=int, default=8000)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=128)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--shards", type=int, default=1,
                    help="document partitions served by the planner/executor")
    ap.add_argument("--index-dir", default=None,
                    help="persist the sharded index here, then serve from the "
                         "reloaded store (build-then-serve round trip)")
    ap.add_argument("--topk", type=int, default=10,
                    help="also serve a ranked top-K disjunctive batch "
                         "(0 disables the ranked path)")
    ap.add_argument("--fused", action="store_true",
                    help="answer each shard's ranked batch with one fused "
                         "Pallas dispatch (kernels.fused_query) instead of "
                         "the multi-phase probe/unpack/score pipeline "
                         "(disables the small-query exhaustive shortcut so "
                         "the kernel actually runs on demo-sized corpora)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace JSON of every served batch here")
    ap.add_argument("--probe-log", default=None,
                    help="stream per-(query, term, shard) probe records (JSONL)")
    ap.add_argument("--replicas", type=int, default=None,
                    help="also serve through the scheduler (Session.submit): "
                         "0 = inline, N>0 = N process replicas per shard")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="scheduler default deadline; requests queued past it "
                         "are shed with a typed Rejected")
    ap.add_argument("--slo", action="store_true",
                    help="print the scheduler's rolling SLO report (per-tenant "
                         "deadline-hit-rate/p99/burn-rate), a per-request "
                         "latency autopsy, and Prometheus-rendered metrics "
                         "(implies --replicas 0 when --replicas is unset)")
    ap.add_argument("--probe-log-max-bytes", type=int, default=None,
                    help="rotate the probe log past this size (<path>.1 keeps "
                         "the previous window; unset = unbounded)")
    args = ap.parse_args()
    if args.slo and args.replicas is None:
        args.replicas = 0  # the SLO report reads the scheduler's window

    corpus = synthesize_corpus(
        CorpusConfig(n_docs=args.docs, n_terms=args.terms, avg_doc_len=80)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(
        embed_dim=64, truncation_k=args.k, block_size=args.block_size
    )
    params = train_membership(corpus, inv, li_cfg, steps=args.train_steps)
    lb = fit_thresholds(params, inv)
    tracer = Tracer() if args.trace_out else None
    probe_log = (
        ProbeLog(args.probe_log, max_bytes=args.probe_log_max_bytes)
        if args.probe_log
        else None
    )
    cfg = ServeConfig(algorithm=args.algorithm, verified=not args.no_verify,
                      use_kernel=args.use_kernel, n_shards=args.shards,
                      obs=dict(trace=tracer, probe_log=probe_log,
                               probe_log_max_bytes=args.probe_log_max_bytes),
                      ranked=dict(fused_kernel=args.fused,
                                  # the exhaustive shortcut would swallow every
                                  # demo-sized query before the fused dispatch
                                  topk_exhaustive_cutoff=0 if args.fused
                                  else RankedConfig.topk_exhaustive_cutoff))
    eng = BooleanEngine(lb, inv, li_cfg, cfg)
    if args.index_dir:
        t0 = time.time()
        eng.save(args.index_dir)
        save_s = time.time() - t0
        t0 = time.time()
        eng = BooleanEngine.from_store(lb, li_cfg, cfg, args.index_dir)
        print(f"[serve] index saved to {args.index_dir} in {save_s:.2f}s, "
              f"reloaded in {time.time() - t0:.2f}s — serving from the store")
    print(f"[serve] {len(eng.shards)} active shard(s), ranges {eng._ranges}")
    print("[serve] memory report (bits):", eng.memory_report())

    q = sample_queries(corpus, args.queries, seed=3)
    t0 = time.time()
    results = eng.query_batch(q)
    dt = (time.time() - t0) / args.queries * 1e3
    exact = brute_force_answers(corpus, q)
    n_exact = sum(np.array_equal(r, e) for r, e in zip(results, exact))
    n_super = sum(np.setdiff1d(e, r).size == 0 for r, e in zip(results, exact))
    print(f"[serve] {args.queries} queries, {dt:.2f} ms/query, "
          f"exact={n_exact}/{args.queries}, superset={n_super}/{args.queries}")
    if not args.no_verify:
        assert n_exact == args.queries, "verified mode must be exact"
        print("[serve] verified mode: all results exact ✓")
    s = eng.metrics.snapshot()["summary"]
    print(f"[serve] summary: {s['n_shards']} shards, cache "
          f"{s['cache_hits']}h/{s['cache_misses']}m/{s['cache_evictions']}e, "
          f"probe bytes {s['probe_bytes']} (ratio {s['bytes_ratio']:.3f})")

    if args.topk > 0:
        from repro.rank.score import ImpactModel, brute_force_topk

        ranked_q, _ = zipf_disjunctions(inv.dfs, args.queries, seed=7)
        t0 = time.time()
        ranked = eng.query_topk(ranked_q, args.topk)
        dt = (time.time() - t0) / args.queries * 1e3
        im = eng.impact_model or ImpactModel.build(inv)
        oracle = brute_force_topk(inv, im, ranked_q, args.topk)
        ok = all(
            np.array_equal(r.ids, e.ids) and np.array_equal(r.scores, e.scores)
            for r, e in zip(ranked, oracle)
        )
        rs = eng.metrics.snapshot()["ranked"]
        print(f"[serve] ranked top-{args.topk}: {args.queries} OR queries, "
              f"{dt:.2f} ms/query, exact-vs-BM25-brute-force={ok}, "
              f"scored {rs['touched_postings']}/{rs['exhaustive_postings']} "
              f"postings (fraction {rs['scored_fraction']:.3f})")
        if args.fused:
            print(f"[serve] fused kernel: {rs['fused_queries']} shard-queries "
                  f"in one-dispatch batches, {rs['fused_lanes']} probe lanes, "
                  f"{rs['fused_stream_bytes']} stream bytes touched")
        assert ok, "ranked serving must match brute-force BM25"

    if args.replicas is not None:
        import tempfile

        from repro.serve import QueryRequest, Session

        eng.cfg.sched.n_replicas = args.replicas
        eng.cfg.sched.default_deadline_ms = args.deadline_ms
        store = args.index_dir or (
            tempfile.mkdtemp(prefix="repro-shards-") if args.replicas > 0 else None
        )
        with Session(eng, store_dir=store) as session:
            if args.replicas > 0:
                session.warm()  # spawn + jit warmup outside the timed region
            t0 = time.time()
            futs = [
                session.submit_async(QueryRequest(terms=row), block=True)
                for row in q
            ]
            outs = [f.result() for f in futs]
            dt = (time.time() - t0) / len(q) * 1e3
            served = [o for o in outs if o.ok]
            shed = [o for o in outs if not o.ok]
            n_same = sum(
                np.array_equal(o.ids, r) for o, r in zip(outs, results) if o.ok
            )
            sm = eng.metrics.snapshot()["sched"]
            kind = f"{args.replicas} process replica(s)/shard" if args.replicas \
                else "inline"
            print(f"[serve] scheduler ({kind}): {len(served)} served in "
                  f"{sm['batches']} batches (mean size "
                  f"{sm['batch_size']['mean']:.1f}), {dt:.2f} ms/query, "
                  f"parity-with-facade={n_same}/{len(served)}")
            if shed:
                print(f"[serve] scheduler shed {len(shed)} request(s): "
                      f"{sorted({o.reason for o in shed})}")
            assert n_same == len(served), "Session.submit must match query_batch"
            if served:
                a = served[0].autopsy()
                print(f"[serve] autopsy (first served): "
                      f"total {a['total_us'] / 1e3:.2f} ms = "
                      f"queue {a['queue_us'] / 1e3:.2f} + "
                      f"dispatch {a['dispatch_us'] / 1e3:.2f} + "
                      f"execute {a['execute_us'] / 1e3:.2f} + "
                      f"merge {a['merge_us'] / 1e3:.2f} ms "
                      f"(execute {a['execute_frac']:.0%} of total)")
            if tracer is not None and args.replicas > 0:
                lanes = sorted({s.pid for s in tracer.spans if s.pid != 0})
                wspans = sum(1 for s in tracer.spans if s.pid != 0)
                print(f"[serve] distributed trace: {wspans} worker spans "
                      f"across {len(lanes)} replica lane(s) collated onto "
                      f"the host timeline")
            if args.slo:
                from repro.obs import render_prometheus

                rep = session.slo_report()
                print(f"[serve] SLO report (window {rep['window_s']:.0f}s, "
                      f"target {rep['target']:.0%}):")
                for tenant, t in sorted(rep["tenants"].items()):
                    print(f"[serve]   tenant {tenant!r}: {t['requests']} req "
                          f"({t['shed']} shed), hit-rate "
                          f"{t['deadline_hit_rate']:.1%}, p99 "
                          f"{t['p99_ms']:.2f} ms, burn {t['burn_rate']:.2f}x")
                prom = render_prometheus({"sched": rep["sched"]})
                print(f"[serve] prometheus ({len(prom.splitlines())} lines):")
                for line in prom.splitlines()[:6]:
                    print(f"[serve]   {line}")

    lat = eng.metrics.snapshot().get("latency", {})
    for name in ("query_us", "topk_query_us"):
        h = lat.get(name)
        if h:
            print(f"[serve] latency {name}: p50 {h['p50'] / 1e3:.2f} ms, "
                  f"p99 {h['p99'] / 1e3:.2f} ms over {h['count']} queries")
    if probe_log is not None:
        probe_log.close()
        print(f"[serve] probe log written to {args.probe_log}")
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"[serve] trace written to {args.trace_out} "
              f"({len(tracer.spans)} spans) — open in ui.perfetto.dev")


if __name__ == "__main__":
    main()
