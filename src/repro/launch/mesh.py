"""Production mesh builders.

Functions, not module constants — importing this module never touches jax
device state (dryrun.py must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.common.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """1-device mesh with the production axis names (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (1, n), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    return (
        MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))
        if multi_pod
        else MeshConfig(shape=(16, 16), axes=("data", "model"))
    )
