"""Production mesh builders.

Functions, not module constants — importing this module never touches jax
device state (dryrun.py must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.common.config import MeshConfig
from repro.common.sharding import concrete_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return concrete_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names (CPU tests/examples)."""
    n = len(jax.devices())
    return concrete_mesh((1, n), ("data", "model"))


def mesh_config(multi_pod: bool = False) -> MeshConfig:
    return (
        MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))
        if multi_pod
        else MeshConfig(shape=(16, 16), axes=("data", "model"))
    )
