"""Decode cache for the serving engine: LRU with a decode-cost budget.

The FIFO term-count cache this replaces treated a 3-posting list and a
3-million-posting list as equally expensive to evict; re-decoding the long
list costs ~10^6x more.  CostLRU charges each entry its actual decode cost
(bytes of decoded output — decode work is linear in it) against a total
budget, evicts least-recently-used entries until the budget holds, and keeps
hit/miss/eviction counters for the serving memory report.

The counters are repro.obs.metrics.Counter primitives — the shard's metrics
registry exposes them through its 'decode_cache' collector and resets them
through ``reset_counters`` (the int-valued ``hits``/``misses``/``evictions``
properties keep the original accessor shape).

The newest entry is always retained even if it alone exceeds the budget
(a verification round needs the list it just decoded).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Generic, Hashable, TypeVar

from repro.obs.metrics import Counter

K = TypeVar("K", bound=Hashable)
V = TypeVar("V")


class CostLRU(Generic[K, V]):
    def __init__(self, budget: int):
        if budget <= 0:
            raise ValueError(f"budget must be positive, got {budget}")
        self.budget = int(budget)
        self.total_cost = 0
        self._hits = Counter()
        self._misses = Counter()
        self._evictions = Counter()
        self._entries: OrderedDict[K, tuple[V, int]] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: K) -> bool:
        return key in self._entries

    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    def get(self, key: K) -> V | None:
        entry = self._entries.get(key)
        if entry is None:
            self._misses.inc()
            return None
        self._entries.move_to_end(key)
        self._hits.inc()
        return entry[0]

    def put(self, key: K, value: V, cost: int) -> None:
        cost = max(int(cost), 1)
        old = self._entries.pop(key, None)
        if old is not None:
            self.total_cost -= old[1]
        self._entries[key] = (value, cost)
        self.total_cost += cost
        while self.total_cost > self.budget and len(self._entries) > 1:
            _, (_, c) = self._entries.popitem(last=False)
            self.total_cost -= c
            self._evictions.inc()

    def reset_counters(self) -> None:
        """Zero the hit/miss/eviction window; cached entries stay resident."""
        self._hits.reset()
        self._misses.reset()
        self._evictions.reset()

    def stats(self) -> dict[str, int]:
        return {
            "entries": len(self._entries),
            "cost_bytes": self.total_cost,
            "budget_bytes": self.budget,
            "hits": self._hits.value,
            "misses": self._misses.value,
            "evictions": self._evictions.value,
        }
