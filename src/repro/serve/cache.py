"""Decode cache for the serving engine: LRU with a decode-cost budget.

The FIFO term-count cache this replaces treated a 3-posting list and a
3-million-posting list as equally expensive to evict; re-decoding the long
list costs ~10^6x more.  CostLRU charges each entry its actual decode cost
(bytes of decoded output — decode work is linear in it) against a total
budget, evicts least-recently-used entries until the budget holds, and keeps
hit/miss/eviction counters for the serving memory report.

The newest entry is always retained even if it alone exceeds the budget
(a verification round needs the list it just decoded).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Generic, Hashable, TypeVar

K = TypeVar("K", bound=Hashable)
V = TypeVar("V")


class CostLRU(Generic[K, V]):
    def __init__(self, budget: int):
        if budget <= 0:
            raise ValueError(f"budget must be positive, got {budget}")
        self.budget = int(budget)
        self.total_cost = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._entries: OrderedDict[K, tuple[V, int]] = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: K) -> bool:
        return key in self._entries

    def get(self, key: K) -> V | None:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return entry[0]

    def put(self, key: K, value: V, cost: int) -> None:
        cost = max(int(cost), 1)
        old = self._entries.pop(key, None)
        if old is not None:
            self.total_cost -= old[1]
        self._entries[key] = (value, cost)
        self.total_cost += cost
        while self.total_cost > self.budget and len(self._entries) > 1:
            _, (_, c) = self._entries.popitem(last=False)
            self.total_cost -= c
            self.evictions += 1

    def reset_counters(self) -> None:
        """Zero the hit/miss/eviction window; cached entries stay resident."""
        self.hits = self.misses = self.evictions = 0

    def stats(self) -> dict[str, int]:
        return {
            "entries": len(self._entries),
            "cost_bytes": self.total_cost,
            "budget_bytes": self.budget,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
