"""Doc-partitioned shard executor: one shard of the Boolean serving engine.

``ShardEngine`` owns everything one document partition needs to serve its
slice of a query batch end to end:

  * a learned-Bloom slice (doc-embedding rows [lo, hi) of the global model +
    the global per-term zero-FN thresholds — a min over a superset of each
    shard's positives, so the zero-false-negative guarantee survives
    partitioning) and the dense EngineState built from it;
  * a local compressed tier-2 store (HybridPostings over local doc ids,
    built lazily or preloaded from the persistent shard-store);
  * its own guided-probe ``TermModel``s (GuidedPostings) and decode-cost
    budgeted ``CostLRU``, with per-shard ``serving_stats()``.

``execute`` consumes the planner's ShardPlan (run mask + probe routes) and
returns its results as a *packed bitmap* over local doc ids — 32x cheaper to
move to the merging facade than id lists, and word-copyable into the global
bitmap because shard boundaries are aligned to 32-doc words
(``shard_ranges``).

``query_topk_local`` is the ranked path: the shard runs MaxScore dynamic
pruning (repro.rank.topk) against its tier-2 payload streams — full decodes
through the CostLRU, candidate probes through the guided ε-window rank
models landing directly on rank-aligned payloads, segment-granularity score
bounds from the store — and returns its local top-k in *global* doc ids so
the facade can merge shard heaps and forward score floors.
"""
from __future__ import annotations

import numpy as np

from repro.common.config import LearnedIndexConfig
from repro.core import algorithms as alg
from repro.core.learned_bloom import LearnedBloom
from repro.index.build import InvertedIndex, slice_index
from repro.index.intersect import gallop_membership
from repro.obs import trace
from repro.obs.metrics import Registry
from repro.obs.trace import NULL_SPAN
from repro.rank.score import TopKResult
from repro.rank.topk import RankedStats, topk_query
from repro.serve.cache import CostLRU
from repro.serve.planner import QueryPlan, ShardPlan

WORD_BITS = 32  # packed-bitmap word width; shard boundaries align to this


def shard_ranges(n_docs: int, k: int, *, align: int = WORD_BITS) -> list[tuple[int, int]]:
    """K contiguous doc-id ranges covering [0, n_docs), boundaries aligned.

    Alignment to 32-doc words lets per-shard packed result bitmaps merge into
    the global bitmap by pure word copy (no cross-shard bit shifting).  Small
    collections can yield empty ranges (lo == hi) — the facade skips them.
    """
    if k <= 0:
        raise ValueError(f"need k >= 1 shards, got {k}")
    cuts = [0]
    for i in range(1, k):
        c = int(round(i * n_docs / k / align)) * align
        cuts.append(min(max(c, cuts[-1]), n_docs))
    cuts.append(n_docs)
    return [(cuts[i], cuts[i + 1]) for i in range(k)]


def slice_bloom(lb: LearnedBloom, lo: int, hi: int) -> LearnedBloom:
    """Learned-Bloom restriction to docs [lo, hi), rebased to local ids.

    Slices the doc-embedding table rows (term table, MLP head and τ are
    shared — τ_t fitted over *all* positives lower-bounds the shard's, so
    zero-FN holds locally) and remaps spilled backup keys into the local
    t*n_local + d encoding.
    """
    params = dict(lb.params)
    doc_embed = dict(params["doc_embed"])
    doc_embed["table"] = params["doc_embed"]["table"][lo:hi]
    params["doc_embed"] = doc_embed
    n_local = hi - lo
    keys = lb.backup_keys
    if len(keys):
        t, d = keys // lb.n_docs, keys % lb.n_docs
        sel = (d >= lo) & (d < hi)
        keys = t[sel] * np.int64(n_local) + (d[sel] - lo)  # stays sorted
    return LearnedBloom(params=params, tau=lb.tau, backup_keys=keys, n_docs=n_local)


def pack_ids(ids: np.ndarray, n_docs: int) -> np.ndarray:
    """Sorted unique doc ids -> packed uint32 bitmap (bit d%32 of word d//32)."""
    out = np.zeros((n_docs + WORD_BITS - 1) // WORD_BITS, dtype=np.uint32)
    if len(ids):
        ids = np.asarray(ids, np.int64)
        np.bitwise_or.at(out, ids // WORD_BITS, np.uint32(1) << (ids % WORD_BITS).astype(np.uint32))
    return out


def unpack_row(words: np.ndarray, n_docs: int) -> np.ndarray:
    """Packed uint32 bitmap row -> sorted int32 doc ids (inverse of pack_ids)."""
    bits = np.unpackbits(
        np.ascontiguousarray(words).view(np.uint8), bitorder="little"
    )[:n_docs]
    return np.nonzero(bits)[0].astype(np.int32)


class ShardEngine:
    """Executor for one document partition (the former BooleanEngine core)."""

    def __init__(
        self,
        lb: LearnedBloom,
        inv: InvertedIndex,
        li_cfg: LearnedIndexConfig,
        cfg,  # ServeConfig (typed loosely to avoid a circular import)
        *,
        lo: int = 0,
        hi: int | None = None,
        tier2=None,  # preloaded HybridPostings (the persistent shard-store)
        # global rank.score.ImpactModel, or a zero-arg provider of one (the
        # facade defers the O(n_postings) quantizer fit to first ranked use)
        impact_model=None,
    ):
        self.cfg = cfg
        self.inv = inv
        self.lb = lb
        self.lo = lo
        self.hi = inv.n_docs if hi is None else hi
        self.shard_id = 0  # position in the facade's shard list (it sets this)
        self._tier2 = tier2 if cfg.postings_store == "hybrid" else None
        self._guided = None  # lazy GuidedPostings over tier-2
        self._impact_model = impact_model
        self._ranked = None  # lazy _RankedSource over tier-2 payloads
        self.ranked_stats = RankedStats()
        self._dfs = inv.dfs  # local document frequencies, materialized once
        self._decode_cache: CostLRU[int, np.ndarray] = CostLRU(cfg.cache_budget_bytes)
        self.state = alg.build_engine(
            lb.params, lb.tau, inv,
            truncation_k=li_cfg.truncation_k, block_size=li_cfg.block_size,
        )

    @classmethod
    def from_range(
        cls, lb, inv, li_cfg, cfg, lo: int, hi: int, tier2=None, impact_model=None
    ) -> "ShardEngine":
        """Build the shard by slicing a global model + index to [lo, hi)."""
        return cls(
            slice_bloom(lb, lo, hi), slice_index(inv, lo, hi), li_cfg, cfg,
            lo=lo, hi=hi, tier2=tier2, impact_model=impact_model,
        )

    # ------------------------------------------------------------- stores
    @property
    def n_docs(self) -> int:
        return self.inv.n_docs

    @property
    def local_dfs(self) -> np.ndarray:
        """Per-term local document frequencies (the planner's run/est input)."""
        return self._dfs

    @property
    def tier2(self):
        """Compressed tier-2 postings store (hybrid per-term codec choice)."""
        if self._tier2 is None and self.cfg.postings_store == "hybrid":
            from repro.postings import HybridPostings

            self._tier2 = HybridPostings.from_index(self.inv)
        return self._tier2

    def ensure_payloads(self) -> None:
        """Quantize + attach this shard's payload stream if it can and hasn't.

        Deferred off the Boolean-only path (packing every term costs real
        startup time); the ranked path and the persisting save() force it.
        The values are bit-identical to the global stream's slice because
        the ImpactModel's statistics are collection-global.
        """
        store = self.tier2
        if (
            store is None
            or store.has_payloads
            or self._impact_model is None
            or self.inv.tfs is None
        ):
            return
        if callable(self._impact_model):
            self._impact_model = self._impact_model()
        im = self._impact_model
        store.attach_payloads(
            im.quantize_index(self.inv, lo=self.lo),
            bits=im.params.bits,
            scale=im.scale,
        )

    @property
    def guided(self):
        """Model-guided prober over tier-2 (None when serving raw postings)."""
        if self._guided is None:
            store = self.tier2
            if store is not None and self.cfg.use_guided:
                from repro.postings import GuidedPostings

                self._guided = GuidedPostings(
                    store, fallback=self._postings,
                    use_kernel=self.cfg.guided_kernel,
                    probe_log=getattr(self.cfg, "probe_log", None),
                )
        return self._guided

    def _postings(self, t: int) -> np.ndarray:
        """Fully-decoded postings of term t, via the cost-budgeted LRU."""
        store = self.tier2
        if store is None:
            return self.inv.postings(t)
        hit = self._decode_cache.get(t)
        if hit is None:
            with trace.span("decode.postings", term=int(t)) as sp:
                hit = store.postings(t)
                sp.set(bytes=int(hit.nbytes))
            self._decode_cache.put(t, hit, hit.nbytes)
        return hit

    # ------------------------------------------------------------- ranked
    @property
    def ranked(self) -> "_RankedSource":
        """RankedSource over this shard's payload streams (built on demand)."""
        if self._ranked is None:
            self.ensure_payloads()
            store = self.tier2
            if store is None or not store.has_payloads:
                raise ValueError(
                    "ranked serving needs tier-2 payload streams: build the "
                    "engine from an index with term frequencies (ImpactModel) "
                    "or load a layout-v2 store saved with payloads"
                )
            self._ranked = _RankedSource(self)
        return self._ranked

    def query_topk_local(
        self,
        terms,
        k: int,
        *,
        required=(),
        floor: int = 0,
    ) -> TopKResult:
        """This shard's exact top-k in *global* doc ids — descending score
        with ties ascending id.  ``floor`` is the facade's running k-th best
        score: only strictly better docs can matter here (later shards hold
        larger ids, so floor ties lose)."""
        if self.cfg.ranked.fused_kernel:
            return self.query_topk_batch([(tuple(terms), k, tuple(required), floor)])[0]
        src = self.ranked
        scorer = self._batch_scorer() if self.cfg.ranked.score_kernel else None
        with trace.span("shard.topk", shard=self.shard_id, k=int(k),
                        terms=len(tuple(terms))):
            ans = topk_query(
                src, terms, k,
                required=required, floor=floor,
                exhaustive_cutoff=self.cfg.ranked.topk_exhaustive_cutoff,
                stats=self.ranked_stats, batch_scorer=scorer,
            )
        return TopKResult(
            ids=(ans.ids.astype(np.int64) + self.lo).astype(np.int32),
            scores=ans.scores,
        )

    def query_topk_batch(self, items) -> list[TopKResult]:
        """Batched ranked entry point: [(terms, k, required, floor), ...] ->
        one TopKResult per item, global doc ids.

        With ``ranked.fused_kernel`` the whole batch's probe tail is answered
        by a single ``kernel.fused_query`` dispatch (replacing the per-term
        guided-probe / payload-unpack / score host bridge spans); otherwise
        it loops the multi-phase ``query_topk_local``.  Both paths are
        bit-identical by construction and asserted so in tests/benchmarks.
        """
        # shard-attribute any probe records from in here (query inherited:
        # the facade sets it per query outside, workers leave it batch-wide)
        log = getattr(self.cfg, "probe_log", None)
        ctx = (
            log.context(query=None, shard=self.shard_id)
            if log is not None
            else NULL_SPAN
        )
        if not self.cfg.ranked.fused_kernel:
            with ctx:
                return [
                    self.query_topk_local(t, k, required=r, floor=f)
                    for (t, k, r, f) in items
                ]
        from repro.kernels.fused_query.ops import fused_topk_batch

        src = self.ranked
        with ctx, trace.span("shard.topk_batch", shard=self.shard_id,
                             items=len(items)):
            answers = fused_topk_batch(
                src, items,
                exhaustive_cutoff=self.cfg.ranked.topk_exhaustive_cutoff,
                stats=self.ranked_stats,
            )
        return [
            TopKResult(
                ids=(a.ids.astype(np.int64) + self.lo).astype(np.int32),
                scores=a.scores,
            )
            for a in answers
        ]

    def _batch_scorer(self):
        from repro.kernels.bm25_score.ops import score_candidates

        scale = self.tier2.payload_scale / max(
            (1 << self.tier2.payload_bits) - 1, 1
        )
        return lambda imp: score_candidates(imp, scale)[0]

    # ------------------------------------------------------------- planning
    def route_term(self, t: int, est_cands: int) -> str | None:
        """Cost-model route for term t at the planner's candidate estimate:
        'guided' | 'decode' for learned-codec terms, None when no model
        applies (classical codec, raw store, or guided probing disabled)."""
        g = self.guided
        if g is None:
            return None
        tm = g.term_model(t)
        if tm is None:
            return None
        return "guided" if est_cands * tm.avg_window < tm.n else "decode"

    # ------------------------------------------------------------- execute
    def candidate_mask(self, q: np.ndarray) -> np.ndarray:
        """(Q, T) padded terms -> (Q, n_docs) bool learned-Bloom candidates."""
        if self.cfg.use_kernel and self.cfg.algorithm == "exhaustive":
            return self._kernel_exhaustive(q)
        return alg.run_queries(self.state, q, self.cfg.algorithm)

    def execute(
        self,
        q: np.ndarray,
        plan: ShardPlan | None = None,
        qplans: list[QueryPlan] | None = None,
        mask: np.ndarray | None = None,
    ) -> np.ndarray:
        """Serve the batch's slice on this shard -> (Q, words) packed bitmap
        over local doc ids.  Honors the planner's run mask and probe routes
        when given; without a plan every query runs with local term order.

        ``mask`` lets the facade precompute the learned-Bloom candidates:
        model scoring is one jit dispatch per shard and contends badly when
        issued from concurrent threads, so the facade runs that phase
        serially and fans out only this (numpy probe) phase to its pool.
        """
        n_queries = q.shape[0]
        words = (self.n_docs + WORD_BITS - 1) // WORD_BITS
        out = np.zeros((n_queries, words), dtype=np.uint32)
        run = plan.run if plan is not None else None
        if self.n_docs == 0 or (run is not None and not run.any()):
            return out
        if mask is None:
            # worker path (no facade precompute): span the jit probe so a
            # replica's shipped trace shows model time vs verify time
            with trace.span(
                "shard.candidate_mask", shard=self.shard_id, queries=n_queries
            ):
                mask = self.candidate_mask(q)
        log = getattr(self.cfg, "probe_log", None)
        for i in range(n_queries):
            if run is not None and not run[i]:
                continue
            # probe records inside attribute to (batch-local query i, shard)
            ctx = log.context(query=i, shard=self.shard_id) if log is not None else NULL_SPAN
            with ctx, trace.span("shard.verify", shard=self.shard_id, query=i) as sp:
                ids = np.nonzero(mask[i])[0].astype(np.int32)
                sp.set(candidates=int(len(ids)))
                if self.cfg.verified:
                    if qplans is not None:
                        routes = plan.routes[i] if plan is not None else None
                        ids = self._verify_terms(qplans[i].terms, ids, routes)
                    else:
                        ids = self._verify(q[i], ids)
                sp.set(results=int(len(ids)))
            out[i] = pack_ids(ids, self.n_docs)
        return out

    def _kernel_exhaustive(self, q: np.ndarray) -> np.ndarray:
        """Pallas path: per-term packed bitmasks, AND-combined per query."""
        import jax.numpy as jnp

        from repro.kernels.membership.ops import score_terms_bitmask

        valid = q >= 0
        flat_terms = jnp.asarray(np.maximum(q, 0).reshape(-1))
        bm = score_terms_bitmask(self.state.params, flat_terms, self.state.tau)
        bm = np.array(bm).reshape(q.shape[0], q.shape[1], -1)  # writable copy
        full = np.uint32(0xFFFFFFFF)
        bm[~valid] = full
        anded = bm[:, 0]
        for t in range(1, q.shape[1]):
            anded = anded & bm[:, t]
        # unpack to bool (D,)
        bits = np.unpackbits(
            anded.view(np.uint8), axis=-1, bitorder="little"
        )[:, : self.state.n_docs].astype(bool)
        bits[~valid.any(axis=1)] = False
        return bits

    # ------------------------------------------------------------- verify
    def _verify(self, query: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Exact candidate re-check, smallest *local* list first (the
        plan-less path: direct shard use and unit tests)."""
        terms = sorted({int(t) for t in query if t >= 0})  # dedupe repeats
        if not terms or len(ids) == 0:
            return ids
        terms.sort(key=lambda t: int(self._dfs[t]))
        return self._verify_terms(tuple(terms), ids)

    def _verify_terms(
        self,
        terms: tuple[int, ...],
        ids: np.ndarray,
        routes: dict[int, str] | None = None,
    ) -> np.ndarray:
        """Exact re-check of candidates against tier-2 in the given term
        order.  Each term filters the (sorted) survivors either by guided
        ε-window probes (learned-codec terms, honoring the planner's route
        hint) or by galloping search over the fully-decoded list."""
        out = ids
        if not terms or len(out) == 0:
            return out
        if int(self._dfs[np.asarray(terms)].min()) == 0:
            return out[:0]  # some term occurs nowhere locally: empty AND
        guided = self.guided
        for t in terms:
            if len(out) == 0:
                break
            if guided is not None:
                hint = routes.get(t) if routes else None
                out = out[guided.contains(t, out, route=hint)]
            else:
                out = out[gallop_membership(self._postings(t), out)]
        return out

    # ------------------------------------------------------------- stats
    def memory_bits(self) -> dict[str, int]:
        """This shard's dense-state + tier-2 bits (facade sums across shards)."""
        s = self.state
        bits = {
            "tier1_bits": int(s.tier1.size * 32),
            "block_bitmap_bits": int(s.block_bitmaps.size * 32),
        }
        if self._tier2 is not None:
            bits["tier2_bits"] = int(self._tier2.size_bits())
            if self._tier2.has_payloads:
                bits["payload_bits"] = int(self._tier2.payload_size_bits())
        return bits

    @property
    def metrics(self) -> Registry:
        """This shard's metrics registry (built lazily so partially-
        constructed test doubles work; collectors close over self, so the
        registry tracks later cache/guided/ranked replacements)."""
        reg = getattr(self, "_metrics", None)
        if reg is None:
            reg = Registry()
            reg.register("range", lambda: {"lo": int(self.lo), "hi": int(self.hi)})
            reg.register(
                "decode_cache",
                lambda: self._decode_cache.stats(),
                reset=lambda: self._decode_cache.reset_counters(),
            )
            reg.register(
                "guided",
                lambda: self._guided.stats.as_dict() if self._guided is not None else None,
                reset=lambda: self._guided.reset_stats() if self._guided is not None else None,
            )
            reg.register(
                "ranked",
                lambda: self.ranked_stats.as_dict() if self.ranked_stats.queries else None,
                reset=lambda: setattr(self, "ranked_stats", RankedStats()),
            )
            reg.register(
                "arena",
                lambda: (
                    self._ranked._arena.counters.as_dict()
                    if self._ranked is not None
                    and getattr(self._ranked, "_arena", None)
                    else None
                ),
            )
            self._metrics = reg
        return reg

    def serving_stats(self) -> dict[str, dict]:
        """Hot-path accounting: decode-cache behaviour + guided-probe bytes
        (one registry snapshot — see repro.obs.metrics)."""
        return self.metrics.snapshot()

    def reset_stats(self) -> None:
        """Zero this shard's probe/cache/ranked accounting window.  Owns all
        shard-local state (the facade never reaches into privates); cached
        decodes stay resident so the next pass measures warm serving."""
        self.metrics.reset()


class _RankedSource:
    """rank.topk.RankedSource over one shard's tier-2 payload streams.

    Full decodes go through the shard's decode-cost-budgeted CostLRU (ids
    under the term key the Boolean path shares, payload vectors under a
    ("pay", t) key); probes ride the guided ε-window rank models where the
    term's codec is learned and fall back to binary search in the cached
    decode otherwise.  Either way the payload read is rank-aligned —
    ``payload_at`` touches only the probe's packed words.
    """

    def __init__(self, shard: ShardEngine):
        self._sh = shard
        self._store = shard.tier2
        self._arena = None  # lazy DeviceArena (False = checked, ineligible)

    def n(self, t: int) -> int:
        return int(self._sh._dfs[t])

    def ub(self, t: int) -> int:
        return self._store.term_ub(t)

    def _payloads(self, t: int) -> np.ndarray:
        key = ("pay", t)
        hit = self._sh._decode_cache.get(key)
        if hit is None:
            with trace.span("decode.payloads", term=int(t)) as sp:
                hit = self._store.payloads(t).astype(np.int64)
                sp.set(bytes=int(hit.nbytes))
            self._sh._decode_cache.put(key, hit, hit.nbytes)
        return hit

    def full(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        return self._sh._postings(t), self._payloads(t)

    def probe(self, t: int, cands: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        g = self._sh.guided
        if g is not None:
            # one probe path for every codec: GuidedPostings routes learned
            # terms through ε-windows and classical terms through the cached
            # decode, and its ProbeStats accounting covers both uniformly
            found, rank = g.probe(t, cands)
        else:  # use_guided=False: binary search in the cached decode
            p = self._sh._postings(t)
            rank = np.searchsorted(p, cands).astype(np.int64)
            found = (rank < len(p)) & (p[np.minimum(rank, len(p) - 1)] == cands)
        q = np.zeros(len(cands), np.int64)
        if found.any():
            q[found] = self._store.payload_at(t, rank[found]).astype(np.int64)
        return found, q

    # ---- fused-kernel extensions (kernels.fused_query.ops) ----
    @property
    def arena(self):
        """This shard's device-resident impact arena, or None.

        Built lazily on the first fused dispatch that could use it (decode +
        upload is startup cost, not serving) and cached for the shard's
        lifetime — the zero-re-upload property the residence test asserts.
        ``False`` caches a failed eligibility check so it runs once.
        """
        if self._arena is None:
            from repro.kernels.arena import DeviceArena

            cfg = getattr(self._sh.cfg, "ranked", None)
            if (
                cfg is None
                or not getattr(cfg, "device_arena", False)
                or not DeviceArena.eligible(self._store.n_terms, self._sh.n_docs)
            ):
                self._arena = False
            else:
                self._arena = DeviceArena.build(
                    self, self._store.n_terms, self._sh.n_docs
                )
        return self._arena or None

    @property
    def payload_bits(self) -> int:
        """Quantized-impact width — static per store, so per kernel dispatch."""
        return int(self._store.payload_bits)

    def payload_words(self, t: int) -> np.ndarray:
        """Term t's packed payload stream (uint32 words, rank-aligned)."""
        return self._store.payload_streams[t]

    def postings(self, t: int) -> np.ndarray:
        """Fully-decoded ids only (host rank fallback for classical codecs)."""
        return self._sh._postings(t)

    def term_model(self, t: int):
        """Guided ε-window rank model, or None (classical codec/no guiding)."""
        g = self._sh.guided
        return g.term_model(t) if g is not None else None

    def seg_ub(self, t: int, cands: np.ndarray) -> np.ndarray:
        """Block-max bound per candidate: its bracketing segment's max impact
        (learned codecs), the whole-list bound otherwise."""
        g = self._sh.guided
        tm = g.term_model(t) if g is not None else None
        if tm is None:
            return np.full(len(cands), self._store.term_ub(t), np.int64)
        seg = np.searchsorted(tm.seg_first, np.asarray(cands, np.int64), side="right") - 1
        ubs = self._store.term_seg_ubs(t).astype(np.int64)
        out = ubs[np.maximum(seg, 0)]
        out[seg < 0] = 0  # candidate precedes the whole list: cannot match
        return out
