"""Batched Boolean-query serving engine — the paper's system, deployable form.

Pipeline per batch of queries (pad-to-bucket batching):
  1. algorithm from LearnedIndexConfig: exhaustive | two_tier | block;
  2. learned-Bloom scoring (zero false negatives) produces candidate masks;
  3. optional `verified` mode re-checks candidates against the exact tier-2
     postings (the paper's fallback structure) -> exact conjunctive results.
     Verification is *model-guided*: terms are visited smallest-list-first,
     and learned-codec terms answer contains() probes straight from PLM/RMI
     stream metadata (predict rank, decode only the ±ε correction window —
     repro.postings.search), so the hot path reads ε-window bytes instead of
     whole compressed lists.  Classical-codec terms fall back to full decode
     through a decode-cost-budgeted LRU cache, membership via galloping
     search (index/intersect.py);
  4. results returned as packed bitmaps (32x cheaper to move than id lists)
     plus materialized doc ids per query.

The Pallas membership kernel (kernels/membership) is used for the doc-scan
algorithms when `use_kernel=True`; the guided-probe batches can run on the
kernels/guided_search Pallas kernel with `guided_kernel=True` (pure
numpy/jnp paths are the references).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.common.config import LearnedIndexConfig
from repro.core import algorithms as alg
from repro.core.learned_bloom import LearnedBloom
from repro.index.build import InvertedIndex
from repro.index.intersect import gallop_membership
from repro.kernels.membership.ops import score_terms_bitmask
from repro.serve.cache import CostLRU


@dataclass
class ServeConfig:
    algorithm: str = "block"
    verified: bool = True
    use_kernel: bool = False
    max_query_terms: int = 8
    postings_store: str = "hybrid"  # tier-2 backing: "hybrid" (compressed) | "raw"
    use_guided: bool = True  # model-guided contains() probes for learned terms
    guided_kernel: bool = False  # batch probes on the Pallas guided_search kernel
    cache_budget_bytes: int = 32 << 20  # decode-cost budget of the tier-2 LRU


class BooleanEngine:
    def __init__(
        self,
        lb: LearnedBloom,
        inv: InvertedIndex,
        li_cfg: LearnedIndexConfig,
        cfg: ServeConfig | None = None,
    ):
        self.cfg = cfg or ServeConfig()
        self.inv = inv
        self.lb = lb
        self._tier2 = None  # lazy HybridPostings (built on first verification)
        self._guided = None  # lazy GuidedPostings over tier-2
        self._dfs = inv.dfs  # materialized once; _verify sorts terms by df per query
        self._decode_cache: CostLRU[int, np.ndarray] = CostLRU(self.cfg.cache_budget_bytes)
        self.state = alg.build_engine(
            lb.params, lb.tau, inv,
            truncation_k=li_cfg.truncation_k, block_size=li_cfg.block_size,
        )

    @property
    def tier2(self):
        """Compressed tier-2 postings store (hybrid per-term codec choice)."""
        if self._tier2 is None and self.cfg.postings_store == "hybrid":
            from repro.postings import HybridPostings

            self._tier2 = HybridPostings.from_index(self.inv)
        return self._tier2

    @property
    def guided(self):
        """Model-guided prober over tier-2 (None when serving raw postings)."""
        if self._guided is None:
            store = self.tier2
            if store is not None and self.cfg.use_guided:
                from repro.postings import GuidedPostings

                self._guided = GuidedPostings(
                    store, fallback=self._postings, use_kernel=self.cfg.guided_kernel
                )
        return self._guided

    def _postings(self, t: int) -> np.ndarray:
        """Fully-decoded postings of term t, via the cost-budgeted LRU."""
        store = self.tier2
        if store is None:
            return self.inv.postings(t)
        hit = self._decode_cache.get(t)
        if hit is None:
            hit = store.postings(t)
            self._decode_cache.put(t, hit, hit.nbytes)
        return hit

    # ------------------------------------------------------------- query
    def query_batch(self, queries: np.ndarray) -> list[np.ndarray]:
        """(Q, T) padded term ids -> list of result doc-id arrays."""
        q = np.asarray(queries, dtype=np.int32)
        if q.shape[1] < self.cfg.max_query_terms:
            q = np.pad(q, ((0, 0), (0, self.cfg.max_query_terms - q.shape[1])),
                       constant_values=-1)
        if self.cfg.use_kernel and self.cfg.algorithm == "exhaustive":
            mask = self._kernel_exhaustive(q)
        else:
            mask = alg.run_queries(self.state, q, self.cfg.algorithm)
        results = []
        for i in range(q.shape[0]):
            ids = np.nonzero(mask[i])[0].astype(np.int32)
            if self.cfg.verified:
                ids = self._verify(q[i], ids)
            results.append(ids)
        return results

    def _kernel_exhaustive(self, q: np.ndarray) -> np.ndarray:
        """Pallas path: per-term packed bitmasks, AND-combined per query."""
        valid = q >= 0
        flat_terms = jnp.asarray(np.maximum(q, 0).reshape(-1))
        bm = score_terms_bitmask(self.state.params, flat_terms, self.state.tau)
        bm = np.array(bm).reshape(q.shape[0], q.shape[1], -1)  # writable copy
        full = np.uint32(0xFFFFFFFF)
        bm[~valid] = full
        anded = bm[:, 0]
        for t in range(1, q.shape[1]):
            anded = anded & bm[:, t]
        # unpack to bool (D,)
        bits = np.unpackbits(
            anded.view(np.uint8), axis=-1, bitorder="little"
        )[:, : self.state.n_docs].astype(bool)
        bits[~valid.any(axis=1)] = False
        return bits

    def _verify(self, query: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Exact candidate re-check against tier-2, smallest list first.

        Visiting terms in ascending document frequency shrinks the candidate
        set fastest; each term then filters the (sorted) survivors either by
        guided ε-window probes (learned-codec terms) or by galloping search
        over the fully-decoded list (classical codecs / raw store).
        """
        out = ids
        terms = sorted({int(t) for t in query if t >= 0})  # dedupe repeats
        if not terms or len(out) == 0:
            return out
        dfs = self._dfs
        terms.sort(key=lambda t: int(dfs[t]))
        if int(dfs[terms[0]]) == 0:  # some term occurs nowhere: empty AND
            return out[:0]
        guided = self.guided
        for t in terms:
            if len(out) == 0:
                break
            if guided is not None:
                out = out[guided.contains(t, out)]
            else:
                out = out[gallop_membership(self._postings(t), out)]
        return out

    # ------------------------------------------------------------- stats
    def memory_report(self) -> dict[str, int]:
        """Bits used by each component (feeds the Eq.(2) comparison)."""
        s = self.state
        report = {
            "model_bits": self.lb.size_bits(),
            "tier1_bits": int(s.tier1.size * 32),
            "block_bitmap_bits": int(s.block_bitmaps.size * 32),
            "backup_bits": int(self.lb.backup_keys.size * 64),
        }
        if self._tier2 is not None:
            report["tier2_bits"] = self._tier2.size_bits()
        return report

    def serving_stats(self) -> dict[str, dict]:
        """Hot-path accounting: decode-cache behaviour + guided-probe bytes."""
        stats: dict[str, dict] = {"decode_cache": self._decode_cache.stats()}
        if self._guided is not None:
            stats["guided"] = self._guided.stats.as_dict()
        return stats
