"""Batched Boolean-query serving engine — the paper's system, deployable form.

Pipeline per batch of queries (pad-to-bucket batching):
  1. algorithm from LearnedIndexConfig: exhaustive | two_tier | block;
  2. learned-Bloom scoring (zero false negatives) produces candidate masks;
  3. optional `verified` mode re-checks candidates against the exact tier-2
     postings (the paper's fallback structure) -> exact conjunctive results;
  4. results returned as packed bitmaps (32x cheaper to move than id lists)
     plus materialized doc ids per query.

The Pallas membership kernel (kernels/membership) is used for the doc-scan
algorithms when `use_kernel=True`; the pure-jnp path is the reference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import LearnedIndexConfig
from repro.core import algorithms as alg
from repro.core.learned_bloom import LearnedBloom
from repro.index.build import InvertedIndex
from repro.kernels.membership.ops import score_terms_bitmask


@dataclass
class ServeConfig:
    algorithm: str = "block"
    verified: bool = True
    use_kernel: bool = False
    max_query_terms: int = 8


class BooleanEngine:
    def __init__(
        self,
        lb: LearnedBloom,
        inv: InvertedIndex,
        li_cfg: LearnedIndexConfig,
        cfg: ServeConfig | None = None,
    ):
        self.cfg = cfg or ServeConfig()
        self.inv = inv
        self.lb = lb
        self.state = alg.build_engine(
            lb.params, lb.tau, inv,
            truncation_k=li_cfg.truncation_k, block_size=li_cfg.block_size,
        )

    # ------------------------------------------------------------- query
    def query_batch(self, queries: np.ndarray) -> list[np.ndarray]:
        """(Q, T) padded term ids -> list of result doc-id arrays."""
        q = np.asarray(queries, dtype=np.int32)
        if q.shape[1] < self.cfg.max_query_terms:
            q = np.pad(q, ((0, 0), (0, self.cfg.max_query_terms - q.shape[1])),
                       constant_values=-1)
        if self.cfg.use_kernel and self.cfg.algorithm == "exhaustive":
            mask = self._kernel_exhaustive(q)
        else:
            mask = alg.run_queries(self.state, q, self.cfg.algorithm)
        results = []
        for i in range(q.shape[0]):
            ids = np.nonzero(mask[i])[0].astype(np.int32)
            if self.cfg.verified:
                ids = self._verify(q[i], ids)
            results.append(ids)
        return results

    def _kernel_exhaustive(self, q: np.ndarray) -> np.ndarray:
        """Pallas path: per-term packed bitmasks, AND-combined per query."""
        valid = q >= 0
        flat_terms = jnp.asarray(np.maximum(q, 0).reshape(-1))
        bm = score_terms_bitmask(self.state.params, flat_terms, self.state.tau)
        bm = np.array(bm).reshape(q.shape[0], q.shape[1], -1)  # writable copy
        full = np.uint32(0xFFFFFFFF)
        bm[~valid] = full
        anded = bm[:, 0]
        for t in range(1, q.shape[1]):
            anded = anded & bm[:, t]
        # unpack to bool (D,)
        bits = np.unpackbits(
            anded.view(np.uint8), axis=-1, bitorder="little"
        )[:, : self.state.n_docs].astype(bool)
        bits[~valid.any(axis=1)] = False
        return bits

    def _verify(self, query: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Exact re-check against tier-2 postings (paper's fallback)."""
        out = ids
        for t in query:
            if t < 0 or len(out) == 0:
                continue
            p = self.inv.postings(int(t))
            sel = np.searchsorted(p, out)
            sel = np.clip(sel, 0, len(p) - 1)
            out = out[p[sel] == out]
        return out

    # ------------------------------------------------------------- stats
    def memory_report(self) -> dict[str, int]:
        """Bits used by each component (feeds the Eq.(2) comparison)."""
        s = self.state
        return {
            "model_bits": self.lb.size_bits(),
            "tier1_bits": int(s.tier1.size * 32),
            "block_bitmap_bits": int(s.block_bitmaps.size * 32),
            "backup_bits": int(self.lb.backup_keys.size * 64),
        }
