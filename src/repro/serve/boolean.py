"""Batched Boolean-query serving engine — doc-partitioned planner/executor.

The paper's system in deployable form, refactored into three layers:

  1. **plan** (serve/planner.py) — a query batch becomes per-shard probe
     plans: smallest-global-df term ordering, per-shard run masks (a shard
     skips conjunctions provably empty on its partition), and cost-model
     routes pinning each learned-codec term to guided ε-window probes or
     full decode;
  2. **execute** (serve/shard.py) — K document-partitioned ShardEngines,
     each owning its learned-Bloom slice, guided-probe TermModels and
     decode-cost-budgeted CostLRU, serve their plan (one candidate-mask
     dispatch + one guided probe batch per shard) and return packed result
     bitmaps over local doc ids.  Parallel shard execution belongs to the
     continuous-batching scheduler (serve/sched): its Session dispatches
     per-shard work to process-replica groups, which is what removed the
     retired thread pool's ~8x GIL convoy at K=4;
  3. **merge** — shard bitmaps word-copy into the global bitmap at their
     doc-id offset (shard boundaries are 32-aligned), then materialize to
     per-query sorted doc-id arrays.

``BooleanEngine`` is the thin facade over all three.  K=1 reproduces the
unsharded engine bit-for-bit; engines can also start from the persistent
shard-store (index/store.py) via ``from_store`` — no re-encoding, stream
bytes page in lazily via mmap.

``query_topk`` is the ranked path over the same shards: the planner dedupes
terms and computes per-shard run masks, each ShardEngine returns its local
top-k by MaxScore dynamic pruning over the tier-2 payload streams, and the
facade folds shard heaps in ascending doc-range order, forwarding the
running k-th best score as the next shard's pruning floor.  Scores are
integer quantized-impact sums with ties broken by ascending doc id, so the
merged top-k is bit-identical for K=1 and any K>1 — and to the brute-force
BM25 oracle (rank.score.brute_force_topk).
"""
from __future__ import annotations

import time
import warnings

import numpy as np

from repro.common.config import LearnedIndexConfig
from repro.core.learned_bloom import LearnedBloom
from repro.index.build import InvertedIndex
from repro.obs import trace
from repro.obs.metrics import Registry
from repro.obs.trace import NULL_SPAN
from repro.postings.search import ProbeStats
from repro.rank.score import BM25Params, ImpactModel, TopKResult, select_topk
from repro.rank.topk import RankedStats
from repro.serve.config import ObsConfig, RankedConfig, SchedConfig, ServeConfig
from repro.serve.planner import BatchPlan, plan_batch, plan_ranked, ranked_run_mask
from repro.serve.shard import WORD_BITS, ShardEngine, shard_ranges, slice_bloom, unpack_row

__all__ = [
    "BooleanEngine",
    "ObsConfig",
    "RankedConfig",
    "SchedConfig",
    "ServeConfig",
]


class BooleanEngine:
    """Facade: plans a batch, fans it out across shards, merges bitmaps."""

    def __init__(
        self,
        lb: LearnedBloom,
        inv: InvertedIndex | None,
        li_cfg: LearnedIndexConfig,
        cfg: ServeConfig | None = None,
        *,
        shards: list[tuple[tuple[int, int], ShardEngine | None]] | None = None,
    ):
        self.cfg = cfg or ServeConfig()
        self.lb = lb
        self.inv = inv
        self.li_cfg = li_cfg
        self.n_docs = lb.n_docs
        self._impact_model = None
        can_rank = (
            self.cfg.ranked.enabled
            and inv is not None
            and inv.tfs is not None
            and self.cfg.postings_store == "hybrid"
        )
        # shards get the *provider*, not the model: quantizer fitting is an
        # O(n_postings) float64 pass that Boolean-only serving never needs,
        # so it runs at first ranked use (ensure_payloads), not construction
        provider = self._build_impact_model if can_rank else None
        if shards is None:
            if inv is None:
                raise ValueError("need an InvertedIndex (or prebuilt shards)")
            shards = [
                (
                    (lo, hi),
                    ShardEngine.from_range(
                        lb, inv, li_cfg, self.cfg, lo, hi,
                        impact_model=provider,
                    )
                    if hi > lo else None,
                )
                for lo, hi in shard_ranges(inv.n_docs, self.cfg.n_shards)
            ]
        self._ranges = [r for r, _ in shards]
        self._shards = [s for _, s in shards]
        active = self.shards
        for sid, sh in enumerate(active):
            sh.shard_id = sid
        if inv is not None:
            self._global_dfs = inv.dfs
        else:
            self._global_dfs = sum((s.local_dfs for s in active), start=0)
        # one registry per facade: primitives (query counters, per-phase
        # latency histograms) plus collectors aggregating the shards
        obs = self.cfg.obs
        self.metrics = obs.metrics if obs.metrics is not None else Registry()
        self._ranked_queries = self.metrics.counter("queries.ranked")
        self._boolean_queries = self.metrics.counter("queries.boolean")
        self._register_collectors()

    def _build_impact_model(self) -> ImpactModel:
        """Fit (once) the collection-global quantizer: every shard's payload
        stream is then a bit-exact slice of the global one (rank/score.py)."""
        if self._impact_model is None:
            self._impact_model = ImpactModel.build(
                self.inv, BM25Params(bits=self.cfg.ranked.payload_bits)
            )
        return self._impact_model

    @property
    def impact_model(self) -> ImpactModel | None:
        """The fitted global quantizer, or None for engines that cannot rank
        from live arrays (no tfs / raw store / loaded-store payloads)."""
        return self._impact_model

    @classmethod
    def from_store(
        cls,
        lb: LearnedBloom,
        li_cfg: LearnedIndexConfig,
        cfg: ServeConfig | None,
        index_dir: str,
        *,
        mmap: bool = True,
    ) -> "BooleanEngine":
        """Start from a persistent shard-store: no re-encoding, lazy streams."""
        from repro.index.store import load_sharded

        cfg = cfg or ServeConfig()
        n_docs, entries = load_sharded(index_dir, mmap=mmap)
        if n_docs != lb.n_docs:
            raise ValueError(f"store has {n_docs} docs, model {lb.n_docs}")
        shards = [
            (
                (lo, hi),
                ShardEngine(
                    slice_bloom(lb, lo, hi), inv, li_cfg, cfg,
                    lo=lo, hi=hi, tier2=store,
                )
                if inv is not None else None,
            )
            for (lo, hi), inv, store in entries
        ]
        return cls(lb, None, li_cfg, cfg, shards=shards)

    def save(self, index_dir: str) -> None:
        """Persist every shard's index + compressed store (build-then-serve).

        Forces tier-2 builds (hybrid codec selection) so the saved layout is
        complete; a reloaded engine never re-encodes.
        """
        from repro.index.store import save_sharded

        if self.cfg.postings_store != "hybrid":
            raise ValueError("only the hybrid postings store is persistable")
        for sh in self.shards:
            sh.ensure_payloads()  # the saved layout carries the ranked tier
        entries = [
            ((lo, hi), sh.inv if sh else None, sh.tier2 if sh else None)
            for (lo, hi), sh in zip(self._ranges, self._shards)
        ]
        save_sharded(index_dir, self.n_docs, entries)

    # ------------------------------------------------------------- shards
    @property
    def shards(self) -> list[ShardEngine]:
        """Non-empty shard executors, ascending doc range."""
        return [s for s in self._shards if s is not None]

    @property
    def n_shards(self) -> int:
        return len(self._ranges)

    @property
    def tier2(self):
        """K=1 convenience: the single shard's compressed tier-2 store."""
        active = self.shards
        return active[0].tier2 if len(active) == 1 else None

    # ------------------------------------------------------------- query
    def query_batch(self, queries: np.ndarray) -> list[np.ndarray]:
        """(Q, T) padded term ids -> list of result doc-id arrays."""
        q = self._padded(queries)
        if q.shape[0] == 0:
            return []
        if (q < 0).all():  # all-padding batch: empty without touching a probe
            return [np.zeros(0, np.int32) for _ in range(q.shape[0])]
        bitmap = self._execute(q)
        return [unpack_row(bitmap[i], self.n_docs) for i in range(q.shape[0])]

    def _observe_us(self, name: str, t0_ns: int) -> None:
        self.metrics.histogram("latency." + name).observe(
            (time.perf_counter_ns() - t0_ns) / 1e3
        )

    def query_batch_bitmap(self, queries: np.ndarray) -> np.ndarray:
        """(Q, T) padded term ids -> (Q, ceil(n_docs/32)) packed uint32 bitmap."""
        q = self._padded(queries)
        words = (self.n_docs + WORD_BITS - 1) // WORD_BITS
        if q.shape[0] == 0 or (q < 0).all():
            return np.zeros((q.shape[0], words), dtype=np.uint32)
        return self._execute(q)

    def query_topk(
        self,
        queries: np.ndarray,
        k: int = 10,
        *,
        mode: str = "or",
        required: np.ndarray | None = None,
    ) -> list[TopKResult]:
        """(Q, T) padded term ids -> exact ranked top-k per query.

        ``mode`` "or" scores any matching term (disjunctive), "and" requires
        every term; a boolean ``required`` mask of queries' shape marks a
        per-position required subset for mixed AND/OR.  Results order by
        (score desc, doc id asc) and are bit-identical across shard counts
        and to brute-force quantized-BM25 over decoded postings.
        """
        q = np.asarray(queries, dtype=np.int32)
        if q.ndim != 2:
            raise ValueError(f"queries must be (Q, T), got shape {q.shape}")
        empty = TopKResult(ids=np.zeros(0, np.int32), scores=np.zeros(0, np.int64))
        if k <= 0:
            return [empty for _ in range(q.shape[0])]
        self._ranked_queries.inc(int(q.shape[0]))
        log = self.cfg.obs.probe_log
        active = self.shards
        out: list[TopKResult] = []
        with trace.activate(self.cfg.obs.trace), \
                trace.span("serve.topk_batch", queries=int(q.shape[0]), k=int(k)):
            with trace.span("serve.plan"):
                qplans = plan_ranked(q, self._global_dfs, mode=mode, required=required)
                runs = [ranked_run_mask(qplans, sh.local_dfs) for sh in active]
            # a shard whose run mask is all-empty contributes nothing to any
            # heap: drop it here instead of re-deriving floors against it
            live = [(sh, run) for sh, run in zip(active, runs) if run.any()]
            if self.cfg.ranked.fused_kernel:
                return self._query_topk_fused(qplans, live, k, empty)
            for i, qp in enumerate(qplans):
                if qp.dead:
                    out.append(empty)
                    continue
                t_query = time.perf_counter_ns()
                heap = empty
                # ascending doc ranges + ascending-id tie break make the floor
                # a strict bar: a later shard's tie can never displace the heap
                for sh, run in live:
                    if not run[i]:
                        continue
                    floor = int(heap.scores[k - 1]) if len(heap.scores) == k else 0
                    ctx = (log.context(query=i, shard=sh.shard_id)
                           if log is not None else NULL_SPAN)
                    with ctx:
                        part = sh.query_topk_local(
                            qp.terms, k, required=qp.required, floor=floor
                        )
                    if len(part.ids) == 0:
                        continue
                    with trace.span("serve.heap_merge", query=i, shard=sh.shard_id):
                        heap = select_topk(
                            np.concatenate([heap.ids, part.ids]),
                            np.concatenate([heap.scores, part.scores]),
                            k,
                        )
                self._observe_us("topk_query_us", t_query)
                out.append(heap)
        return out

    def _query_topk_fused(self, qplans, live, k: int, empty) -> list[TopKResult]:
        """Fused-kernel ranked execution: shards outer, one batched dispatch
        per shard (``shard.query_topk_batch``), heap floors forwarded between
        shards exactly as the per-query loop does — shard doc ranges ascend,
        so each shard sees the floors the previous shards established.
        Bit-identical to the multi-phase loop (asserted in tests/benchmarks).
        """
        t_batch = time.perf_counter_ns()
        heaps = [empty] * len(qplans)
        n_live_q = sum(1 for qp in qplans if not qp.dead)
        for sh, run in live:
            idx = [i for i, qp in enumerate(qplans) if not qp.dead and run[i]]
            if not idx:
                continue
            items = []
            for i in idx:
                floor = (int(heaps[i].scores[k - 1])
                         if len(heaps[i].scores) == k else 0)
                items.append((qplans[i].terms, k, qplans[i].required, floor))
            parts = sh.query_topk_batch(items)
            for i, part in zip(idx, parts):
                if len(part.ids) == 0:
                    continue
                with trace.span("serve.heap_merge", query=i, shard=sh.shard_id):
                    heaps[i] = select_topk(
                        np.concatenate([heaps[i].ids, part.ids]),
                        np.concatenate([heaps[i].scores, part.scores]),
                        k,
                    )
        if n_live_q:  # batch wall spread over queries: same metric, one pass
            per_q = (time.perf_counter_ns() - t_batch) // n_live_q
            for _ in range(n_live_q):
                self._observe_us("topk_query_us", time.perf_counter_ns() - per_q)
        return heaps

    def _padded(self, queries: np.ndarray) -> np.ndarray:
        q = np.asarray(queries, dtype=np.int32)
        if q.ndim != 2:
            raise ValueError(f"queries must be (Q, T), got shape {q.shape}")
        if q.shape[1] < self.cfg.max_query_terms:
            q = np.pad(q, ((0, 0), (0, self.cfg.max_query_terms - q.shape[1])),
                       constant_values=-1)
        return q

    def _execute(self, q: np.ndarray) -> np.ndarray:
        """Plan, fan out across shards, merge packed bitmaps by doc offset.

        Two phases per the executor contract: learned-Bloom candidate masks
        are one jit dispatch per shard, issued serially (concurrent dispatch
        contends on the device client); the probe/verify phase — guided
        ε-window probes and cache decodes, pure numpy — runs shard by shard
        on the calling thread.  Parallel shard execution lives one level up:
        serve.sched.Session dispatches to process replicas (no GIL convoy,
        the retired ThreadPoolExecutor's measured ~8x slowdown at K=4).
        """
        active = self.shards
        t_batch = time.perf_counter_ns()
        self._boolean_queries.inc(int(q.shape[0]))
        with trace.activate(self.cfg.obs.trace), \
                trace.span("serve.batch", queries=int(q.shape[0]),
                           shards=len(active)):
            t0 = time.perf_counter_ns()
            with trace.span("serve.plan"):
                plan = plan_batch(q, self._global_dfs, active,
                                  verified=self.cfg.verified)
            self._observe_us("plan_us", t0)
            t0 = time.perf_counter_ns()
            masks = []
            for sh, sp in zip(active, plan.shard_plans):
                if sh.n_docs > 0 and sp.run.any():
                    with trace.span("serve.candidate_mask", shard=sh.shard_id):
                        masks.append(sh.candidate_mask(q))
                else:
                    masks.append(None)
            self._observe_us("mask_us", t0)
            t0 = time.perf_counter_ns()
            parts = []
            for sh, sp, m in zip(active, plan.shard_plans, masks):
                with trace.span("serve.probe_phase", shard=sh.shard_id):
                    parts.append(sh.execute(q, sp, plan.qplans, mask=m))
            self._observe_us("probe_us", t0)
            t0 = time.perf_counter_ns()
            with trace.span("serve.merge"):
                out = self._merge(parts, active)
            self._observe_us("merge_us", t0)
        # per-query latency at batch granularity: each query is charged the
        # batch mean, so histogram counts tally queries and percentiles
        # weight batches by their size (batch-of-1 harnesses record the true
        # per-query wall)
        n_q = max(int(q.shape[0]), 1)
        us = (time.perf_counter_ns() - t_batch) / 1e3 / n_q
        hist = self.metrics.histogram("latency.query_us")
        for _ in range(n_q):
            hist.observe(us)
        return out

    def _merge(self, parts: list[np.ndarray], active: list[ShardEngine]) -> np.ndarray:
        """Word-copy each shard's packed bitmap at its doc-id offset (shard
        boundaries are 32-aligned, so no cross-shard bit arithmetic)."""
        n_queries = parts[0].shape[0] if parts else 0
        out = np.zeros((n_queries, (self.n_docs + WORD_BITS - 1) // WORD_BITS), np.uint32)
        for sh, bm in zip(active, parts):
            off = sh.lo // WORD_BITS
            out[:, off : off + bm.shape[1]] = bm
        return out

    # ------------------------------------------------------------- stats
    def memory_report(self) -> dict[str, int]:
        """Bits used by each component (feeds the Eq.(2) comparison);
        dense-state and tier-2 bits summed over shards."""
        report = {
            "model_bits": self.lb.size_bits(),
            "tier1_bits": 0,
            "block_bitmap_bits": 0,
            "backup_bits": int(self.lb.backup_keys.size * 64),
        }
        tier2_bits = payload_bits = None
        for sh in self.shards:
            bits = sh.memory_bits()
            report["tier1_bits"] += bits["tier1_bits"]
            report["block_bitmap_bits"] += bits["block_bitmap_bits"]
            if "tier2_bits" in bits:
                tier2_bits = (tier2_bits or 0) + bits["tier2_bits"]
            if "payload_bits" in bits:
                payload_bits = (payload_bits or 0) + bits["payload_bits"]
        if tier2_bits is not None:
            report["tier2_bits"] = tier2_bits
        if payload_bits is not None:
            report["payload_bits"] = payload_bits
        return report

    def _register_collectors(self) -> None:
        """Aggregating collectors over the shards, all read through one
        ``Registry.snapshot()`` (the keys serving_stats always reported)."""
        reg = self.metrics
        reg.register("decode_cache", self._collect_cache)
        reg.register(
            "shards",
            lambda: [sh.serving_stats() for sh in self.shards],
            reset=lambda: [sh.reset_stats() for sh in self.shards],
        )
        reg.register("guided", self._collect_guided)
        reg.register("ranked", self._collect_ranked)
        reg.register("summary", self._collect_summary)

    def _collect_cache(self) -> dict[str, int]:
        keys = ("entries", "cost_bytes", "budget_bytes", "hits", "misses", "evictions")
        per = [sh._decode_cache.stats() for sh in self.shards]
        return {k: sum(s[k] for s in per) for k in keys}

    def _collect_guided(self) -> dict | None:
        """'guided' keeps the single-engine shape: counters summed across
        shards, ratios recomputed by ProbeStats.as_dict."""
        per = [sh._guided.stats for sh in self.shards if sh._guided is not None]
        if not per:
            return None
        return ProbeStats(**{
            f: sum(int(getattr(g, f)) for g in per)
            for f in ("probes", "guided_terms", "fallback_terms", "routed_terms",
                      "window_bytes", "metadata_bytes", "fallback_bytes",
                      "full_equiv_bytes")
        }).as_dict()

    def _collect_ranked(self) -> dict | None:
        per = [sh.ranked_stats for sh in self.shards if sh.ranked_stats.queries]
        if not per:
            return None
        agg = RankedStats(**{
            f: sum(int(getattr(r, f)) for r in per)
            for f in ("queries", "exhaustive_queries", "scored_postings",
                      "probed_postings", "exhaustive_postings",
                      "fused_queries", "fused_lanes", "fused_stream_bytes",
                      "fused_device_bytes", "fused_kernel_ns",
                      "fused_bridge_ns")
        }).as_dict()
        # shard counters tally (query, shard) pairs; report the facade's
        # query count on top so per-query averages come out right
        agg["shard_queries"] = agg.pop("queries")
        agg["queries"] = self._ranked_queries.value
        return agg

    def _collect_summary(self) -> dict:
        """The one-number view benchmarks report (stable legacy keys)."""
        cache = self._collect_cache()
        guided = self._collect_guided()
        ranked = self._collect_ranked()
        return {
            "n_shards": len(self.shards),
            "cache_hits": cache["hits"],
            "cache_misses": cache["misses"],
            "cache_evictions": cache["evictions"],
            "probe_bytes": guided["guided_bytes"] if guided else 0,
            "bytes_ratio": guided["bytes_ratio"] if guided else 0.0,
            "scored_fraction": ranked["scored_fraction"] if ranked else 0.0,
        }

    def serving_stats(self) -> dict[str, dict]:
        """Deprecated: one snapshot of the facade metrics registry.

        Kept as a thin wrapper so existing callers see the same shape
        ('decode_cache', 'shards', 'guided', 'ranked', 'summary' — plus the
        registry's own 'queries' counters and 'latency' histograms).  New
        code should read ``engine.metrics.snapshot()`` directly.
        """
        warnings.warn(
            "serving_stats() is deprecated; read engine.metrics.snapshot()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.metrics.snapshot()

    def reset_stats(self) -> None:
        """Zero every accounting window through the metrics registry: facade
        counters/histograms reset, and each shard's public reset_stats()
        zeroes its own guided/ranked/cache state (cached decodes stay
        resident, so the next pass measures warm serving)."""
        self.metrics.reset()
