"""Batched Boolean-query serving engine — the paper's system, deployable form.

Pipeline per batch of queries (pad-to-bucket batching):
  1. algorithm from LearnedIndexConfig: exhaustive | two_tier | block;
  2. learned-Bloom scoring (zero false negatives) produces candidate masks;
  3. optional `verified` mode re-checks candidates against the exact tier-2
     postings (the paper's fallback structure) -> exact conjunctive results.
     Tier-2 is served from the hybrid learned/classical compressed store
     (repro.postings.HybridPostings, built lazily on first verification) so
     the fallback pays min-bits storage, not raw int32 arrays;
  4. results returned as packed bitmaps (32x cheaper to move than id lists)
     plus materialized doc ids per query.

The Pallas membership kernel (kernels/membership) is used for the doc-scan
algorithms when `use_kernel=True`; the pure-jnp path is the reference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import LearnedIndexConfig
from repro.core import algorithms as alg
from repro.core.learned_bloom import LearnedBloom
from repro.index.build import InvertedIndex
from repro.kernels.membership.ops import score_terms_bitmask


@dataclass
class ServeConfig:
    algorithm: str = "block"
    verified: bool = True
    use_kernel: bool = False
    max_query_terms: int = 8
    postings_store: str = "hybrid"  # tier-2 backing: "hybrid" (compressed) | "raw"


class BooleanEngine:
    def __init__(
        self,
        lb: LearnedBloom,
        inv: InvertedIndex,
        li_cfg: LearnedIndexConfig,
        cfg: ServeConfig | None = None,
    ):
        self.cfg = cfg or ServeConfig()
        self.inv = inv
        self.lb = lb
        self._tier2 = None  # lazy HybridPostings (built on first verification)
        self._decode_cache: dict[int, np.ndarray] = {}  # FIFO, _CACHE_TERMS max
        self.state = alg.build_engine(
            lb.params, lb.tau, inv,
            truncation_k=li_cfg.truncation_k, block_size=li_cfg.block_size,
        )

    @property
    def tier2(self):
        """Compressed tier-2 postings store (hybrid per-term codec choice)."""
        if self._tier2 is None and self.cfg.postings_store == "hybrid":
            from repro.postings import HybridPostings

            self._tier2 = HybridPostings.from_index(self.inv)
        return self._tier2

    _CACHE_TERMS = 1024  # hot-term decoded lists kept resident

    def _postings(self, t: int) -> np.ndarray:
        store = self.tier2
        if store is None:
            return self.inv.postings(t)
        hit = self._decode_cache.get(t)
        if hit is None:
            hit = store.postings(t)
            if len(self._decode_cache) >= self._CACHE_TERMS:  # FIFO eviction
                self._decode_cache.pop(next(iter(self._decode_cache)))
            self._decode_cache[t] = hit
        return hit

    # ------------------------------------------------------------- query
    def query_batch(self, queries: np.ndarray) -> list[np.ndarray]:
        """(Q, T) padded term ids -> list of result doc-id arrays."""
        q = np.asarray(queries, dtype=np.int32)
        if q.shape[1] < self.cfg.max_query_terms:
            q = np.pad(q, ((0, 0), (0, self.cfg.max_query_terms - q.shape[1])),
                       constant_values=-1)
        if self.cfg.use_kernel and self.cfg.algorithm == "exhaustive":
            mask = self._kernel_exhaustive(q)
        else:
            mask = alg.run_queries(self.state, q, self.cfg.algorithm)
        results = []
        for i in range(q.shape[0]):
            ids = np.nonzero(mask[i])[0].astype(np.int32)
            if self.cfg.verified:
                ids = self._verify(q[i], ids)
            results.append(ids)
        return results

    def _kernel_exhaustive(self, q: np.ndarray) -> np.ndarray:
        """Pallas path: per-term packed bitmasks, AND-combined per query."""
        valid = q >= 0
        flat_terms = jnp.asarray(np.maximum(q, 0).reshape(-1))
        bm = score_terms_bitmask(self.state.params, flat_terms, self.state.tau)
        bm = np.array(bm).reshape(q.shape[0], q.shape[1], -1)  # writable copy
        full = np.uint32(0xFFFFFFFF)
        bm[~valid] = full
        anded = bm[:, 0]
        for t in range(1, q.shape[1]):
            anded = anded & bm[:, t]
        # unpack to bool (D,)
        bits = np.unpackbits(
            anded.view(np.uint8), axis=-1, bitorder="little"
        )[:, : self.state.n_docs].astype(bool)
        bits[~valid.any(axis=1)] = False
        return bits

    def _verify(self, query: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Exact re-check against tier-2 postings (paper's fallback)."""
        out = ids
        for t in query:
            if t < 0 or len(out) == 0:
                continue
            p = self._postings(int(t))
            if len(p) == 0:  # term occurs nowhere: conjunction is empty
                return out[:0]
            sel = np.searchsorted(p, out)
            sel = np.clip(sel, 0, len(p) - 1)
            out = out[p[sel] == out]
        return out

    # ------------------------------------------------------------- stats
    def memory_report(self) -> dict[str, int]:
        """Bits used by each component (feeds the Eq.(2) comparison)."""
        s = self.state
        report = {
            "model_bits": self.lb.size_bits(),
            "tier1_bits": int(s.tier1.size * 32),
            "block_bitmap_bits": int(s.block_bitmaps.size * 32),
            "backup_bits": int(self.lb.backup_keys.size * 64),
        }
        if self._tier2 is not None:
            report["tier2_bits"] = self._tier2.size_bits()
        return report
