"""Serving configuration: one ServeConfig, three nested sub-configs.

``ServeConfig`` grew one flat flag per subsystem until the scheduler would
have added a tenth; the knobs now group by the component that reads them:

  * ``ServeConfig.obs``    — observability handles (span tracer, metrics
    registry, probe log); repro.obs reads these and nothing else does;
  * ``ServeConfig.ranked`` — the ranked (top-k) tier: payload quantization,
    MaxScore exhaustive cutoff, Pallas scorer;
  * ``ServeConfig.sched``  — the continuous-batching scheduler
    (serve/sched): batch coalescing, admission bounds, tenant quotas,
    deadlines, process-replica fan-out.

Engine-core flags (algorithm, verification, sharding, guided probes, cache
budget) stay top-level — every layer reads them.

Backwards compatibility: the old flat kwargs (``ServeConfig(trace=...,
payload_bits=4, ranked=False)``) are still accepted — they land in the right
sub-config and raise a ``DeprecationWarning`` — and the old flat attributes
remain readable/writable as properties forwarding to the sub-configs, so
``eng.cfg.trace = tracer`` keeps working.  ``shard_workers`` (the retired
thread-pool fan-out, superseded by ``sched.n_replicas`` process replicas) is
accepted and ignored with a warning.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # handles only; never imported at runtime from here
    from repro.obs.metrics import Registry
    from repro.obs.probelog import ProbeLog
    from repro.obs.slo import SLOMonitor
    from repro.obs.trace import Tracer


@dataclass
class ObsConfig:
    """Observability handles (all opt-in; None costs ~nothing).

    With a tracer and/or probe log installed, the scheduler forwards a
    TraceContext to process replicas, which ship their span buffers and
    probe records back with each response — the handles below then cover
    the distributed path too, no extra plumbing.
    """

    trace: "Tracer | None" = None  # span tracer, active for every served batch
    metrics: "Registry | None" = None  # facade registry (engine creates one if None)
    probe_log: "ProbeLog | None" = None  # per-(query, term, shard) probe JSONL
    # rotate a file-backed probe log past this size (ProbeLog(max_bytes=));
    # None = unbounded (launch/serve.py threads --probe-log-max-bytes here)
    probe_log_max_bytes: int | None = None
    slo: "SLOMonitor | None" = None  # per-tenant SLO window (Session makes one if None)


@dataclass
class RankedConfig:
    """Ranked (BM25 top-k) tier knobs."""

    enabled: bool = True  # build payload streams when the index carries tfs
    payload_bits: int = 8  # quantized-impact width (BM25Params.bits)
    # queries whose total postings fit under this skip MaxScore bookkeeping
    # and score exhaustively (still exact); 0 forces pruning everywhere
    topk_exhaustive_cutoff: int = 2048
    score_kernel: bool = False  # batch exhaustive scoring on the Pallas kernel
    # answer each shard's ranked batch with one fused Pallas dispatch
    # (kernels.fused_query) instead of the multi-phase probe/unpack/score/
    # select pipeline; bit-identical, with the multi-phase path as oracle
    fused_kernel: bool = False
    # keep a device-resident impact arena per shard (kernels.arena) so the
    # fused path answers no-required-term items in one dense dispatch with
    # zero per-call index staging; built lazily on first fused use, only
    # while the shard fits the arena's size caps
    device_arena: bool = True

    def __bool__(self) -> bool:  # legacy truthiness: `if cfg.ranked:`
        return self.enabled


@dataclass
class SchedConfig:
    """Continuous-batching scheduler (serve/sched.Session) knobs."""

    max_batch: int = 16  # coalesce at most this many arrivals per dispatch
    max_queue: int = 256  # admission bound on queued requests
    # after the first arrival, wait up to this long for more to coalesce
    # (0 = dispatch whatever is queued the moment the scheduler is free)
    batch_window_us: int = 0
    # process replicas per shard; 0 = inline execution on the session's own
    # dispatch thread (the engine's ShardEngines, serial fan-out)
    n_replicas: int = 0
    default_deadline_ms: float | None = None  # applied when a request has none
    tenant_quota: int | None = None  # max queued requests per tenant
    worker_retries: int = 1  # batch retries after a worker crash
    spawn_timeout_s: float = 120.0  # process-replica ready handshake bound
    # bounded coalescing window, measured from the *head* arrival's submit
    # time: while a forming batch is below max_batch and its oldest entry
    # has waited less than this, take_batch lingers for more arrivals (adds
    # at most coalesce_us to any request's latency; a batch that already
    # waited while runners were busy dispatches immediately)
    coalesce_us: int = 0
    # forward the global running kth-score floor across shard-group ranked
    # dispatches: groups run in ascending-lo order and each later group
    # inherits the merged heap's kth score as its floor, so shards stop
    # scoring candidates the global top-k already excludes
    forward_floor: bool = True
    # replay each replica's recent call signatures after a respawn so the
    # fresh worker re-compiles (or restores from the persistent compilation
    # cache) every executable the crashed one had warm
    warm_snapshot: bool = True
    # directory for JAX's persistent compilation cache in workers (None =
    # in-memory jit only); best-effort — unsupported builds ignore it
    compile_cache_dir: str | None = None


# legacy flat kwarg -> (sub-config attr, field on it)
_LEGACY = {
    "trace": ("obs", "trace"),
    "metrics": ("obs", "metrics"),
    "probe_log": ("obs", "probe_log"),
    "payload_bits": ("ranked", "payload_bits"),
    "topk_exhaustive_cutoff": ("ranked", "topk_exhaustive_cutoff"),
    "score_kernel": ("ranked", "score_kernel"),
    "fused_kernel": ("ranked", "fused_kernel"),
}

# (filename, lineno, message) triples that already warned: the flat-kwarg
# shim fires once per *call site*, not on every sub-config rebuild — worker
# respawns and per-request reconstruction otherwise flood test output
_WARNED_SITES: set[tuple] = set()


def _warn_once(message: str, *, stacklevel: int) -> None:
    """DeprecationWarning deduped by the frame that called the constructor."""
    import sys

    fr = sys._getframe(stacklevel)
    site = (fr.f_code.co_filename, fr.f_lineno, message)
    if site in _WARNED_SITES:
        return
    _WARNED_SITES.add(site)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel + 1)


def _coerce(cls, value):
    """Sub-config argument: an instance, a kwargs dict, or None (defaults)."""
    if value is None:
        return cls()
    if isinstance(value, dict):
        return cls(**value)
    return value


class ServeConfig:
    """Engine-core flags + the three nested sub-configs (see module doc)."""

    def __init__(
        self,
        algorithm: str = "block",
        verified: bool = True,
        use_kernel: bool = False,
        max_query_terms: int = 8,
        postings_store: str = "hybrid",  # tier-2: "hybrid" (compressed) | "raw"
        use_guided: bool = True,  # model-guided contains() probes
        guided_kernel: bool = False,  # probes on the Pallas guided_search kernel
        cache_budget_bytes: int = 32 << 20,  # decode-cost budget per shard LRU
        n_shards: int = 1,  # document partitions (contiguous, 32-aligned)
        obs: ObsConfig | None = None,
        ranked: "RankedConfig | bool | None" = None,
        sched: SchedConfig | None = None,
        **legacy,
    ):
        self.algorithm = algorithm
        self.verified = verified
        self.use_kernel = use_kernel
        self.max_query_terms = max_query_terms
        self.postings_store = postings_store
        self.use_guided = use_guided
        self.guided_kernel = guided_kernel
        self.cache_budget_bytes = cache_budget_bytes
        self.n_shards = n_shards
        self.obs = _coerce(ObsConfig, obs)
        if isinstance(ranked, bool):  # old `ranked=False` bool flag
            legacy["ranked"] = ranked
            ranked = None
        self.ranked = _coerce(RankedConfig, ranked)
        self.sched = _coerce(SchedConfig, sched)
        if legacy.pop("shard_workers", None) is not None:
            _warn_once(
                "ServeConfig(shard_workers=) is retired: the thread-pool "
                "fan-out is superseded by the serve.sched scheduler "
                "(ServeConfig.sched.n_replicas process replicas)",
                stacklevel=2,
            )
        unknown = set(legacy) - set(_LEGACY) - {"ranked"}
        if unknown:
            raise TypeError(f"unknown ServeConfig kwarg(s): {sorted(unknown)}")
        if legacy:
            _warn_once(
                f"flat ServeConfig kwarg(s) {sorted(legacy)} are deprecated; "
                "use the nested sub-configs (ServeConfig.obs / .ranked)",
                stacklevel=2,
            )
        for k, v in legacy.items():
            if k == "ranked":
                self.ranked.enabled = v
            else:
                sub, attr = _LEGACY[k]
                setattr(getattr(self, sub), attr, v)

    def __repr__(self) -> str:
        flags = ", ".join(
            f"{k}={getattr(self, k)!r}"
            for k in ("algorithm", "verified", "n_shards", "postings_store")
        )
        return f"ServeConfig({flags}, obs={self.obs!r}, ranked={self.ranked!r}, sched={self.sched!r})"

    # ------------------------------------------------ flat-attribute compat
    # Old code reads/writes `cfg.trace`, `cfg.payload_bits`, ... — forward
    # silently (the deprecation surface is the constructor kwargs).
    @property
    def trace(self):
        return self.obs.trace

    @trace.setter
    def trace(self, v):
        self.obs.trace = v

    @property
    def metrics(self):
        return self.obs.metrics

    @metrics.setter
    def metrics(self, v):
        self.obs.metrics = v

    @property
    def probe_log(self):
        return self.obs.probe_log

    @probe_log.setter
    def probe_log(self, v):
        self.obs.probe_log = v

    @property
    def payload_bits(self) -> int:
        return self.ranked.payload_bits

    @payload_bits.setter
    def payload_bits(self, v: int):
        self.ranked.payload_bits = v

    @property
    def topk_exhaustive_cutoff(self) -> int:
        return self.ranked.topk_exhaustive_cutoff

    @topk_exhaustive_cutoff.setter
    def topk_exhaustive_cutoff(self, v: int):
        self.ranked.topk_exhaustive_cutoff = v

    @property
    def score_kernel(self) -> bool:
        return self.ranked.score_kernel

    @score_kernel.setter
    def score_kernel(self, v: bool):
        self.ranked.score_kernel = v

    @property
    def fused_kernel(self) -> bool:
        return self.ranked.fused_kernel

    @fused_kernel.setter
    def fused_kernel(self, v: bool):
        self.ranked.fused_kernel = v

    # ------------------------------------------------------- worker export
    def worker_spec(self) -> dict:
        """Picklable kwargs reconstructing this config in a worker process.

        Drops the obs handles (a worker builds its own registry; tracer and
        probe log are facade-side) and the sched block (workers execute, the
        session schedules).
        """
        return {
            "algorithm": self.algorithm,
            "verified": self.verified,
            "use_kernel": self.use_kernel,
            "max_query_terms": self.max_query_terms,
            "postings_store": self.postings_store,
            "use_guided": self.use_guided,
            "guided_kernel": self.guided_kernel,
            "cache_budget_bytes": self.cache_budget_bytes,
            "n_shards": self.n_shards,
            "ranked": RankedConfig(
                enabled=self.ranked.enabled,
                payload_bits=self.ranked.payload_bits,
                topk_exhaustive_cutoff=self.ranked.topk_exhaustive_cutoff,
                score_kernel=self.ranked.score_kernel,
                fused_kernel=self.ranked.fused_kernel,
                device_arena=self.ranked.device_arena,
            ),
        }
