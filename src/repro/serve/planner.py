"""Query planner for doc-partitioned serving: batch -> per-shard probe plans.

The planner/executor split: before any shard touches a posting stream, the
planner turns a padded query batch into

  * per-query term orders — deduped terms sorted by ascending *global*
    document frequency (smallest list first shrinks candidate sets fastest;
    global df keeps every shard filtering in the same order, so K=1 plans
    reproduce the unsharded engine's verification order exactly);
  * per-shard run masks — a shard skips a query outright when one of its
    terms has zero *local* df (the conjunction is provably empty on that
    shard) and skips all-padding queries everywhere;
  * per-shard probe routes — for each (query, term) the planner runs the
    guided-search cost model (expected ε-window ranks vs list length,
    repro.postings.search) against its candidate-cardinality estimate, the
    smallest local df in the query, and pins the term to 'guided' ε-window
    probes or 'decode' (full decompression through the shard's CostLRU).

Executors (serve/shard.ShardEngine) honor the plan verbatim; routing hints
never affect result exactness — both probe paths are exact — only which
stream bytes the shard touches.  Unverified serving keeps only the padding
skip: candidate supersets are returned as-is, so df-based pruning would
change results.

The ranked path plans with ``plan_ranked``: terms dedupe, zero-global-df
terms drop (they score nothing anywhere), and each query carries its
required (conjunctive) subset so MaxScore executors can skip shards where a
required term — or, disjunctively, *every* term — is locally absent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, Sequence

import numpy as np


class ShardLike(Protocol):
    """What the planner needs from an executor shard."""

    @property
    def local_dfs(self) -> np.ndarray: ...

    def route_term(self, t: int, est_cands: int) -> str | None: ...


@dataclass(frozen=True)
class QueryPlan:
    """One query's shard-independent plan."""

    terms: tuple[int, ...]  # deduped, ascending global df (stable on ties)
    allpad: bool  # no real terms: empty result everywhere, both modes
    dead: bool  # some term has zero global df: empty AND (verified mode)


@dataclass
class ShardPlan:
    """One shard's slice of the batch plan."""

    shard_id: int
    run: np.ndarray  # (Q,) bool — execute this query on this shard
    routes: list[dict[int, str] | None]  # per query: term -> 'guided'|'decode'


@dataclass
class BatchPlan:
    queries: np.ndarray  # (Q, T) padded int32, as handed to executors
    qplans: list[QueryPlan]
    shard_plans: list[ShardPlan]

    @property
    def n_queries(self) -> int:
        return len(self.qplans)


def plan_queries(queries: np.ndarray, global_dfs: np.ndarray) -> list[QueryPlan]:
    """Shard-independent half of the plan: term orders + liveness."""
    dfs = np.asarray(global_dfs)
    out = []
    for row in np.asarray(queries):
        terms = sorted({int(t) for t in row if t >= 0})  # dedupe repeats
        terms.sort(key=lambda t: int(dfs[t]))  # stable: ties stay id-ascending
        out.append(
            QueryPlan(
                terms=tuple(terms),
                allpad=not terms,
                dead=bool(terms) and int(dfs[terms[0]]) == 0,
            )
        )
    return out


@dataclass(frozen=True)
class RankedQueryPlan:
    """One ranked query's shard-independent plan."""

    terms: tuple[int, ...]  # deduped, nonzero global df, ascending term id
    required: tuple[int, ...]  # conjunctive subset of terms
    dead: bool  # nothing can score: no live terms, or a required term df=0


def plan_ranked(
    queries: np.ndarray,
    global_dfs: np.ndarray,
    *,
    mode: str = "or",
    required: np.ndarray | None = None,
) -> list[RankedQueryPlan]:
    """Ranked-batch plan: per-query live terms + required subset.

    ``mode`` is "or" (nothing required) or "and" (everything required);
    a boolean ``required`` mask (same shape as queries) overrides it for
    mixed AND/OR queries.  A query is dead when a required term has zero
    global df (empty conjunction) or no term has postings at all.
    """
    if mode not in ("or", "and"):
        raise ValueError(f"mode must be 'or' or 'and', got {mode!r}")
    queries = np.asarray(queries)
    if required is not None and np.asarray(required).shape != queries.shape:
        raise ValueError(
            f"required mask shape {np.asarray(required).shape} != queries {queries.shape}"
        )
    dfs = np.asarray(global_dfs)
    out = []
    for qi, row in enumerate(queries):
        raw = sorted({int(t) for t in row if t >= 0})
        if required is not None:
            req_raw = {int(t) for t, r in zip(row, required[qi]) if t >= 0 and r}
        else:
            req_raw = set(raw) if mode == "and" else set()
        terms = tuple(t for t in raw if int(dfs[t]) > 0)
        dead = not terms or any(int(dfs[t]) == 0 for t in req_raw)
        out.append(
            RankedQueryPlan(
                terms=terms,
                required=tuple(sorted(req_raw & set(terms))),
                dead=dead,
            )
        )
    return out


def ranked_run_mask(
    qplans: Sequence[RankedQueryPlan], local_dfs: np.ndarray
) -> np.ndarray:
    """(Q,) bool — which ranked queries can score anything on this shard:
    every required term present locally, and at least one term live."""
    run = np.zeros(len(qplans), dtype=bool)
    for i, qp in enumerate(qplans):
        if qp.dead:
            continue
        ldfs = [int(local_dfs[t]) for t in qp.terms]
        if not any(ldfs):
            continue
        if any(int(local_dfs[t]) == 0 for t in qp.required):
            continue
        run[i] = True
    return run


def plan_batch(
    queries: np.ndarray,
    global_dfs: np.ndarray,
    shards: Sequence[ShardLike],
    *,
    verified: bool = True,
) -> BatchPlan:
    """Full batch plan over the given executor shards (see module docstring)."""
    q = np.asarray(queries, dtype=np.int32)
    qplans = plan_queries(q, global_dfs)
    shard_plans = []
    for sid, sh in enumerate(shards):
        local_dfs = sh.local_dfs
        run = np.zeros(len(qplans), dtype=bool)
        routes: list[dict[int, str] | None] = [None] * len(qplans)
        for i, qp in enumerate(qplans):
            if qp.allpad:
                continue
            if not verified:
                run[i] = True  # supersets served as-is: no df pruning
                continue
            if qp.dead:
                continue
            ldfs = [int(local_dfs[t]) for t in qp.terms]
            est = min(ldfs)
            if est == 0:  # some term absent on this shard: empty AND here
                continue
            run[i] = True
            hints = {t: r for t in qp.terms if (r := sh.route_term(t, est))}
            if hints:
                routes[i] = hints
        shard_plans.append(ShardPlan(shard_id=sid, run=run, routes=routes))
    return BatchPlan(queries=q, qplans=qplans, shard_plans=shard_plans)
