from repro.serve.boolean import BooleanEngine, ServeConfig

__all__ = ["BooleanEngine", "ServeConfig"]
