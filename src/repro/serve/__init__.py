from repro.rank.score import TopKResult
from repro.serve.boolean import BooleanEngine
from repro.serve.config import ObsConfig, RankedConfig, SchedConfig, ServeConfig
from repro.serve.planner import (
    BatchPlan,
    QueryPlan,
    RankedQueryPlan,
    ShardPlan,
    plan_batch,
    plan_ranked,
    ranked_run_mask,
)
from repro.serve.sched import (
    QueryRequest,
    QueryResult,
    Rejected,
    Session,
    WorkerFailure,
)
from repro.serve.shard import ShardEngine, shard_ranges, slice_bloom

__all__ = [
    "BatchPlan",
    "BooleanEngine",
    "ObsConfig",
    "QueryPlan",
    "QueryRequest",
    "QueryResult",
    "RankedConfig",
    "RankedQueryPlan",
    "Rejected",
    "SchedConfig",
    "ServeConfig",
    "Session",
    "ShardEngine",
    "ShardPlan",
    "TopKResult",
    "WorkerFailure",
    "plan_batch",
    "plan_ranked",
    "ranked_run_mask",
    "shard_ranges",
    "slice_bloom",
]
