from repro.serve.boolean import BooleanEngine, ServeConfig
from repro.serve.planner import BatchPlan, QueryPlan, ShardPlan, plan_batch
from repro.serve.shard import ShardEngine, shard_ranges, slice_bloom

__all__ = [
    "BatchPlan",
    "BooleanEngine",
    "QueryPlan",
    "ServeConfig",
    "ShardEngine",
    "ShardPlan",
    "plan_batch",
    "shard_ranges",
    "slice_bloom",
]
