from repro.rank.score import TopKResult
from repro.serve.boolean import BooleanEngine, ServeConfig
from repro.serve.planner import (
    BatchPlan,
    QueryPlan,
    RankedQueryPlan,
    ShardPlan,
    plan_batch,
    plan_ranked,
    ranked_run_mask,
)
from repro.serve.shard import ShardEngine, shard_ranges, slice_bloom

__all__ = [
    "BatchPlan",
    "BooleanEngine",
    "QueryPlan",
    "RankedQueryPlan",
    "ServeConfig",
    "ShardEngine",
    "ShardPlan",
    "TopKResult",
    "plan_batch",
    "plan_ranked",
    "ranked_run_mask",
    "shard_ranges",
    "slice_bloom",
]
