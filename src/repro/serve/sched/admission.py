"""Admission control: a bounded query queue with tenants, priorities, deadlines.

The queue is the scheduler's only buffer, so admission is where overload
policy lives:

  * **bound** — at most ``SchedConfig.max_queue`` requests wait; when a new
    arrival finds the queue full, the *lowest-priority* queued request is
    shed (``Rejected("queue_full")``) to make room — ties shed the youngest,
    so FIFO order is disturbed as little as possible.  An arrival that is
    itself the lowest priority is rejected instead of churning the queue.
  * **tenant quota** — ``SchedConfig.tenant_quota`` caps queued requests per
    tenant (``Rejected("tenant_quota")``); one chatty tenant cannot convoy
    everyone else.
  * **deadline** — each entry carries an absolute monotonic deadline
    (request's ``deadline_ms`` or the config default).  ``take_batch``
    sheds expired entries (``Rejected("deadline")``) *before* they are
    handed to a worker: a request that already missed its budget never
    costs a dispatch.

``take_batch`` is also the coalescing point of continuous batching: it
blocks until work exists, optionally lingers ``batch_window_us`` for more
arrivals, then returns up to ``max_batch`` entries of the head's mode —
coalescing same-mode entries past other-mode ones (FIFO within each mode)
— so while workers are busy, arrivals pile up and the next dispatch is a
bigger batch.

Every decision is counted in the session's metrics registry
(``sched.enqueued``, ``sched.shed.*``) and the queue depth is a gauge;
shedding resolves the victim's future, so no request is ever silently
dropped.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import numpy as np

from repro.serve.sched.api import (
    REJECT_DEADLINE,
    REJECT_QUEUE_FULL,
    REJECT_SHUTDOWN,
    REJECT_TENANT_QUOTA,
    QueryRequest,
    Rejected,
)


@dataclass(eq=False)  # identity equality: rows are arrays, and each entry is unique
class Pending:
    """One admitted request waiting for dispatch."""

    req: QueryRequest
    future: Future
    row: np.ndarray  # padded int32 term row (the request's batch slice)
    t_submit: float  # monotonic seconds
    deadline: float | None  # absolute monotonic seconds, None = none
    seq: int = 0  # admission order (FIFO tie-break)

    def resolve(self, outcome) -> None:
        if not self.future.done():
            self.future.set_result(outcome)

    def reject(self, reason: str, detail: str = "") -> None:
        self.resolve(Rejected(reason=reason, tenant=self.req.tenant, detail=detail))


class AdmissionQueue:
    """Bounded, tenant-aware, deadline-shedding FIFO (see module doc)."""

    def __init__(self, sched_cfg, metrics, *, clock=time.monotonic):
        self.cfg = sched_cfg
        self.clock = clock
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._space = threading.Condition(self._lock)
        self._items: list[Pending] = []
        self._tenant_queued: dict[str, int] = {}
        self._seq = 0
        self._closed = False
        self._enqueued = metrics.counter("sched.enqueued")
        self._shed_full = metrics.counter("sched.shed.queue_full")
        self._shed_quota = metrics.counter("sched.shed.tenant_quota")
        self._shed_deadline = metrics.counter("sched.shed.deadline")
        self._depth = metrics.gauge("sched.queue_depth")

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    # ------------------------------------------------------------- admit
    def offer(self, pending: Pending, *, block: bool = False) -> bool:
        """Admit ``pending`` or resolve it as Rejected; True iff admitted.

        ``block=True`` (the legacy sync wrappers) waits for space instead of
        shedding — those callers have no deadline and expect backpressure.
        """
        tenant = pending.req.tenant
        with self._lock:
            if self._closed:
                pending.reject(REJECT_SHUTDOWN)
                return False
            quota = self.cfg.tenant_quota
            if quota is not None and self._tenant_queued.get(tenant, 0) >= quota:
                self._shed_quota.inc()
                pending.reject(
                    REJECT_TENANT_QUOTA,
                    detail=f"tenant {tenant!r} already has {quota} queued",
                )
                return False
            while len(self._items) >= self.cfg.max_queue:
                if block:
                    self._space.wait(timeout=0.05)
                    if self._closed:
                        pending.reject(REJECT_SHUTDOWN)
                        return False
                    continue
                if not self._shed_for(pending):
                    self._shed_full.inc()
                    pending.reject(
                        REJECT_QUEUE_FULL,
                        detail=f"queue at max_queue={self.cfg.max_queue}",
                    )
                    return False
            pending.seq = self._seq
            self._seq += 1
            self._items.append(pending)
            self._tenant_queued[tenant] = self._tenant_queued.get(tenant, 0) + 1
            self._enqueued.inc()
            self._depth.set(len(self._items))
            self._nonempty.notify()
        return True

    def _shed_for(self, incoming: Pending) -> bool:
        """Evict the lowest-priority queued victim to admit ``incoming``.

        Victim = min priority, youngest first among ties (preserves the
        FIFO head).  Only a strictly higher-priority arrival may displace —
        equal priority rejects the newcomer, not the queue.  Lock held.
        """
        if not self._items:
            return False
        victim = min(self._items, key=lambda p: (p.req.priority, -p.seq))
        if victim.req.priority >= incoming.req.priority:
            return False
        self._items.remove(victim)
        self._drop_tenant(victim.req.tenant)
        self._shed_full.inc()
        victim.reject(
            REJECT_QUEUE_FULL,
            detail=f"shed for priority-{incoming.req.priority} arrival",
        )
        return True

    def _drop_tenant(self, tenant: str) -> None:
        n = self._tenant_queued.get(tenant, 0) - 1
        if n <= 0:
            self._tenant_queued.pop(tenant, None)
        else:
            self._tenant_queued[tenant] = n

    # ------------------------------------------------------------- drain
    def take_batch(self, max_batch: int) -> list[Pending]:
        """Block until work exists; return a same-mode batch (<= max_batch).

        Expired entries are shed here — *before* dispatch — so a request
        past its deadline never reaches a worker.  Returns [] only when the
        queue is closed and empty.
        """
        with self._lock:
            while True:
                self._expire_locked()
                if self._items:
                    break
                if self._closed:
                    return []
                self._nonempty.wait(timeout=0.05)
            if self.cfg.batch_window_us > 0 and len(self._items) < max_batch:
                deadline = self.clock() + self.cfg.batch_window_us / 1e6
                while len(self._items) < max_batch:
                    left = deadline - self.clock()
                    if left <= 0 or self._closed:
                        break
                    self._nonempty.wait(timeout=left)
                self._expire_locked()
                if not self._items:
                    return []
            if self.cfg.coalesce_us > 0 and len(self._items) < max_batch:
                # bounded coalescing window, anchored to the *head* arrival's
                # submit time: light-load singleton batches linger for
                # stragglers, but a batch that already aged while runners
                # were busy dispatches immediately — no request ever waits
                # more than coalesce_us beyond its submit for batching
                deadline = self._items[0].t_submit + self.cfg.coalesce_us / 1e6
                while len(self._items) < max_batch:
                    left = deadline - self.clock()
                    if left <= 0 or self._closed:
                        break
                    self._nonempty.wait(timeout=left)
                self._expire_locked()
                if not self._items:
                    return []
            # the head's mode goes first, and later same-mode entries
            # coalesce past other-mode entries (FIFO preserved *within*
            # each mode; the skipped mode is left at the head for the next
            # round).  A strict prefix would break every batch at a mode
            # switch, and a mixed workload would pay the per-dispatch cost
            # once per mode *run* instead of once per max_batch.
            mode = self._items[0].req.mode
            batch: list[Pending] = []
            keep: list[Pending] = []
            for p in self._items:
                if len(batch) < max_batch and p.req.mode == mode:
                    self._drop_tenant(p.req.tenant)
                    batch.append(p)
                else:
                    keep.append(p)
            self._items = keep
            self._depth.set(len(self._items))
            self._space.notify_all()
        return batch

    def _expire_locked(self) -> None:
        now = self.clock()
        live = []
        for p in self._items:
            if p.deadline is not None and now > p.deadline:
                self._drop_tenant(p.req.tenant)
                self._shed_deadline.inc()
                p.reject(
                    REJECT_DEADLINE,
                    detail=f"queued {1e3 * (now - p.t_submit):.1f}ms past deadline",
                )
            else:
                live.append(p)
        if len(live) != len(self._items):
            self._items[:] = live
            self._depth.set(len(live))
            self._space.notify_all()

    def close(self) -> None:
        """Reject everything still queued and wake all waiters."""
        with self._lock:
            self._closed = True
            for p in self._items:
                self._drop_tenant(p.req.tenant)
                p.reject(REJECT_SHUTDOWN)
            self._items.clear()
            self._depth.set(0)
            self._nonempty.notify_all()
            self._space.notify_all()
