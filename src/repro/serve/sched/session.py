"""Session: the continuous-batching serving front-end.

``Session`` is the one front door to the serving stack — the unified API
the ROADMAP's "throughput serving" item asked for:

    requests ──> admission ──> queue ──> coalesce ──> per-shard dispatch
                 (tenant        │         (continuous   (replica groups,
                  quota,        │          batching:     least-loaded,
                  bound,        │          same-mode,    retry-once)
                  shed)         │          ≤ max_batch)       │
                                │                             ▼
                 deadline shed ─┘                      merge + resolve

One scheduler thread drains the admission queue (sched/admission.py) into
coalesced same-mode batches; batch *execution* runs on a small runner pool
(`max(1, n_replicas)` slots) so that with process replicas multiple batches
are in flight at once — while a batch executes, new arrivals pile up, and
the next dispatch is a bigger batch.  That is continuous batching: device-
sized per-shard batches form from whatever has arrived, with no fixed batch
boundary and no closed-loop barrier.

Within a batch the dispatch is the planner/executor seam from the sharded
refactor: every shard's replica group gets the whole padded batch, plans it
locally with *global* document frequencies (identical term order and
routes), and returns packed bitmaps (Boolean) or local top-k heaps
(ranked); the session word-copies bitmaps by doc offset and folds heaps
with the same ``select_topk`` the engine facade uses — so every path stays
bit-identical to the legacy ``query_*`` entry points, which survive here as
thin wrappers over ``submit``.

Every decision is observable: ``sched.*`` counters/histograms land in the
engine's metrics registry and enqueue/queue-wait/batch/dispatch/merge spans
ride the engine's tracer (repro.obs), so BENCH artifacts explain themselves.
With process replicas the trace is *distributed*: a TraceContext travels
with each fan-out, workers ship their span buffers and probe records back
with the response, and replicas collate them onto the host timeline in
their own pid lanes (obs/collate.py) — one request renders end-to-end from
admission wait to worker probe/decode/kernel to merge.  Per-request
``QueryResult.autopsy()`` decomposes latency into queue/dispatch/execute/
merge, and ``slo_report()`` summarizes per-tenant deadline-hit-rate, p99
and burn-rate over a rolling window (obs/slo.py).
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

from repro.obs import trace
from repro.obs.slo import SLOMonitor
from repro.obs.trace import Span, TraceContext
from repro.rank.score import TopKResult, select_topk
from repro.serve.sched.admission import AdmissionQueue, Pending
from repro.serve.sched.api import (
    MODE_BOOLEAN,
    MODE_RANKED,
    REJECT_SHUTDOWN,
    REJECT_WORKER_FAILED,
    QueryRequest,
    QueryResult,
    Rejected,
    WorkerFailure,
)
from repro.serve.sched.replica import InlineReplica, ProcessReplica, ReplicaGroup
from repro.serve.shard import WORD_BITS, pack_ids, unpack_row


def _numpy_tree(obj):
    """Best-effort jax->numpy conversion of a params pytree (pickling)."""
    if isinstance(obj, dict):
        return {k: _numpy_tree(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return type(obj)(_numpy_tree(v) for v in obj)
    return np.asarray(obj)


class Session:
    """Continuous-batching front-end over a ``BooleanEngine`` (see module doc).

    ``store_dir`` is required when ``cfg.sched.n_replicas > 0``: process
    replicas rebuild their engines from the persistent shard-store (saved
    there on first use if absent).  ``replica_groups`` injects prebuilt
    groups (tests).  Use as a context manager, or call ``close()``.
    """

    def __init__(
        self,
        engine,
        *,
        store_dir: str | None = None,
        replica_groups: list[ReplicaGroup] | None = None,
        auto_start: bool = True,
    ):
        self.engine = engine
        self.cfg = engine.cfg
        self.sched_cfg = engine.cfg.sched
        self.metrics = engine.metrics
        self.n_docs = engine.n_docs
        self._closed = False
        self._queue = AdmissionQueue(self.sched_cfg, self.metrics)
        self._batches = self.metrics.counter("sched.batches")
        self._dispatched = self.metrics.counter("sched.dispatched")
        self._short_circuit = self.metrics.counter("sched.short_circuit")
        self._batch_size = self.metrics.histogram("sched.batch_size")
        self._queue_us = self.metrics.histogram("sched.queue_us")
        self._service_us = self.metrics.histogram("sched.service_us")
        self._dispatch_us = self.metrics.histogram("sched.dispatch_us")
        self._execute_us = self.metrics.histogram("sched.execute_us")
        self._merge_us = self.metrics.histogram("sched.merge_us")
        self.slo = self.cfg.obs.slo if self.cfg.obs.slo is not None else SLOMonitor()
        self._trace_seq = itertools.count(1)  # trace ids for worker IPC
        self._store_dir = store_dir  # warm-snapshot + compile-cache home
        self._groups = (
            replica_groups
            if replica_groups is not None
            else self._build_groups(store_dir)
        )
        # 2x the replica count so batch N+1 plans/merges while batch N is in
        # the workers (the replicas' own locks serialize actual execution)
        slots = 2 * max(1, self.sched_cfg.n_replicas)
        self._slots = threading.Semaphore(slots)
        self._runners = ThreadPoolExecutor(slots, thread_name_prefix="sched-run")
        # per-shard dispatch inside one batch: calls block in pipe recv (GIL
        # released), so threads here fan process replicas out for real
        self._fan = ThreadPoolExecutor(
            max(1, len(self._groups)) * slots, thread_name_prefix="sched-fan"
        )
        self._loop_thread = threading.Thread(
            target=self._loop, name="sched-loop", daemon=True
        )
        if auto_start:
            self._loop_thread.start()

    # --------------------------------------------------------------- setup
    def _build_groups(self, store_dir: str | None) -> list[ReplicaGroup]:
        eng, sc = self.engine, self.sched_cfg
        if sc.n_replicas <= 0:
            return [
                ReplicaGroup(
                    sh.shard_id,
                    [InlineReplica(sh, eng._global_dfs, eng.cfg)],
                    lo=sh.lo,
                    n_docs=sh.n_docs,
                    retries=sc.worker_retries,
                    metrics=self.metrics,
                    obs=eng.cfg.obs,
                )
                for sh in eng.shards
            ]
        if store_dir is None:
            raise ValueError(
                "process replicas (sched.n_replicas > 0) rebuild engines from "
                "the persistent shard-store: pass Session(engine, store_dir=...)"
            )
        if not os.path.exists(os.path.join(store_dir, "shards.json")):
            eng.save(store_dir)
        lb = eng.lb
        lb_params = _numpy_tree(lb.params)
        lb_tau = np.asarray(lb.tau)
        lb_backup = np.asarray(lb.backup_keys)
        global_dfs = np.asarray(eng._global_dfs)
        # one shared persistent-compile-cache home per store: every worker of
        # every (re)spawn deserializes executables the first run compiled
        compile_cache_dir = sc.compile_cache_dir
        if compile_cache_dir is None and sc.warm_snapshot:
            compile_cache_dir = os.path.join(store_dir, "xla-compile-cache")
        snapshot = self._load_warm_snapshot(store_dir) if sc.warm_snapshot else None
        groups = []
        for idx, ((lo, hi), sh) in enumerate(zip(eng._ranges, eng._shards)):
            if sh is None:
                continue
            spec = {
                "store_dir": store_dir,
                "shard_idx": idx,
                "lo": lo,
                "hi": hi,
                "lb_params": lb_params,
                "lb_tau": lb_tau,
                "lb_backup_keys": lb_backup,
                "n_docs": lb.n_docs,
                "li_cfg": eng.li_cfg,
                "cfg_kwargs": eng.cfg.worker_spec(),
                "global_dfs": global_dfs,
                "compile_cache_dir": compile_cache_dir,
            }
            replicas = [
                ProcessReplica(
                    spec,
                    spawn_timeout_s=sc.spawn_timeout_s,
                    obs=eng.cfg.obs,
                    label=f"shard{idx}/replica{j}",
                    record_warm=sc.warm_snapshot,
                )
                for j in range(sc.n_replicas)
            ]
            if snapshot:
                for r in replicas:
                    r.preload_warm(snapshot)
            groups.append(
                ReplicaGroup(
                    idx,
                    replicas,
                    lo=lo,
                    n_docs=hi - lo,
                    retries=sc.worker_retries,
                    metrics=self.metrics,
                    obs=eng.cfg.obs,
                )
            )
        return groups

    @staticmethod
    def _load_warm_snapshot(store_dir: str) -> list | None:
        path = os.path.join(store_dir, "warm_snapshot.json")
        try:
            with open(path) as f:
                data = json.load(f)
            entries = data.get("entries")
            return entries or None
        except (OSError, ValueError):
            return None

    def save_warm_snapshot(self) -> str | None:
        """Persist the replicas' recorded warm traffic to the shard-store.

        ``warm_snapshot.json`` holds one representative message per dispatch
        shape any replica served; a *future* session over the same store
        preloads it into fresh replicas, whose first spawn then replays the
        previous run's whole compile surface against the persistent XLA
        cache — warm across worker restarts *and* session restarts.
        """
        if self._store_dir is None:
            return None
        merged: dict = {}
        for g in self._groups:
            for r in g.replicas:
                if isinstance(r, ProcessReplica):
                    for e in r.export_warm():
                        merged[json.dumps(e, sort_keys=True)] = e
        if not merged:
            return None
        path = os.path.join(self._store_dir, "warm_snapshot.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": list(merged.values())}, f)
        return path

    def warm(self) -> None:
        """Force-spawn every process replica and pre-compile the batch shapes.

        The membership probe (``candidate_mask``) is one jit dispatch
        specialized on the padded batch shape; dispatch pads batches to
        power-of-two buckets (``_bucket``), so warming each bucket here keeps
        compilation out of the serving path entirely.
        """
        replicas = [r for g in self._groups for r in g.replicas]
        futs = [self._fan.submit(r.call, ("ping",)) for r in replicas]
        for f in futs:
            assert f.result() == "pong"
        # one live term so the probe phase actually runs (all-pad batches
        # short-circuit before the jit dispatch)
        t = int(np.argmax(self.engine._global_dfs))
        b = 1
        while True:
            q = np.full((b, self.cfg.max_query_terms), -1, dtype=np.int32)
            q[:, 0] = t
            futs = [self._fan.submit(r.call, ("bool", q)) for r in replicas]
            for f in futs:
                f.result()
            if b >= self.sched_cfg.max_batch:
                break
            b = min(2 * b, self.sched_cfg.max_batch)
        if self.cfg.ranked.enabled and self.cfg.ranked.fused_kernel:
            self._warm_fused(replicas, t)
        if self.sched_cfg.warm_snapshot and self.sched_cfg.n_replicas > 0:
            self.save_warm_snapshot()

    def _warm_fused(self, replicas, t: int) -> None:
        """Pre-trigger the fused ranked kernel's row buckets on every replica.

        The fused dispatch jit-specializes on its padded (rows, terms,
        candidates, window) bucket; driving the power-of-two row buckets with
        a real term keeps that compilation out of the serving path, same as
        the boolean warm above.  Best-effort: a store without payload
        streams can't rank, so failures leave the replica cold, not broken.
        """
        # several dense terms at k=1: the threshold rises after the first
        # essential decode, leaving the rest as a probe tail for the kernel
        dfs = np.asarray(self.engine._global_dfs)
        terms = tuple(int(x) for x in np.argsort(dfs)[-4:] if dfs[x] > 0) or (t,)
        item = (terms, (), 1, 0)
        b = 1
        while True:
            futs = [
                self._fan.submit(r.call, ("topk", [item] * b)) for r in replicas
            ]
            try:
                for f in futs:
                    f.result()
            except Exception:
                return
            if b >= self.sched_cfg.max_batch:
                return
            b = min(2 * b, self.sched_cfg.max_batch)

    @staticmethod
    def _bucket(n: int) -> int:
        """Round a batch size up to a power of two: a handful of padded
        shapes instead of one jit compilation per distinct batch size."""
        b = 1
        while b < n:
            b *= 2
        return b

    # -------------------------------------------------------------- submit
    def submit_async(self, req: QueryRequest, *, block: bool = False) -> Future:
        """Admit one request; the future resolves to QueryResult | Rejected.

        ``block=True`` waits for queue space instead of shedding on a full
        queue (the legacy sync wrappers' backpressure).  Never blocks on
        execution — that is the future's job.
        """
        fut: Future = Future()
        t_submit = time.monotonic()
        if self._closed:
            self._slo_track(fut, req.tenant, t_submit, None)
            fut.set_result(Rejected(reason=REJECT_SHUTDOWN, tenant=req.tenant))
            return fut
        row = req.terms
        if len(row) < self.cfg.max_query_terms:
            row = np.pad(
                row, (0, self.cfg.max_query_terms - len(row)), constant_values=-1
            )
        # all-pad / k<=0 short-circuit: resolved here, never queued, exactly
        # like the engine facade's empty-batch path
        if (row < 0).all() or (req.mode == MODE_RANKED and req.k <= 0):
            self._short_circuit.inc()
            self._slo_track(fut, req.tenant, t_submit, None)
            fut.set_result(self._empty_result(req))
            return fut
        deadline_ms = (
            req.deadline_ms
            if req.deadline_ms is not None
            else self.sched_cfg.default_deadline_ms
        )
        pending = Pending(
            req=req,
            future=fut,
            row=row,
            t_submit=t_submit,
            deadline=(
                t_submit + deadline_ms / 1e3 if deadline_ms is not None else None
            ),
        )
        self._slo_track(fut, req.tenant, t_submit, pending.deadline)
        with trace.activate(self.cfg.obs.trace), trace.span(
            "sched.enqueue", mode=req.mode, tenant=req.tenant, priority=req.priority
        ):
            self._queue.offer(pending, block=block)
        return fut

    def _slo_track(
        self, fut: Future, tenant: str, t_submit: float, deadline: float | None
    ) -> None:
        """Feed the SLO window when the future resolves — served or shed,
        every admitted outcome is one sample (shed never meets a deadline)."""

        def cb(f: Future) -> None:
            r = f.result()  # resolved by contract before callbacks fire
            now = time.monotonic()
            served = bool(r.ok)
            met = served and (deadline is None or now <= deadline)
            self.slo.record(
                tenant,
                latency_us=1e6 * (now - t_submit),
                served=served,
                deadline_met=met,
            )

        fut.add_done_callback(cb)

    def submit(self, req: QueryRequest, *, timeout: float | None = None):
        """Synchronous submit: block until served or shed."""
        return self.submit_async(req, block=True).result(timeout)

    def _empty_result(self, req: QueryRequest) -> QueryResult:
        scores = np.zeros(0, np.int64) if req.mode == MODE_RANKED else None
        return QueryResult(ids=np.zeros(0, np.int32), scores=scores)

    # ---------------------------------------------------------------- loop
    def _loop(self) -> None:
        while True:
            # claim a runner slot *before* popping work: while every slot is
            # busy, arrivals keep coalescing in the queue instead of being
            # pinned inside an already-popped batch that is stuck waiting
            # for a runner
            self._slots.acquire()
            batch = self._queue.take_batch(self.sched_cfg.max_batch)
            if not batch:
                self._slots.release()
                if self._closed:
                    return
                continue
            self._runners.submit(self._run_batch, batch)

    def _run_batch(self, batch: list[Pending]) -> None:
        t0 = time.monotonic()
        mode = batch[0].req.mode
        for p in batch:
            self._queue_us.observe(1e6 * (t0 - p.t_submit))
        self._queue_wait_spans(batch, t0)
        self._batches.inc()
        self._batch_size.observe(len(batch))
        self._dispatched.inc(len(batch))
        try:
            with trace.activate(self.cfg.obs.trace), trace.span(
                "sched.batch", mode=mode, size=len(batch)
            ):
                if mode == MODE_BOOLEAN:
                    self._run_boolean(batch, t0)
                else:
                    self._run_ranked(batch, t0)
        except WorkerFailure as e:
            for p in batch:
                p.reject(REJECT_WORKER_FAILED, detail=str(e))
        except Exception as e:  # never leave an admitted future hanging
            for p in batch:
                p.reject(REJECT_WORKER_FAILED, detail=repr(e))
        finally:
            self._service_us.observe(1e6 * (time.monotonic() - t0))
            self._slots.release()

    def _queue_wait_spans(self, batch: list[Pending], t0: float) -> None:
        """Retroactive admission-wait spans: submit -> dispatch per request.

        ``time.monotonic`` and ``perf_counter`` share CLOCK_MONOTONIC on
        Linux, so the wait interval maps onto the tracer's timeline exactly;
        recorded at dispatch because only then is the wait's end known.
        """
        tracer = self.cfg.obs.trace
        if tracer is None:
            return
        now_us = (time.perf_counter_ns() - tracer.epoch_ns) / 1e3
        tid = threading.get_ident()
        for p in batch:
            dur_us = 1e6 * (t0 - p.t_submit)
            tracer.add_span(
                Span(
                    name="sched.queue_wait",
                    ts_us=now_us - dur_us,
                    dur_us=dur_us,
                    tid=tid,
                    depth=0,
                    attrs={"tenant": p.req.tenant, "mode": p.req.mode},
                )
            )

    def _stack_rows(self, batch: list[Pending], pad_rows: bool = False) -> np.ndarray:
        width = max(len(p.row) for p in batch)
        rows = self._bucket(len(batch)) if pad_rows else len(batch)
        q = np.full((rows, width), -1, dtype=np.int32)
        for j, p in enumerate(batch):
            q[j, : len(p.row)] = p.row
        return q

    def _fan_out(self, msg) -> list:
        """One message to every shard group, in parallel when it pays.

        Appends a ``TraceContext`` telling workers what telemetry to ship
        back (None when nothing is listening, so the trace-off wire cost
        stays zero); inline replicas ignore the extra element.
        """
        msg = msg + (self._trace_ctx(),)
        if len(self._groups) == 1:
            return [self._groups[0].call(msg)]
        futs = [self._fan.submit(g.call, msg) for g in self._groups]
        return [f.result() for f in futs]  # re-raises WorkerFailure

    def _trace_ctx(self):
        obs = self.cfg.obs
        if obs.trace is None and obs.probe_log is None:
            return None
        return TraceContext(
            trace_id=next(self._trace_seq),
            trace=obs.trace is not None,
            probe=obs.probe_log is not None,
        )

    def _ranked_forward_floors(self, batch, items, idxmap) -> list:
        """Ranked fan-in with the global kth-score floor θ forwarded.

        Groups run *sequentially* in ascending doc-range order; each later
        group's items carry the merged running heap's kth score as a strict
        floor, so its shards stop scoring candidates the global top-k
        already excludes (shard heaps prune globally instead of
        independently — the K>1 scored_fraction satellite).  Doc ranges
        ascend and ties break by ascending id, so a later shard's tie can
        never displace the heap: results stay bit-identical to the
        concurrent floor-0 fan-out, which tests assert.
        """
        order = sorted(range(len(self._groups)), key=lambda g: self._groups[g].lo)
        heaps: list = [None] * len(items)
        for g in order:
            group = self._groups[g]
            sent = []
            for n, (terms, req, k, _) in enumerate(items):
                h = heaps[n]
                floor = int(h.scores[k - 1]) if h is not None and len(h.scores) == k else 0
                sent.append((terms, req, k, floor))
            part = group.call(("topk", sent, self._trace_ctx()))
            for n, (terms, req, k, _) in enumerate(items):
                ids, scores = part[n]
                if len(ids) == 0:
                    continue
                h = heaps[n]
                if h is None:
                    heaps[n] = select_topk(ids, scores, k)
                else:
                    heaps[n] = select_topk(
                        np.concatenate([h.ids, ids]),
                        np.concatenate([h.scores, scores]),
                        k,
                    )
        empty = TopKResult(ids=np.zeros(0, np.int32), scores=np.zeros(0, np.int64))
        return [h if h is not None else empty for h in heaps]

    def _timing(self, p: Pending, t0: float, phases: dict | None = None) -> dict:
        return {
            "queue_us": 1e6 * (t0 - p.t_submit),
            "service_us": 1e6 * (time.monotonic() - t0),
            "phases": dict(phases) if phases else None,
        }

    def _phase_marks(self, t0: float, t_x0: float, t_x1: float) -> dict:
        """The batch's service decomposition (one dict shared per batch):
        dispatch = stack/plan before the fan-out, execute = fan-out wall,
        merge = everything after (fold + resolve).  Feeds QueryResult.autopsy
        and the sched.dispatch_us/execute_us/merge_us histograms."""
        t_m = time.monotonic()
        phases = {
            "dispatch_us": 1e6 * (t_x0 - t0),
            "execute_us": 1e6 * (t_x1 - t_x0),
            "merge_us": 1e6 * (t_m - t_x1),
        }
        self._dispatch_us.observe(phases["dispatch_us"])
        self._execute_us.observe(phases["execute_us"])
        self._merge_us.observe(phases["merge_us"])
        return phases

    def _run_boolean(self, batch: list[Pending], t0: float) -> None:
        q = self._stack_rows(batch, pad_rows=True)  # bucketed probe shape
        t_x0 = time.monotonic()
        with trace.span("sched.dispatch", shards=len(self._groups), size=len(batch)):
            parts = self._fan_out(("bool", q))
        t_x1 = time.monotonic()
        words = (self.n_docs + WORD_BITS - 1) // WORD_BITS
        merged = np.zeros((len(batch), words), dtype=np.uint32)
        with trace.span("sched.merge"):
            for g, bm in zip(self._groups, parts):
                off = g.lo // WORD_BITS
                merged[:, off : off + bm.shape[1]] = bm[: len(batch)]
        phases = self._phase_marks(t0, t_x0, t_x1)
        for j, p in enumerate(batch):
            p.resolve(
                QueryResult(
                    ids=unpack_row(merged[j], self.n_docs),
                    **self._timing(p, t0, phases),
                )
            )

    def _run_ranked(self, batch: list[Pending], t0: float) -> None:
        from repro.serve.planner import plan_ranked

        q = self._stack_rows(batch)
        required = np.zeros(q.shape, dtype=bool)
        for j, p in enumerate(batch):
            if p.req.required is not None:
                required[j, : len(p.req.required)] = p.req.required
        qplans = plan_ranked(q, self.engine._global_dfs, mode="or", required=required)
        items, idxmap = [], []
        for j, (p, qp) in enumerate(zip(batch, qplans)):
            if qp.dead:
                p.resolve(
                    QueryResult(
                        ids=np.zeros(0, np.int32),
                        scores=np.zeros(0, np.int64),
                        **self._timing(p, t0),
                    )
                )
                continue
            # floor=0 placeholder: _ranked_forward_floors rewrites it per
            # group when SchedConfig.forward_floor shares the running global
            # kth score across the fan-in (exactness never depends on it —
            # shard heaps merge associatively — it only skips work)
            items.append((qp.terms, qp.required, int(p.req.k), 0))
            idxmap.append(j)
        if not items:
            return
        forward = self.sched_cfg.forward_floor and len(self._groups) > 1
        t_x0 = time.monotonic()
        with trace.span("sched.dispatch", shards=len(self._groups), size=len(items)):
            if forward:
                tops = self._ranked_forward_floors(batch, items, idxmap)
            else:
                parts = self._fan_out(("topk", items))
        t_x1 = time.monotonic()
        with trace.span("sched.merge"):
            if not forward:
                tops = []
                for n, j in enumerate(idxmap):
                    p = batch[j]
                    ids = np.concatenate([part[n][0] for part in parts])
                    scores = np.concatenate([part[n][1] for part in parts])
                    tops.append(select_topk(ids, scores, int(p.req.k)))
        phases = self._phase_marks(t0, t_x0, t_x1)
        for top, j in zip(tops, idxmap):
            p = batch[j]
            p.resolve(
                QueryResult(
                    ids=top.ids, scores=top.scores, **self._timing(p, t0, phases)
                )
            )

    # ----------------------------------------------------- legacy wrappers
    def query_batch(self, queries: np.ndarray) -> list[np.ndarray]:
        """Legacy entry point: (Q, T) padded term ids -> per-query doc ids.

        A thin wrapper over ``submit`` — every row becomes one boolean
        ``QueryRequest`` (blocking admission, no deadline), results are
        bit-identical to ``BooleanEngine.query_batch``.
        """
        rows = self._rows(queries)
        futs = [
            self.submit_async(QueryRequest(terms=row), block=True) for row in rows
        ]
        return [self._unwrap(f).ids for f in futs]

    def query_batch_bitmap(self, queries: np.ndarray) -> np.ndarray:
        """Legacy entry point: (Q, T) -> (Q, ceil(n_docs/32)) packed uint32."""
        rows = self._rows(queries)
        words = (self.n_docs + WORD_BITS - 1) // WORD_BITS
        out = np.zeros((len(rows), words), dtype=np.uint32)
        futs = [
            self.submit_async(QueryRequest(terms=row), block=True) for row in rows
        ]
        for j, f in enumerate(futs):
            out[j] = pack_ids(self._unwrap(f).ids, self.n_docs)
        return out

    def query_topk(
        self,
        queries: np.ndarray,
        k: int = 10,
        *,
        mode: str = "or",
        required: np.ndarray | None = None,
    ) -> list[TopKResult]:
        """Legacy entry point: ranked top-k, bit-identical to the facade."""
        if mode not in ("or", "and"):
            raise ValueError(f"mode must be 'or' or 'and', got {mode!r}")
        rows = self._rows(queries)
        futs = []
        for j, row in enumerate(rows):
            if required is not None:
                req_mask = np.asarray(required[j], dtype=bool)
            elif mode == "and":
                req_mask = row >= 0
            else:
                req_mask = None
            futs.append(
                self.submit_async(
                    QueryRequest(terms=row, mode=MODE_RANKED, k=k, required=req_mask),
                    block=True,
                )
            )
        return [
            TopKResult(ids=r.ids, scores=r.scores)
            for r in (self._unwrap(f) for f in futs)
        ]

    def _rows(self, queries: np.ndarray) -> list[np.ndarray]:
        q = np.asarray(queries, dtype=np.int32)
        if q.ndim != 2:
            raise ValueError(f"queries must be (Q, T), got shape {q.shape}")
        return [q[i] for i in range(q.shape[0])]

    def _unwrap(self, fut: Future) -> QueryResult:
        r = fut.result()
        if not r.ok:
            raise RuntimeError(f"request shed: {r.reason} ({r.detail})")
        return r

    # ------------------------------------------------------------------ slo
    def slo_report(self) -> dict:
        """Rolling SLO view: per-tenant deadline-hit-rate / p99 / burn-rate
        (obs/slo.py sliding window) paired with the whole-process ``sched.*``
        latency histograms from the metrics registry."""
        sched = self.metrics.snapshot().get("sched", {})
        keep = (
            "queue_us",
            "service_us",
            "dispatch_us",
            "execute_us",
            "merge_us",
            "batch_size",
            "shed",
        )
        return {
            "window_s": self.slo.window_s,
            "target": self.slo.target,
            "tenants": self.slo.report(),
            "sched": {k: sched[k] for k in keep if k in sched},
        }

    # ---------------------------------------------------------------- exit
    def close(self) -> None:
        """Shed the queue (typed ``Rejected("shutdown")``), stop replicas."""
        if self._closed:
            return
        self._closed = True
        self._queue.close()
        if self._loop_thread.is_alive():
            self._loop_thread.join(timeout=5.0)
        self._runners.shutdown(wait=True)
        self._fan.shutdown(wait=True)
        for g in self._groups:
            g.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
