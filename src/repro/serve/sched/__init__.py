from repro.serve.sched.admission import AdmissionQueue, Pending
from repro.serve.sched.api import (
    MODE_BOOLEAN,
    MODE_RANKED,
    REJECT_DEADLINE,
    REJECT_QUEUE_FULL,
    REJECT_SHUTDOWN,
    REJECT_TENANT_QUOTA,
    REJECT_WORKER_FAILED,
    QueryRequest,
    QueryResult,
    Rejected,
    SubmitOutcome,
    WorkerFailure,
)
from repro.serve.sched.replica import (
    InlineReplica,
    ProcessReplica,
    ReplicaError,
    ReplicaGroup,
)
from repro.serve.sched.session import Session

__all__ = [
    "AdmissionQueue",
    "InlineReplica",
    "MODE_BOOLEAN",
    "MODE_RANKED",
    "Pending",
    "ProcessReplica",
    "QueryRequest",
    "QueryResult",
    "REJECT_DEADLINE",
    "REJECT_QUEUE_FULL",
    "REJECT_SHUTDOWN",
    "REJECT_TENANT_QUOTA",
    "REJECT_WORKER_FAILED",
    "Rejected",
    "ReplicaError",
    "ReplicaGroup",
    "Session",
    "SubmitOutcome",
    "WorkerFailure",
]
