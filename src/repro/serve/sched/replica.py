"""Replica groups: least-loaded dispatch over shard executors, retry-once.

A ``ReplicaGroup`` owns every executor that can serve one document
partition.  Two replica kinds implement the same two-method surface
(``call(msg)`` / ``close()`` plus an ``inflight`` load counter):

  * ``InlineReplica`` — the facade engine's own in-process ``ShardEngine``.
    The 0-replica scheduler path: no processes, no pickling, execution on
    the session's dispatch thread through the *same* ``execute_bool`` /
    ``execute_topk`` helpers the workers run.
  * ``ProcessReplica`` — a spawned worker process (sched/worker.py) holding
    its own engine over the shared mmap shard-store.  Spawn is lazy (first
    ``call``) and a replica that died is respawned on its next use, so a
    crashed worker costs one failed dispatch, not a dead shard.

``ReplicaGroup.call`` picks the least-loaded live replica (smallest
``inflight``), and on a ``ReplicaError`` retries the batch — preferring a
*different* replica — up to ``SchedConfig.worker_retries`` times before
surfacing a typed ``WorkerFailure``.  The session converts that into
``Rejected("worker_failed")`` results: a crash mid-batch is visible, typed,
and bounded, never a hang or a silent drop.

Observability rides the same seam.  After every ready handshake — first
spawn or respawn — a ``ProcessReplica`` pings the worker's monotonic clock
(obs/collate.estimate_clock_offset) so shipped span timestamps can be mapped
onto the host timeline; replies carrying a third element (the worker's span
buffer and probe records, see sched/worker.py) are ingested into the host
tracer / probe sink right where the reply lands.  ``ReplicaGroup.call``
re-activates the configured tracer around the dispatch because it often runs
on a fan-pool thread that has no ambient tracer of its own.

Warm snapshots close the respawn compile gap.  A worker process owns every
jit/Pallas executable its shard ever compiled, so a crash used to mean the
replacement re-pays each padded-shape compilation on first contact.  A
``ProcessReplica`` therefore keeps a small *warm log* — one sanitized
(trace-context-stripped) representative message per distinct dispatch shape
— and replays it into every freshly spawned process right after the ready
handshake, before the replica serves its next request.  Paired with the
persistent XLA compilation cache (sched/worker.py points
``jax_compilation_cache_dir`` at the shard-store), the replay re-traces
against on-disk executables instead of recompiling, so a respawned worker
is serving-warm and bit-identical from its first real dispatch.  The log
round-trips through ``Session.warm()``'s ``warm_snapshot.json`` so even a
brand-new session restores the previous run's shape coverage.
"""
from __future__ import annotations

import multiprocessing as mp
import threading

import numpy as np

from repro.obs import trace
from repro.obs.collate import estimate_clock_offset, ingest_worker_spans
from repro.serve.sched.api import WorkerFailure
from repro.serve.sched.worker import execute_bool, execute_topk, worker_main


class ReplicaError(RuntimeError):
    """One dispatch to one replica failed (connection lost or worker error)."""


class InlineReplica:
    """In-process executor over the facade's own ShardEngine."""

    def __init__(self, shard, global_dfs, cfg):
        self._shard = shard
        self._dfs = global_dfs
        self._cfg = cfg
        self._lock = threading.Lock()  # ShardEngine state is not thread-safe
        self.inflight = 0

    def call(self, msg):
        with self._lock:
            op = msg[0]
            if op == "bool":
                return execute_bool(self._shard, msg[1], self._dfs, self._cfg.verified)
            if op == "topk":
                return execute_topk(self._shard, msg[1])
            if op == "ping":
                return "pong"
            if op == "stats":
                return self._shard.metrics.snapshot()
            if op == "caches":
                from repro.serve.sched.worker import cache_report

                return cache_report(self._shard)
            raise ReplicaError(f"unknown op {op!r}")

    def close(self) -> None:
        pass


class ProcessReplica:
    """A worker process serving one shard; lazily spawned, auto-respawned.

    ``obs`` (an ObsConfig) is where shipped worker telemetry lands: spans
    into ``obs.trace`` (time-aligned via the per-spawn clock sync), probe
    records into ``obs.probe_log``.  ``label`` names the replica's process
    lane in the exported trace.
    """

    _WARM_LIMIT = 32  # distinct dispatch shapes worth replaying into a respawn

    def __init__(
        self,
        spec: dict,
        *,
        spawn_timeout_s: float = 120.0,
        obs=None,
        label: str | None = None,
        record_warm: bool = True,
    ):
        self.spec = spec
        self.spawn_timeout_s = spawn_timeout_s
        self.obs = obs
        self.label = label or f"shard{spec['shard_idx']}-worker"
        self.record_warm = record_warm
        self.inflight = 0
        self.pid: int | None = None
        self.clock_offset_ns: int | None = None  # worker clock - host clock
        self.clock_rtt_ns: int | None = None
        self.clock_syncs = 0  # one per (re)spawn; tests assert the re-sync
        self.warm_replays = 0  # entries replayed into the last (re)spawn
        # signature -> sanitized (ctx-stripped) message; ordered, bounded
        self._warm_log: dict = {}
        self._lock = threading.Lock()  # pipe is strict request/response
        self._proc = None
        self._conn = None

    @property
    def alive(self) -> bool:
        return self._conn is not None and self._proc is not None and self._proc.is_alive()

    def _start_locked(self) -> None:
        ctx = mp.get_context("spawn")  # fork is unsafe under a live XLA client
        parent, child = ctx.Pipe()
        proc = ctx.Process(
            target=worker_main, args=(child, self.spec), daemon=True,
            name=f"shard-worker-{self.spec['shard_idx']}",
        )
        proc.start()
        child.close()
        if not parent.poll(self.spawn_timeout_s):
            proc.terminate()
            raise ReplicaError(
                f"worker for shard {self.spec['shard_idx']} not ready within "
                f"{self.spawn_timeout_s}s"
            )
        tag, payload = parent.recv()
        if tag != "ready":
            proc.terminate()
            raise ReplicaError(f"worker failed to build its engine: {payload}")
        self._proc, self._conn = proc, parent
        self.pid = int(payload["pid"])
        self._sync_clock_locked()

    def _sync_clock_locked(self) -> None:
        """Estimate this worker's monotonic-clock offset (min-RTT pings).

        Runs after every ready handshake, so a respawned replica — a fresh
        process with a fresh clock origin — re-syncs before it serves.
        """

        def roundtrip() -> int:
            self._conn.send(("clock",))
            tag, t_worker = self._conn.recv()
            if tag != "ok":
                raise ReplicaError(f"clock sync failed: {t_worker}")
            return int(t_worker)

        self.clock_offset_ns, self.clock_rtt_ns = estimate_clock_offset(roundtrip)
        self.clock_syncs += 1

    def _fail_locked(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
        if self._proc is not None:
            self._proc.terminate()
        self._proc = self._conn = None

    def call(self, msg):
        with self._lock:
            if not self.alive:
                self._fail_locked()  # reap a dead process before respawn
                self._start_locked()
                self._replay_warm_locked()
            payload = self._roundtrip_locked(msg)
            if self.record_warm and msg[0] in ("bool", "topk"):
                self._record_warm_locked(msg)
            return payload

    def _roundtrip_locked(self, msg):
        try:
            self._conn.send(msg)
            reply = self._conn.recv()
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError) as e:
            self._fail_locked()
            raise ReplicaError(f"worker connection lost: {e!r}") from e
        tag, payload = reply[0], reply[1]
        if tag == "err":  # handler error; the worker itself is still up
            raise ReplicaError(payload)
        if len(reply) > 2 and reply[2]:
            self._ingest(reply[2])
        return payload

    # ------------------------------------------------------------- warm log
    @staticmethod
    def _warm_key(msg):
        """Dispatch-shape signature: the jit-specialization key of a message.

        The worker's executables specialize on padded shapes — the boolean
        probe on the (rows, terms) batch shape, the fused ranked kernel on
        its (rows, terms, k) bucket — so one representative message per
        signature covers the whole compile surface.
        """
        op = msg[0]
        if op == "bool":
            return ("bool",) + tuple(msg[1].shape)
        if op == "topk":
            items = msg[1]
            return (
                "topk",
                len(items),
                max((len(it[0]) for it in items), default=0),
                tuple(sorted({int(it[2]) for it in items})),
            )
        return None

    def _record_warm_locked(self, msg) -> None:
        key = self._warm_key(msg)
        if key is None:
            return
        self._warm_log.pop(key, None)
        while len(self._warm_log) >= self._WARM_LIMIT:  # evict oldest shapes
            self._warm_log.pop(next(iter(self._warm_log)))
        self._warm_log[key] = msg[:2]  # ctx stripped: replay is untraced

    def _replay_warm_locked(self) -> None:
        """Replay the warm log into a freshly spawned worker (best-effort).

        Runs after the ready handshake of every (re)spawn: the fresh
        process re-traces each recorded dispatch shape — hitting the
        persistent XLA compile cache instead of the compiler when one is
        configured — so a respawned replica serves its first real request
        re-jit-free.  A replay failure leaves the replica cold, not broken.
        """
        self.warm_replays = 0
        for m in list(self._warm_log.values()):
            try:
                self._roundtrip_locked(m)
                self.warm_replays += 1
            except ReplicaError:
                return

    def export_warm(self) -> list:
        """The warm log as JSON-able entries (Session.warm snapshotting)."""
        with self._lock:
            out = []
            for m in self._warm_log.values():
                if m[0] == "bool":
                    out.append({"op": "bool", "q": np.asarray(m[1]).tolist()})
                else:
                    out.append(
                        {
                            "op": "topk",
                            "items": [
                                [
                                    [int(t) for t in terms],
                                    [int(t) for t in required],
                                    int(k),
                                    int(floor),
                                ]
                                for terms, required, k, floor in m[1]
                            ],
                        }
                    )
            return out

    def preload_warm(self, entries: list) -> None:
        """Seed the warm log from a persisted snapshot (before first spawn)."""
        with self._lock:
            for e in entries:
                if e.get("op") == "bool":
                    m = ("bool", np.asarray(e["q"], dtype=np.int32))
                elif e.get("op") == "topk":
                    m = (
                        "topk",
                        [
                            (tuple(t), tuple(r), int(k), int(f))
                            for t, r, k, f in e["items"]
                        ],
                    )
                else:
                    continue
                key = self._warm_key(m)
                if key is not None:
                    self._warm_log[key] = m

    def _ingest(self, wire: dict) -> None:
        """Land a reply's shipped telemetry on the host obs handles."""
        obs = self.obs
        if obs is None:
            return
        spans = wire.get("spans")
        if spans and obs.trace is not None and self.clock_offset_ns is not None:
            ingest_worker_spans(
                obs.trace,
                spans,
                offset_ns=self.clock_offset_ns,
                pid=self.pid,
                label=self.label,
            )
        probes = wire.get("probes")
        if probes and obs.probe_log is not None:
            obs.probe_log.ingest(probes)

    def close(self) -> None:
        with self._lock:
            if self.alive:
                try:
                    self._conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
                self._proc.join(timeout=2.0)
            self._fail_locked()


class ReplicaGroup:
    """Every replica able to serve one shard + the retry/dispatch policy."""

    def __init__(
        self,
        shard_id: int,
        replicas: list,
        *,
        lo: int = 0,
        n_docs: int = 0,
        retries: int = 1,
        metrics=None,
        obs=None,
    ):
        if not replicas:
            raise ValueError(f"shard {shard_id}: a replica group needs >= 1 replica")
        self.shard_id = shard_id
        self.replicas = replicas
        self.lo = lo  # global doc-id offset (the session's bitmap merge)
        self.n_docs = n_docs
        self.retries = retries
        self.obs = obs  # tracer re-activation on fan-pool threads
        self._retried = metrics.counter("sched.worker_retries") if metrics else None
        self._failed = metrics.counter("sched.worker_failures") if metrics else None

    def call(self, msg):
        """Dispatch to the least-loaded replica; retry once (per config) on
        failure, preferring a sibling replica; then raise WorkerFailure.

        Re-activates the session's tracer for the dispatch: multi-shard
        fan-out runs these calls on pool threads with no ambient tracer, and
        inline replicas record their spans through it (process replicas ship
        theirs back instead).
        """
        tracer = self.obs.trace if self.obs is not None else None
        last: Exception | None = None
        failed = None
        for attempt in range(self.retries + 1):
            replica = min(
                self.replicas, key=lambda r: (r is failed, r.inflight)
            )
            replica.inflight += 1
            try:
                with trace.activate(tracer):
                    return replica.call(msg)
            except ReplicaError as e:
                last = e
                failed = replica
                if self._retried is not None and attempt < self.retries:
                    self._retried.inc()
            finally:
                replica.inflight -= 1
        if self._failed is not None:
            self._failed.inc()
        raise WorkerFailure(
            shard_id=self.shard_id, attempts=self.retries + 1, detail=str(last)
        )

    def close(self) -> None:
        for r in self.replicas:
            r.close()
