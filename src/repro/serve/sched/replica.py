"""Replica groups: least-loaded dispatch over shard executors, retry-once.

A ``ReplicaGroup`` owns every executor that can serve one document
partition.  Two replica kinds implement the same two-method surface
(``call(msg)`` / ``close()`` plus an ``inflight`` load counter):

  * ``InlineReplica`` — the facade engine's own in-process ``ShardEngine``.
    The 0-replica scheduler path: no processes, no pickling, execution on
    the session's dispatch thread through the *same* ``execute_bool`` /
    ``execute_topk`` helpers the workers run.
  * ``ProcessReplica`` — a spawned worker process (sched/worker.py) holding
    its own engine over the shared mmap shard-store.  Spawn is lazy (first
    ``call``) and a replica that died is respawned on its next use, so a
    crashed worker costs one failed dispatch, not a dead shard.

``ReplicaGroup.call`` picks the least-loaded live replica (smallest
``inflight``), and on a ``ReplicaError`` retries the batch — preferring a
*different* replica — up to ``SchedConfig.worker_retries`` times before
surfacing a typed ``WorkerFailure``.  The session converts that into
``Rejected("worker_failed")`` results: a crash mid-batch is visible, typed,
and bounded, never a hang or a silent drop.
"""
from __future__ import annotations

import multiprocessing as mp
import threading

from repro.serve.sched.api import WorkerFailure
from repro.serve.sched.worker import execute_bool, execute_topk, worker_main


class ReplicaError(RuntimeError):
    """One dispatch to one replica failed (connection lost or worker error)."""


class InlineReplica:
    """In-process executor over the facade's own ShardEngine."""

    def __init__(self, shard, global_dfs, cfg):
        self._shard = shard
        self._dfs = global_dfs
        self._cfg = cfg
        self._lock = threading.Lock()  # ShardEngine state is not thread-safe
        self.inflight = 0

    def call(self, msg):
        with self._lock:
            op = msg[0]
            if op == "bool":
                return execute_bool(self._shard, msg[1], self._dfs, self._cfg.verified)
            if op == "topk":
                return execute_topk(self._shard, msg[1])
            if op == "ping":
                return "pong"
            if op == "stats":
                return self._shard.metrics.snapshot()
            raise ReplicaError(f"unknown op {op!r}")

    def close(self) -> None:
        pass


class ProcessReplica:
    """A worker process serving one shard; lazily spawned, auto-respawned."""

    def __init__(self, spec: dict, *, spawn_timeout_s: float = 120.0):
        self.spec = spec
        self.spawn_timeout_s = spawn_timeout_s
        self.inflight = 0
        self._lock = threading.Lock()  # pipe is strict request/response
        self._proc = None
        self._conn = None

    @property
    def alive(self) -> bool:
        return self._conn is not None and self._proc is not None and self._proc.is_alive()

    def _start_locked(self) -> None:
        ctx = mp.get_context("spawn")  # fork is unsafe under a live XLA client
        parent, child = ctx.Pipe()
        proc = ctx.Process(
            target=worker_main, args=(child, self.spec), daemon=True,
            name=f"shard-worker-{self.spec['shard_idx']}",
        )
        proc.start()
        child.close()
        if not parent.poll(self.spawn_timeout_s):
            proc.terminate()
            raise ReplicaError(
                f"worker for shard {self.spec['shard_idx']} not ready within "
                f"{self.spawn_timeout_s}s"
            )
        tag, payload = parent.recv()
        if tag != "ready":
            proc.terminate()
            raise ReplicaError(f"worker failed to build its engine: {payload}")
        self._proc, self._conn = proc, parent

    def _fail_locked(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
        if self._proc is not None:
            self._proc.terminate()
        self._proc = self._conn = None

    def call(self, msg):
        with self._lock:
            if not self.alive:
                self._fail_locked()  # reap a dead process before respawn
                self._start_locked()
            try:
                self._conn.send(msg)
                tag, payload = self._conn.recv()
            except (EOFError, BrokenPipeError, ConnectionResetError, OSError) as e:
                self._fail_locked()
                raise ReplicaError(f"worker connection lost: {e!r}") from e
            if tag == "err":  # handler error; the worker itself is still up
                raise ReplicaError(payload)
            return payload

    def close(self) -> None:
        with self._lock:
            if self.alive:
                try:
                    self._conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
                self._proc.join(timeout=2.0)
            self._fail_locked()


class ReplicaGroup:
    """Every replica able to serve one shard + the retry/dispatch policy."""

    def __init__(
        self,
        shard_id: int,
        replicas: list,
        *,
        lo: int = 0,
        n_docs: int = 0,
        retries: int = 1,
        metrics=None,
    ):
        if not replicas:
            raise ValueError(f"shard {shard_id}: a replica group needs >= 1 replica")
        self.shard_id = shard_id
        self.replicas = replicas
        self.lo = lo  # global doc-id offset (the session's bitmap merge)
        self.n_docs = n_docs
        self.retries = retries
        self._retried = metrics.counter("sched.worker_retries") if metrics else None
        self._failed = metrics.counter("sched.worker_failures") if metrics else None

    def call(self, msg):
        """Dispatch to the least-loaded replica; retry once (per config) on
        failure, preferring a sibling replica; then raise WorkerFailure."""
        last: Exception | None = None
        failed = None
        for attempt in range(self.retries + 1):
            replica = min(
                self.replicas, key=lambda r: (r is failed, r.inflight)
            )
            replica.inflight += 1
            try:
                return replica.call(msg)
            except ReplicaError as e:
                last = e
                failed = replica
                if self._retried is not None and attempt < self.retries:
                    self._retried.inc()
            finally:
                replica.inflight -= 1
        if self._failed is not None:
            self._failed.inc()
        raise WorkerFailure(
            shard_id=self.shard_id, attempts=self.retries + 1, detail=str(last)
        )

    def close(self) -> None:
        for r in self.replicas:
            r.close()
