"""Unified request/response types for the serving front-end.

Every entry point — conjunctive Boolean, ranked top-k, the legacy
``query_*`` wrappers — is one shape on the wire now: a ``QueryRequest``
submitted to a ``Session`` resolves to exactly one of

  * ``QueryResult``  — the answer (doc ids, plus scores on the ranked path)
    with its queue/service timing attached, or
  * ``Rejected``     — a typed shed decision (queue saturation, tenant
    quota, missed deadline, worker failure, shutdown).  Nothing is ever
    dropped silently: an admitted request's future always resolves.

Both carry ``ok`` so callers can branch without isinstance checks.
``WorkerFailure`` is the internal typed error a replica group raises after
its retry budget is spent; the session converts it to ``Rejected`` results
for the affected requests.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Rejected.reason values (closed set; tests and benchmarks match on these)
REJECT_QUEUE_FULL = "queue_full"
REJECT_TENANT_QUOTA = "tenant_quota"
REJECT_DEADLINE = "deadline"
REJECT_WORKER_FAILED = "worker_failed"
REJECT_SHUTDOWN = "shutdown"

MODE_BOOLEAN = "boolean"
MODE_RANKED = "ranked"


@dataclass(eq=False)  # terms is an array; == would be elementwise-ambiguous
class QueryRequest:
    """One query for ``Session.submit`` (either serving mode).

    ``terms`` is a 1-D array/sequence of term ids, ``-1``-padded entries
    ignored.  ``mode`` picks conjunctive Boolean ("boolean") or BM25 top-k
    ("ranked"); ranked requests read ``k`` and the optional per-position
    ``required`` mask (True = this term is conjunctively required — an
    all-True mask is an AND-of-terms ranked query).  ``tenant`` and
    ``priority`` feed admission control: when the queue saturates, the
    lowest-priority queued request is shed first.  ``deadline_ms`` bounds
    the time from submit to dispatch — a request still queued past its
    deadline is shed with ``Rejected("deadline")`` and never reaches a
    worker (``SchedConfig.default_deadline_ms`` applies when unset).
    """

    terms: np.ndarray
    mode: str = MODE_BOOLEAN
    k: int = 10
    required: np.ndarray | None = None
    tenant: str = "default"
    priority: int = 0
    deadline_ms: float | None = None

    def __post_init__(self):
        if self.mode not in (MODE_BOOLEAN, MODE_RANKED):
            raise ValueError(f"mode must be 'boolean' or 'ranked', got {self.mode!r}")
        self.terms = np.atleast_1d(np.asarray(self.terms, dtype=np.int32))
        if self.terms.ndim != 1:
            raise ValueError(f"terms must be 1-D, got shape {self.terms.shape}")
        if self.required is not None:
            req = np.atleast_1d(np.asarray(self.required, dtype=bool))
            if req.shape != self.terms.shape:
                raise ValueError(
                    f"required mask shape {req.shape} != terms {self.terms.shape}"
                )
            self.required = req


@dataclass(eq=False)  # ids/scores are arrays; compare contents explicitly
class QueryResult:
    """The answer to an admitted request.

    ``ids`` are sorted doc ids for Boolean queries and (score desc, id asc)
    ranked doc ids with ``scores`` for ranked queries — bit-identical to the
    legacy ``query_batch`` / ``query_topk`` results for the same engine.
    """

    ids: np.ndarray
    scores: np.ndarray | None = None
    queue_us: float = 0.0  # submit -> dispatch
    service_us: float = 0.0  # dispatch -> resolved (whole coalesced batch)
    # service_us decomposed by the session: dispatch_us (row stacking +
    # planning), execute_us (shard fan-out wall), merge_us (bitmap/heap
    # fold) — None for short-circuited results that never saw a batch
    phases: dict | None = None

    @property
    def ok(self) -> bool:
        return True

    def autopsy(self) -> dict:
        """Where this request's latency went: queue/dispatch/execute/merge.

        Returns absolute microseconds plus each phase's fraction of the
        total (``*_frac``).  Phases cover the whole coalesced batch the
        request rode in — the scheduler amortizes, so a request's execute
        time is its batch's execute time.
        """
        phases = {
            "queue_us": self.queue_us,
            "dispatch_us": 0.0,
            "execute_us": 0.0,
            "merge_us": 0.0,
        }
        phases.update(self.phases or {})
        total = self.queue_us + self.service_us
        out = {"total_us": total, "service_us": self.service_us, **phases}
        for k, v in phases.items():
            out[k.replace("_us", "_frac")] = v / total if total > 0 else 0.0
        return out


@dataclass
class Rejected:
    """A typed shed decision — the request was NOT served.

    ``reason`` is one of the REJECT_* constants; ``detail`` is free-form
    context (e.g. the worker error after the retry budget is spent).
    """

    reason: str
    tenant: str = "default"
    detail: str = ""

    @property
    def ok(self) -> bool:
        return False


@dataclass
class WorkerFailure(RuntimeError):
    """A replica group exhausted its retry budget on one dispatched batch."""

    shard_id: int = -1
    attempts: int = 0
    detail: str = ""

    def __post_init__(self):
        super().__init__(
            f"shard {self.shard_id} failed after {self.attempts} attempt(s): "
            f"{self.detail}"
        )


# what Session.submit/submit_async futures resolve to
SubmitOutcome = QueryResult | Rejected
