"""Process shard worker: one ShardEngine served over a multiprocessing pipe.

The GIL is why the serving front-end sheds threads for processes: the
probe/verify phase is many small numpy ops, and the measured convoy made
K=4 thread fan-out ~8x slower than serial.  A process replica owns a full
``ShardEngine`` for one document partition, rebuilt from the persistent
shard-store — which is what makes replicas cheap: streams are ``np.memmap``
arenas, so spawning R replicas of a shard shares one page cache and none of
them re-encode anything (engines reload ~28x faster than re-encoding).

Protocol (request/response over one ``multiprocessing.Pipe``):

  ("ready", {"shard": i, "pid": p}) worker -> parent once the engine is built
  ("bool", q[, ctx])              (B, T) padded int32 -> ("ok", packed bitmap)
  ("topk", [(terms, required, k, floor), ...][, ctx])
                                  -> ("ok", [(ids, scores), ...]) global ids
  ("ping",)                       -> ("ok", "pong") — forces spawn/warm
  ("clock",)                      -> ("ok", perf_counter_ns) — offset sync
  ("stats",)                      -> ("ok", shard metrics snapshot)
  ("caches",)                     -> ("ok", cache_report) — jit-cache sizes,
                                  observed fused shapes and arena counters;
                                  the warm-snapshot tests read this to prove
                                  a respawned worker is re-jit-free
  ("crash",)                      hard-exits the process (crash-path tests)
  ("stop",)                       clean shutdown
  ("err", traceback_str)          any handler failure (worker stays alive)

When the spec carries ``compile_cache_dir`` the worker points JAX's
persistent compilation cache there before building its engine (best-effort
— an old jax without the knobs just stays in-memory).  Every worker of
every (re)spawn shares that directory, so the warm-log replay a fresh
process receives (sched/replica.py) re-traces against executables already
on disk instead of re-invoking XLA.

``ctx`` is an optional ``repro.obs.TraceContext``: when present the reply
grows a third element, ``("ok", payload, {"spans": [...], "probes": [...]})``
— the worker's span buffer (drained per request, absolute worker-clock
nanoseconds) and its routed-probe records, which the host replica maps onto
its own timeline / probe sink (obs/collate.py).  The worker runs its own
``Tracer`` and an in-memory ``ProbeLog`` either way; with no ctx (or
``ctx.trace`` false) nothing extra is recorded or shipped, keeping the
trace-off wire cost at zero.

Workers plan locally: each carries the *global* document frequencies, so
``plan_batch`` on a worker reproduces the facade plan for its shard exactly
— term order, run masks and guided/decode routes are identical, which is
what keeps the process-parallel path bit-identical to in-process serving.

``execute_bool`` / ``execute_topk`` are shared with ``InlineReplica`` so
the inline (0-replica) scheduler path runs the very same code.
"""
from __future__ import annotations

import os
import time
import traceback

import numpy as np

from repro.obs import trace


def execute_bool(shard, q: np.ndarray, global_dfs: np.ndarray, verified: bool) -> np.ndarray:
    """Plan (global term order) + execute one shard's slice of a batch."""
    from repro.serve.planner import plan_batch

    plan = plan_batch(q, global_dfs, [shard], verified=verified)
    return shard.execute(q, plan.shard_plans[0], plan.qplans)


def execute_topk(shard, items: list) -> list:
    """Serve [(terms, required, k, floor)] -> [(global ids, scores)].

    Applies the ranked run mask locally (skip when no term has local
    postings or a required term is absent — same rule as
    planner.ranked_run_mask), so the session can broadcast one item list to
    every shard group.  Live items go through ``shard.query_topk_batch`` —
    with ``ranked.fused_kernel`` that is one fused Pallas dispatch for the
    whole batch, otherwise a loop over the multi-phase path.
    """
    empty = (np.zeros(0, np.int32), np.zeros(0, np.int64))
    ldfs = shard.local_dfs
    out: list = [empty] * len(items)
    idx, batch = [], []
    for pos, (terms, required, k, floor) in enumerate(items):
        terms = tuple(int(t) for t in terms)
        required = tuple(int(t) for t in required)
        if (
            not terms
            or k <= 0
            or not any(int(ldfs[t]) for t in terms)
            or any(int(ldfs[t]) == 0 for t in required)
        ):
            continue
        idx.append(pos)
        batch.append((terms, int(k), required, int(floor)))
    if batch:
        for pos, r in zip(idx, shard.query_topk_batch(batch)):
            out[pos] = (r.ids, r.scores)
    return out


def cache_report(shard) -> dict:
    """Compiled-executable census for one engine: the warm-restore probe.

    ``dense_cache`` / ``dense_shapes`` cover the fused ranked kernel's jit
    cache in *this* process; ``arena`` is the device-arena residency
    counters (uploads must stay at 1 per process no matter how many
    dispatches ran).  Inline replicas report the same shape.
    """
    from repro.kernels.fused_query import dense

    arena = getattr(getattr(shard, "_ranked", None), "_arena", None) or None
    return {
        "dense_cache": dense.cache_size(),
        "dense_shapes": sorted(dense.observed_shapes()),
        "arena": arena.counters.as_dict() if arena else None,
    }


def _configure_compile_cache(cache_dir: str | None) -> None:
    """Point JAX's persistent compilation cache at the shard-store (best
    effort): respawned workers then deserialize executables instead of
    recompiling them during the warm-log replay."""
    if not cache_dir:
        return
    try:
        import jax

        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        for knob, val in (
            # CPU-backend kernels compile fast/small; without zeroing the
            # thresholds the cache would skip exactly the executables the
            # respawn replay wants back
            ("jax_persistent_cache_min_compile_time_secs", 0.0),
            ("jax_persistent_cache_min_entry_size_bytes", 0),
        ):
            try:
                jax.config.update(knob, val)
            except Exception:
                pass
    except Exception:
        pass


def _build_shard(spec: dict):
    """Reconstruct the spec'd ShardEngine from the persistent shard-store."""
    from repro.core.learned_bloom import LearnedBloom
    from repro.index.store import load_index
    from repro.serve.config import ServeConfig
    from repro.serve.shard import ShardEngine, slice_bloom

    lb = LearnedBloom(
        params=spec["lb_params"],
        tau=spec["lb_tau"],
        backup_keys=spec["lb_backup_keys"],
        n_docs=int(spec["n_docs"]),
    )
    lo, hi = int(spec["lo"]), int(spec["hi"])
    inv, store = load_index(
        os.path.join(spec["store_dir"], f"shard-{spec['shard_idx']:04d}"), mmap=True
    )
    cfg = ServeConfig(**spec["cfg_kwargs"])
    shard = ShardEngine(
        slice_bloom(lb, lo, hi), inv, spec["li_cfg"], cfg, lo=lo, hi=hi, tier2=store
    )
    shard.shard_id = int(spec["shard_idx"])
    return shard, cfg


def worker_main(conn, spec: dict) -> None:
    """Entry point of a spawned process replica (see module docstring)."""
    from repro.obs.probelog import ProbeLog
    from repro.obs.trace import Tracer

    try:
        _configure_compile_cache(spec.get("compile_cache_dir"))
        shard, cfg = _build_shard(spec)
        # in-memory probe sink, installed before the engine's first probe
        # (GuidedPostings captures the handle lazily); drained per request
        # and shipped back when the ctx asks, discarded otherwise
        plog = ProbeLog()
        cfg.obs.probe_log = plog
        wtracer = Tracer(name=f"shard-worker-{spec['shard_idx']}")
        global_dfs = np.asarray(spec["global_dfs"])
        conn.send(("ready", {"shard": int(spec["shard_idx"]), "pid": os.getpid()}))
    except Exception:
        try:
            conn.send(("err", traceback.format_exc()))
        finally:
            return
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return
        op = msg[0]
        if op == "stop":
            return
        if op == "crash":  # test hook: die mid-batch, no reply, no cleanup
            os._exit(17)
        try:
            if op == "ping":
                conn.send(("ok", "pong"))
            elif op == "clock":
                conn.send(("ok", time.perf_counter_ns()))
            elif op in ("bool", "topk"):
                ctx = msg[2] if len(msg) > 2 else None
                traced = ctx is not None and ctx.trace
                with trace.activate(wtracer if traced else None), trace.span(
                    f"worker.{op}", trace_id=getattr(ctx, "trace_id", 0)
                ), plog.context(query=None, shard=shard.shard_id):
                    if op == "bool":
                        payload = execute_bool(shard, msg[1], global_dfs, cfg.verified)
                    else:
                        payload = execute_topk(shard, msg[1])
                probes = plog.drain()  # drain always: bound worker memory
                if ctx is None:
                    conn.send(("ok", payload))
                else:
                    wire = {"spans": wtracer.drain_wire() if traced else []}
                    if ctx.probe:
                        wire["probes"] = probes
                    conn.send(("ok", payload, wire))
            elif op == "stats":
                conn.send(("ok", shard.metrics.snapshot()))
            elif op == "caches":
                conn.send(("ok", cache_report(shard)))
            else:
                conn.send(("err", f"unknown op {op!r}"))
        except Exception:
            try:
                conn.send(("err", traceback.format_exc()))
            except (BrokenPipeError, OSError):
                return
