"""Collating worker spans onto the host timeline.

Process replicas (serve/sched) run their own ``Tracer`` on their own
``perf_counter_ns`` clock — the two clocks share a rate (CLOCK_MONOTONIC)
but not an origin, and the origin gap is different for every spawned
process.  This module owns the two halves of stitching them together:

  * ``estimate_clock_offset`` — the ping half.  N round trips to the worker
    keep the minimum-RTT sample; under the symmetric-delay assumption the
    worker clock read happened at the midpoint of that round trip, so
    ``offset = t_worker - (t0 + t1) / 2`` with error bounded by RTT/2 (a few
    microseconds over a local pipe).  ``ProcessReplica`` runs this after
    every ready handshake, so a respawned replica re-syncs automatically.

  * ``span_from_wire`` / ``ingest_worker_spans`` — the merge half.  Worker
    spans travel as wire dicts with absolute worker-clock nanoseconds
    (Tracer.drain_wire); subtracting the offset and the host tracer's epoch
    lands them on the host timeline in host microseconds.  Each span keeps
    the worker's os pid, so the Chrome trace renders every replica as its
    own named process lane next to the host's lane 0.

``nesting_violations`` is the invariant checker the tests (and anyone
debugging a skewed trace) lean on: within one (pid, tid) lane, complete
spans must either nest or be disjoint — a partial overlap means the clock
mapping or the span bookkeeping is wrong.
"""
from __future__ import annotations

import time
from typing import Callable, Iterable

from repro.obs.trace import Span, Tracer

CLOCK_SYNC_PINGS = 7  # round trips per sync; min-RTT sample wins


def estimate_clock_offset(
    roundtrip: Callable[[], int], n: int = CLOCK_SYNC_PINGS
) -> tuple[int, int]:
    """Estimate a remote monotonic clock's offset from this process's.

    ``roundtrip()`` performs one request/response exchange and returns the
    remote ``perf_counter_ns`` reading.  Returns ``(offset_ns, rtt_ns)`` of
    the minimum-RTT sample; ``remote - offset_ns`` maps a remote timestamp
    into this process's clock, with error bounded by ``rtt_ns / 2``.
    """
    if n < 1:
        raise ValueError(f"clock sync needs >= 1 ping, got {n}")
    best: tuple[int, int] | None = None
    for _ in range(n):
        t0 = time.perf_counter_ns()
        t_remote = int(roundtrip())
        t1 = time.perf_counter_ns()
        rtt = t1 - t0
        if best is None or rtt < best[1]:
            best = (t_remote - (t0 + t1) // 2, rtt)
    return best


def span_from_wire(d: dict, *, offset_ns: int, epoch_ns: int, pid: int) -> Span:
    """One wire dict (Tracer.drain_wire) -> a Span on the host timeline."""
    return Span(
        name=d["name"],
        ts_us=(d["ts_ns"] - offset_ns - epoch_ns) / 1e3,
        dur_us=d["dur_us"],
        tid=d["tid"],
        depth=d["depth"],
        attrs=dict(d.get("attrs") or {}),
        pid=pid,
    )


def ingest_worker_spans(
    tracer: Tracer,
    wire_spans: Iterable[dict],
    *,
    offset_ns: int,
    pid: int,
    label: str | None = None,
) -> int:
    """Merge a replica's shipped span buffer into the host tracer.

    ``offset_ns`` comes from ``estimate_clock_offset`` against that replica;
    ``pid`` keys the replica's Chrome-trace lane and ``label`` names it.
    Returns the number of spans ingested.
    """
    if label is not None:
        tracer.set_process_name(pid, label)
    n = 0
    for d in wire_spans:
        tracer.add_span(
            span_from_wire(d, offset_ns=offset_ns, epoch_ns=tracer.epoch_ns, pid=pid)
        )
        n += 1
    return n


def nesting_violations(spans: Iterable[Span], slack_us: float = 0.0) -> list[str]:
    """Check the per-lane nesting invariant over complete spans.

    Within one (pid, tid) lane, any two spans must either nest (one interval
    contains the other) or be disjoint; a partial overlap beyond
    ``slack_us`` is reported.  Returns human-readable violation strings
    (empty = the collated timeline is consistent).
    """
    lanes: dict[tuple[int, int], list[Span]] = {}
    for s in spans:
        lanes.setdefault((s.pid, s.tid), []).append(s)
    bad: list[str] = []
    for (pid, tid), lane in lanes.items():
        # sort by start, longest first, so containment shows up as a stack
        lane.sort(key=lambda s: (s.ts_us, -s.dur_us))
        stack: list[Span] = []
        for s in lane:
            while stack and s.ts_us >= stack[-1].ts_us + stack[-1].dur_us - slack_us:
                stack.pop()
            if stack:
                parent = stack[-1]
                if s.ts_us + s.dur_us > parent.ts_us + parent.dur_us + slack_us:
                    bad.append(
                        f"lane (pid={pid}, tid={tid}): {s.name!r} "
                        f"[{s.ts_us:.1f}, {s.ts_us + s.dur_us:.1f}]us partially "
                        f"overlaps {parent.name!r} "
                        f"[{parent.ts_us:.1f}, {parent.ts_us + parent.dur_us:.1f}]us"
                    )
            stack.append(s)
    return bad
