"""Metrics registry: counters, gauges, fixed-bucket histograms, collectors.

One ``Registry`` per engine (the facade aggregates its shards' registries
through collectors) replaces the hand-rolled stats dicts that used to live
in serve/boolean.py, serve/shard.py, serve/cache.py, postings/search.py and
rank/topk.py.  ``Registry.snapshot()`` is the single read path: primitives
report their values under their dotted names and registered collectors are
invoked lazily (a collector returning None is omitted, which is how
"no ranked queries yet → no 'ranked' section" is expressed).

``Histogram`` is fixed-bucket: observations land in log-spaced buckets and
percentiles interpolate linearly inside the bracketing bucket, clamped to
the observed min/max — so p50/p90/p99 are exact to within one bucket width
(tested against numpy quantiles).  Fixed buckets keep ``observe`` O(log B)
with zero allocation, which is what lets the serving hot path record
per-phase latencies unconditionally.

``Registry.reset()`` is the single reset path: primitives zero and every
registered reset hook runs — the facade resets shards, shards reset their
guided/ranked/cache accounting — so no caller ever reaches into another
component's private state to start a fresh measurement window.
"""
from __future__ import annotations

import threading
from bisect import bisect_right
from typing import Callable


class Counter:
    """Monotonic event count (resettable for measurement windows)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def snapshot(self) -> int:
        return int(self.value)


class Gauge:
    """Last-set value (queue depth, resident bytes, ...)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0.0

    def snapshot(self) -> float:
        return float(self.value)


def default_latency_buckets() -> list[float]:
    """Log-spaced microsecond buckets, 1us .. 10s (4 per decade)."""
    return [10 ** (k / 4) for k in range(29)]


class Histogram:
    """Fixed-bucket histogram with interpolated percentile summaries.

    Thread-safe: the sched loop's runner threads observe while the session
    (or an SLO scrape) snapshots.  One lock covers observe/snapshot/reset so
    a snapshot is a *consistent* view — count always equals the bucket sum,
    and min/max always bracket the percentiles — instead of a torn read
    mid-observe.  The lock is uncontended in the common case and cheaper
    than the bisect it guards.
    """

    def __init__(self, buckets: list[float] | None = None):
        edges = sorted(float(b) for b in (buckets or default_latency_buckets()))
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        self.edges = edges  # counts[i] holds edges[i-1] <= v < edges[i]
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect_right(self.edges, v)] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def percentile(self, q: float) -> float:
        """Interpolated q-th percentile (0..100), exact within one bucket."""
        with self._lock:
            return self._percentile_locked(q)

    def _percentile_locked(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile wants 0..100, got {q}")
        target = q / 100.0 * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            # bucket i spans [edges[i-1], edges[i]); the open tails clamp to
            # the observed extremes, as does the interpolation inside
            lo = self.edges[i - 1] if i > 0 else self.min
            hi = self.edges[i] if i < len(self.edges) else self.max
            lo, hi = max(lo, self.min), min(hi, self.max)
            if cum + c >= target:
                frac = (target - cum) / c
                return lo + (hi - lo) * frac
            cum += c
        return self.max

    def reset(self) -> None:
        with self._lock:
            self.counts = [0] * (len(self.edges) + 1)
            self.count = 0
            self.sum = 0.0
            self.min = float("inf")
            self.max = float("-inf")

    def snapshot(self) -> dict[str, float] | None:
        with self._lock:
            if self.count == 0:
                return None
            return {
                "count": self.count,
                "sum": self.sum,
                "mean": self.sum / self.count,
                "min": self.min,
                "max": self.max,
                "p50": self._percentile_locked(50),
                "p90": self._percentile_locked(90),
                "p99": self._percentile_locked(99),
            }


class Registry:
    """Named metrics + lazy collectors behind one snapshot()/reset() pair.

    Dotted names nest in the snapshot ("latency.plan_us" lands under
    snapshot()["latency"]["plan_us"]); collectors own a whole top-level key
    and may carry a reset hook so ``reset()`` reaches every accounting
    window exactly once, with no caller touching private state.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._collectors: dict[str, Callable[[], object]] = {}
        self._reset_hooks: list[Callable[[], None]] = []

    # ------------------------------------------------------------- create
    def _get_or_create(self, name: str, kind, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} is {type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, Gauge)

    def histogram(self, name: str, buckets: list[float] | None = None) -> Histogram:
        return self._get_or_create(name, Histogram, lambda: Histogram(buckets))

    def register(
        self,
        name: str,
        collector: Callable[[], object],
        *,
        reset: Callable[[], None] | None = None,
    ) -> None:
        """Attach a zero-arg collector under a top-level snapshot key; a
        None return omits the key.  ``reset`` joins the registry's hooks."""
        with self._lock:
            self._collectors[name] = collector
            if reset is not None:
                self._reset_hooks.append(reset)

    # ------------------------------------------------------------- read
    def snapshot(self) -> dict:
        out: dict = {}
        with self._lock:
            metrics = list(self._metrics.items())
            collectors = list(self._collectors.items())
        for name, m in metrics:
            v = m.snapshot()
            if v is None:
                continue
            node = out
            parts = name.split(".")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = v
        for name, fn in collectors:
            v = fn()
            if v is not None:
                out[name] = v
        return out

    def reset(self) -> None:
        with self._lock:
            metrics = list(self._metrics.values())
            hooks = list(self._reset_hooks)
        for m in metrics:
            m.reset()
        for hook in hooks:
            hook()
