"""Zero-dependency span tracer for the query path.

One ``Tracer`` collects nestable, attributed spans and exports them in the
Chrome-trace JSON format (open ``chrome://tracing`` or https://ui.perfetto.dev
and drop the file in).  The design constraint is the serving hot path: when
no tracer is installed, ``span()`` returns a shared no-op singleton — no
object allocation, no clock read — so the trace-off cost is one thread-local
attribute lookup per call site.

Spans are *ambient*: instead of threading a tracer through every layer
(facade → planner → shard → guided probes → kernel dispatch), an engine
installs its tracer for the duration of a batch with ``activate`` and any
code underneath — including the Pallas host bridges in repro.kernels — opens
spans through the module-level ``span()``.  Activation is thread-local; the
facade re-activates inside worker threads when the probe phase fans out, so
spans carry the worker's tid and the trace shows real parallelism.

Span timestamps are ``perf_counter_ns`` relative to the tracer's epoch,
reported in microseconds (the Chrome trace unit).  Attributes are free-form
key/values rendered into the event's ``args``; callers attach measured
counters after entry via ``handle.set(bytes=...)``.

Spans cross process boundaries: a worker runs its own ``Tracer``, ships
finished spans as wire dicts (``drain_wire`` — absolute worker-clock
nanoseconds, so no epoch needs to travel), and the host maps them onto its
own timeline with the replica's estimated clock offset (obs/collate.py).
``Span.pid`` keeps each process in its own Chrome-trace lane;
``set_process_name`` labels the lanes.  The ``TraceContext`` carried with
each IPC request tells the worker whether to trace at all, so the trace-off
path still costs nothing on the wire.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field


@dataclass
class Span:
    """One finished span (ts/dur in microseconds since the tracer epoch)."""

    name: str
    ts_us: float
    dur_us: float
    tid: int
    depth: int  # nesting level inside its thread (0 = top-level)
    attrs: dict = field(default_factory=dict)
    pid: int = 0  # 0 = the tracer's own process; workers keep their os pid


@dataclass
class TraceContext:
    """Per-request observability contract carried through worker IPC.

    Pickles with the request message; the worker reads it to decide what to
    ship back (span buffer, probe records) and tags its spans with
    ``trace_id`` so one request renders end-to-end across pid lanes.
    """

    trace_id: int = 0
    trace: bool = False  # ship finished spans back with the response
    probe: bool = False  # ship routed-probe records back with the response


class _NullSpan:
    """Shared no-op handle: the entire trace-off path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()

_ambient = threading.local()


def current() -> "Tracer | None":
    """The tracer installed on this thread, or None (tracing off)."""
    return getattr(_ambient, "tracer", None)


def span(name: str, **attrs) -> "_SpanHandle | _NullSpan":
    """Open a span on the ambient tracer; the no-op singleton when off."""
    tracer = getattr(_ambient, "tracer", None)
    if tracer is None:
        return NULL_SPAN
    return tracer.span(name, **attrs)


class _Activation:
    """Context manager installing a tracer as this thread's ambient one."""

    __slots__ = ("_tracer", "_prev")

    def __init__(self, tracer: "Tracer | None"):
        self._tracer = tracer

    def __enter__(self) -> "Tracer | None":
        self._prev = getattr(_ambient, "tracer", None)
        if self._tracer is not None:
            _ambient.tracer = self._tracer
        return self._tracer

    def __exit__(self, *exc) -> bool:
        if self._tracer is not None:
            _ambient.tracer = self._prev
        return False


def activate(tracer: "Tracer | None") -> _Activation:
    """Install ``tracer`` for a with-block; ``activate(None)`` is a no-op
    (it leaves any outer activation in place, so a traced caller still sees
    spans from an engine whose own config carries no tracer)."""
    return _Activation(tracer)


class _SpanHandle:
    """Live span: records a Span onto its tracer at ``__exit__``."""

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "_SpanHandle":
        """Attach measured attributes (bytes touched, counts) after entry."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_SpanHandle":
        self._tracer._stack().append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        tracer = self._tracer
        stack = tracer._stack()
        stack.pop()
        tracer._record(
            Span(
                name=self.name,
                ts_us=(self._t0 - tracer.epoch_ns) / 1e3,
                dur_us=(t1 - self._t0) / 1e3,
                tid=threading.get_ident(),
                depth=len(stack),
                attrs=self.attrs,
            )
        )
        return False


class Tracer:
    """Collects spans; thread-safe; exports Chrome-trace JSON."""

    def __init__(self, name: str = "repro-serve"):
        self.name = name
        self.epoch_ns = time.perf_counter_ns()
        self.spans: list[Span] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._process_names: dict[int, str] = {0: name}

    # ------------------------------------------------------------- record
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, s: Span) -> None:
        with self._lock:
            self.spans.append(s)

    def add_span(self, s: Span) -> None:
        """Append an externally constructed span (collated worker spans,
        retroactive queue-wait spans) onto this tracer's timeline."""
        with self._lock:
            self.spans.append(s)

    def set_process_name(self, pid: int, label: str) -> None:
        """Label a pid lane in the exported trace (host lane 0 is prenamed)."""
        with self._lock:
            self._process_names[int(pid)] = label

    def span(self, name: str, **attrs) -> _SpanHandle:
        return _SpanHandle(self, name, attrs)

    def activate(self) -> _Activation:
        return _Activation(self)

    def reset(self) -> None:
        with self._lock:
            self.spans.clear()
        self.epoch_ns = time.perf_counter_ns()

    # --------------------------------------------------------------- wire
    def drain_wire(self) -> list[dict]:
        """Pop finished spans as picklable wire dicts for IPC shipping.

        Timestamps go out as *absolute* ``perf_counter_ns`` values
        (``ts_ns = epoch_ns + ts_us*1e3``): the receiving host subtracts the
        replica's estimated clock offset and re-bases onto its own epoch
        (obs/collate.span_from_wire), so the epoch itself never travels.
        The epoch is kept — a worker drains after every request without
        restarting its clock.
        """
        with self._lock:
            spans, self.spans = self.spans, []
        return [
            {
                "name": s.name,
                "ts_ns": int(self.epoch_ns + s.ts_us * 1e3),
                "dur_us": s.dur_us,
                "tid": s.tid,
                "depth": s.depth,
                "attrs": s.attrs,
            }
            for s in spans
        ]

    # ------------------------------------------------------------- export
    def chrome_trace(self) -> dict:
        """The trace as a Chrome/Perfetto ``traceEvents`` document.

        Every span becomes one complete ("X") event; nesting is implied by
        (pid, tid, ts, dur) containment, which the viewers render as stacks.
        Worker spans collated from process replicas keep their own pid, so
        each replica renders as its own named process lane ("M" metadata
        events carry the labels).
        """
        with self._lock:
            spans = list(self.spans)
            names = dict(self._process_names)
        events = [
            {
                "name": s.name,
                "cat": "serve",
                "ph": "X",
                "ts": s.ts_us,
                "dur": s.dur_us,
                "pid": s.pid,
                "tid": s.tid,
                "args": dict(s.attrs),
            }
            for s in spans
        ]
        n_spans = len(events)
        events += [
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": label},
            }
            for pid, label in sorted(names.items())
        ]
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"tracer": self.name, "n_spans": n_spans},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
