"""Observability for the serving stack: tracing, metrics, probe logging.

  trace.py     nestable span tracer, Chrome-trace/Perfetto JSON export,
               ambient activation so deep layers need no tracer plumbing
  metrics.py   counters / gauges / fixed-bucket histograms behind one
               Registry.snapshot() / Registry.reset() pair
  probelog.py  per-(query, term, shard) routed-probe JSONL records — the
               training data for the learned guided-vs-decode cost model
"""
from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.probelog import ProbeLog, ProbeRecord
from repro.obs.trace import NULL_SPAN, Span, Tracer, activate, current, span

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "NULL_SPAN",
    "ProbeLog",
    "ProbeRecord",
    "Registry",
    "Span",
    "Tracer",
    "activate",
    "current",
    "span",
]
