"""Observability for the serving stack: tracing, metrics, probe logging.

  trace.py     nestable span tracer, Chrome-trace/Perfetto JSON export,
               ambient activation so deep layers need no tracer plumbing;
               TraceContext + span wire format for process-replica IPC
  collate.py   replica clock-offset estimation (min-RTT ping) and merging
               shipped worker spans onto the host timeline in pid lanes
  metrics.py   counters / gauges / fixed-bucket histograms behind one
               Registry.snapshot() / Registry.reset() pair
  probelog.py  per-(query, term, shard) routed-probe JSONL records — the
               training data for the learned guided-vs-decode cost model —
               with size-capped rotation and worker->host forwarding
  slo.py       rolling per-tenant deadline-hit-rate / p99 / burn-rate over
               a sliding window (Session.slo_report feeds from it)
  export.py    Prometheus text-format rendering of any Registry snapshot
"""
from repro.obs.collate import (
    estimate_clock_offset,
    ingest_worker_spans,
    nesting_violations,
    span_from_wire,
)
from repro.obs.export import render_prometheus, write_prometheus
from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.probelog import ProbeLog, ProbeRecord
from repro.obs.slo import SLOMonitor
from repro.obs.trace import (
    NULL_SPAN,
    Span,
    TraceContext,
    Tracer,
    activate,
    current,
    span,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "NULL_SPAN",
    "ProbeLog",
    "ProbeRecord",
    "Registry",
    "SLOMonitor",
    "Span",
    "TraceContext",
    "Tracer",
    "activate",
    "current",
    "estimate_clock_offset",
    "ingest_worker_spans",
    "nesting_violations",
    "render_prometheus",
    "span",
    "span_from_wire",
    "write_prometheus",
]
