"""Prometheus text-format rendering of a Registry snapshot.

``Registry.snapshot()`` is a nested dict: scalars, histogram summaries
(dicts with count/sum/mean/min/max/p50/p90/p99), per-shard lists, and
free-form collector sections.  ``render_prometheus`` flattens that into the
Prometheus text exposition format (v0.0.4) so any scrape target — a
sidecar, a pushgateway shim, a file watched by node_exporter's textfile
collector — sees the serving stack's metrics without a new dependency:

  * scalars become gauges:      repro_sched_batches 12
  * histogram summaries become Prometheus *summaries*:
        repro_sched_queue_us{quantile="0.5"} 104.2
        repro_sched_queue_us_sum 4210.0
        repro_sched_queue_us_count 40
    (plus _min/_max gauges — fixed-bucket percentiles are already computed
    registry-side, so a summary is the honest encoding, not _bucket lines)
  * lists (the per-shard sections) label elements with {idx="i"}
  * booleans render 0/1; strings are skipped (Prometheus has no string
    sample type and labels-from-values would explode cardinality)

Metric names are sanitized to ``[a-zA-Z0-9_]`` and the output is sorted, so
two snapshots of the same registry diff cleanly.
"""
from __future__ import annotations

import re

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")

# a dict with at least these keys renders as a summary (the Histogram
# snapshot shape; collectors echoing the same shape get the same treatment)
_HIST_KEYS = {"count", "sum", "p50", "p99"}

_QUANTILES = (("p50", "0.5"), ("p90", "0.9"), ("p99", "0.99"))


def _sanitize(part: str) -> str:
    return _NAME_RE.sub("_", str(part))


def _labels(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _render_value(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    return repr(float(v)) if isinstance(v, float) else str(int(v))


def _walk(node, name_parts: tuple, labels: tuple, lines: list, types: dict) -> None:
    if isinstance(node, dict):
        if _HIST_KEYS <= set(node):
            name = "_".join(name_parts)
            types.setdefault(name, "summary")
            for key, q in _QUANTILES:
                if key in node:
                    lines.append(
                        f"{name}{_labels(labels + (('quantile', q),))} "
                        f"{_render_value(node[key])}"
                    )
            lines.append(f"{name}_sum{_labels(labels)} {_render_value(node['sum'])}")
            lines.append(
                f"{name}_count{_labels(labels)} {_render_value(node['count'])}"
            )
            for extra in ("min", "max", "mean"):
                if extra in node:
                    ename = f"{name}_{extra}"
                    types.setdefault(ename, "gauge")
                    lines.append(
                        f"{ename}{_labels(labels)} {_render_value(node[extra])}"
                    )
            return
        for k, v in node.items():
            _walk(v, name_parts + (_sanitize(k),), labels, lines, types)
        return
    if isinstance(node, (list, tuple)):
        for i, item in enumerate(node):
            _walk(item, name_parts, labels + (("idx", str(i)),), lines, types)
        return
    if isinstance(node, str) or node is None:
        return  # no string sample type; skip rather than invent labels
    name = "_".join(name_parts)
    types.setdefault(name, "gauge")
    lines.append(f"{name}{_labels(labels)} {_render_value(node)}")


def render_prometheus(snapshot: dict, *, prefix: str = "repro") -> str:
    """A Registry snapshot (or any nested dict of metrics) as Prometheus
    text exposition; deterministic (sorted) and dependency-free."""
    lines: list[str] = []
    types: dict[str, str] = {}
    _walk(snapshot, (_sanitize(prefix),) if prefix else (), (), lines, types)
    lines.sort()
    out: list[str] = []
    typed: set[str] = set()
    for line in lines:
        metric = line.split("{", 1)[0].split(" ", 1)[0]
        base = metric
        for suffix in ("_sum", "_count"):
            if base.endswith(suffix) and base[: -len(suffix)] in types:
                base = base[: -len(suffix)]
        if base in types and base not in typed:
            typed.add(base)
            out.append(f"# TYPE {base} {types[base]}")
        out.append(line)
    return "\n".join(out) + "\n" if out else ""


def write_prometheus(snapshot: dict, path: str, *, prefix: str = "repro") -> None:
    """Render ``snapshot`` to ``path`` (textfile-collector handoff)."""
    with open(path, "w") as f:
        f.write(render_prometheus(snapshot, prefix=prefix))
