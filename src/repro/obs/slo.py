"""Rolling per-tenant SLO accounting for the serving scheduler.

"Benchmarking Learned Indexes" argues for full latency distributions over
single-point summaries; under overload the number an operator actually
watches is neither — it is the *deadline hit rate* per tenant over a recent
window, and how fast the error budget is burning.  ``SLOMonitor`` keeps a
bounded sliding window of per-request outcomes (served/shed, latency,
deadline met) per tenant and reports:

  deadline_hit_rate   fraction of windowed requests that were served within
                      their deadline (no deadline => served counts as met;
                      a shed request never does)
  p50_ms / p99_ms     latency percentiles over the *served* requests in the
                      window (exact — the window is a bounded sample, not a
                      fixed-bucket histogram)
  burn_rate           (1 - hit_rate) / (1 - target): 1.0 means the error
                      budget is being spent exactly at the sustainable
                      rate, >1 means the SLO will be violated if the window
                      is representative — the standard multiwindow-burn
                      alerting input

The monitor is a leaf: ``record`` takes one lock, appends one tuple, and
prunes lazily, so the session can call it from future callbacks (including
ones that fire under the admission queue's lock) without ordering concerns.
``Session.slo_report()`` pairs this per-tenant view with the registry's
``sched.*`` histograms for the whole-process distributions.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable


class SLOMonitor:
    """Sliding-window per-tenant deadline-hit-rate / latency / burn-rate."""

    def __init__(
        self,
        *,
        window_s: float = 60.0,
        target: float = 0.99,
        max_samples_per_tenant: int = 8192,
        clock: Callable[[], float] = time.monotonic,
    ):
        if not 0.0 < target < 1.0:
            raise ValueError(f"SLO target must be in (0, 1), got {target}")
        self.window_s = float(window_s)
        self.target = float(target)
        self._clock = clock
        self._lock = threading.Lock()
        # tenant -> deque of (t, latency_us, served, deadline_met); bounded
        # so a hot tenant can't grow memory, pruned by age on read/write
        self._windows: dict[str, deque] = {}
        self._maxlen = int(max_samples_per_tenant)

    # ------------------------------------------------------------- record
    def record(
        self, tenant: str, *, latency_us: float, served: bool, deadline_met: bool
    ) -> None:
        """One request outcome (served or shed) for ``tenant``."""
        now = self._clock()
        with self._lock:
            win = self._windows.get(tenant)
            if win is None:
                win = self._windows[tenant] = deque(maxlen=self._maxlen)
            self._prune_locked(win, now)
            win.append((now, float(latency_us), bool(served), bool(deadline_met)))

    def _prune_locked(self, win: deque, now: float) -> None:
        horizon = now - self.window_s
        while win and win[0][0] < horizon:
            win.popleft()

    # ------------------------------------------------------------- report
    @staticmethod
    def _percentile(sorted_vals: list[float], q: float) -> float:
        """Exact linear-interpolation percentile over a sorted sample."""
        if not sorted_vals:
            return 0.0
        pos = q / 100.0 * (len(sorted_vals) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(sorted_vals) - 1)
        return sorted_vals[lo] + (sorted_vals[hi] - sorted_vals[lo]) * (pos - lo)

    def report(self) -> dict[str, dict]:
        """Per-tenant window summary: counts, hit rate, p50/p99, burn rate."""
        now = self._clock()
        out: dict[str, dict] = {}
        with self._lock:
            for tenant, win in self._windows.items():
                self._prune_locked(win, now)
                if not win:
                    continue
                n = len(win)
                served = [s for s in win if s[2]]
                hits = sum(1 for s in win if s[3])
                lat = sorted(s[1] for s in served)
                hit_rate = hits / n
                out[tenant] = {
                    "requests": n,
                    "served": len(served),
                    "shed": n - len(served),
                    "deadline_hit_rate": hit_rate,
                    "p50_ms": self._percentile(lat, 50) / 1e3,
                    "p99_ms": self._percentile(lat, 99) / 1e3,
                    "burn_rate": (1.0 - hit_rate) / (1.0 - self.target),
                }
        return out

    def reset(self) -> None:
        with self._lock:
            self._windows.clear()
