"""Structured probe-trace logging: one JSONL record per routed term.

The learned-serving-policies roadmap item wants to *learn* the
guided-vs-decode cost model instead of hand-tuning it; its training data is
exactly what the router sees plus what the probe actually cost.  Every time
``GuidedPostings`` routes a (query, term, shard) probe, it logs

  query / shard       ambient ids (set by the executor around each query)
  term, n_postings    the term and its local list length
  route               'guided' | 'decode' | 'fallback' | 'empty'
                      (decode = learned codec sent to full decode by the
                      cost model or planner hint; fallback = classical codec)
  n_cands / n_found   candidate-set size in and matches out
  eps_window          the model's expected ε-window width in ranks — the
                      feature the current hand-tuned router thresholds on
  bytes               stream bytes this probe actually touched
  wall_us             host wall clock of the probe

Records append as JSON lines (order = execution order); ``ProbeLog`` is
thread-safe, the ambient (query, shard) context is thread-local so the
shard fan-out pool attributes records correctly, and a path-less ProbeLog
collects records in memory (tests, notebooks).  ``read()`` round-trips a
file back into ``ProbeRecord``s.

File sinks rotate: with ``max_bytes`` set, a file that grows past the cap
is renamed to ``<path>.1`` (replacing the previous rotation) and a fresh
file is opened — a long-running serve holds at most ~2x ``max_bytes`` of
probe history on disk instead of growing without bound.

Records also cross process boundaries: a worker replica logs into an
in-memory ProbeLog, ``drain()``s it into wire dicts after each request, and
the host ``ingest()``s them into its own sink — so the learned-routing
training data covers the process-replica path, not just inline serving.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import asdict, dataclass


@dataclass
class ProbeRecord:
    """One routed probe: the cost-model features and the measured outcome."""

    query: int
    shard: int
    term: int
    route: str
    n_cands: int
    n_found: int
    n_postings: int
    eps_window: float
    bytes: int
    wall_us: float

    def to_json(self) -> str:
        return json.dumps(asdict(self), separators=(",", ":"))

    @classmethod
    def from_json(cls, line: str) -> "ProbeRecord":
        return cls(**json.loads(line))


class _Context:
    __slots__ = ("_log", "_query", "_shard", "_prev")

    def __init__(self, log: "ProbeLog", query: int | None, shard: int | None):
        self._log = log
        self._query = query
        self._shard = shard

    def __enter__(self) -> "_Context":
        local = self._log._local
        self._prev = getattr(local, "ctx", (-1, -1))
        local.ctx = (
            self._prev[0] if self._query is None else self._query,
            self._prev[1] if self._shard is None else self._shard,
        )
        return self

    def __exit__(self, *exc) -> bool:
        self._log._local.ctx = self._prev
        return False


class ProbeLog:
    """JSONL probe-trace sink with ambient (query, shard) attribution."""

    def __init__(self, path: str | None = None, *, max_bytes: int | None = None):
        self.path = path
        self.max_bytes = max_bytes
        self._fh = open(path, "w") if path else None
        self._bytes = 0
        self.records: list[ProbeRecord] | None = [] if path is None else None
        self._lock = threading.Lock()
        self._local = threading.local()
        self.n_records = 0
        self.n_rotations = 0

    # ------------------------------------------------------------- context
    def context(
        self, *, query: int | None = -1, shard: int | None = -1
    ) -> _Context:
        """Attribute records logged inside the with-block to (query, shard).

        ``None`` inherits that half of the enclosing context — e.g. a worker
        sets ``context(shard=...)`` around a whole request without clobbering
        the per-query attribution the executor installs inside it.
        """
        return _Context(self, query, shard)

    # ------------------------------------------------------------- write
    def log(
        self,
        term: int,
        route: str,
        *,
        n_cands: int,
        n_found: int,
        n_postings: int,
        eps_window: float,
        bytes: int,
        wall_us: float,
    ) -> None:
        query, shard = getattr(self._local, "ctx", (-1, -1))
        rec = ProbeRecord(
            query=int(query),
            shard=int(shard),
            term=int(term),
            route=route,
            n_cands=int(n_cands),
            n_found=int(n_found),
            n_postings=int(n_postings),
            eps_window=float(eps_window),
            bytes=int(bytes),
            wall_us=float(wall_us),
        )
        with self._lock:
            self._append_locked(rec)

    def _append_locked(self, rec: ProbeRecord) -> None:
        self.n_records += 1
        if self._fh is not None:
            line = rec.to_json() + "\n"
            self._fh.write(line)
            self._bytes += len(line)
            if self.max_bytes is not None and self._bytes >= self.max_bytes:
                self._rotate_locked()
        else:
            self.records.append(rec)

    def _rotate_locked(self) -> None:
        """Size cap hit: current file becomes <path>.1 (previous rotation is
        replaced), a fresh file takes over — disk stays <= ~2x max_bytes."""
        self._fh.close()
        os.replace(self.path, self.path + ".1")
        self._fh = open(self.path, "w")
        self._bytes = 0
        self.n_rotations += 1

    # --------------------------------------------------------------- wire
    def drain(self) -> list[dict]:
        """Pop in-memory records as picklable wire dicts (worker -> host).

        Only meaningful for path-less logs (workers buffer in memory); a
        file-backed log already persists and drains nothing.
        """
        with self._lock:
            if self.records is None:
                return []
            records, self.records = self.records, []
        return [asdict(r) for r in records]

    def ingest(self, records: list[dict]) -> None:
        """Append wire dicts shipped from a worker replica into this sink."""
        recs = [ProbeRecord(**d) for d in records]
        with self._lock:
            for rec in recs:
                self._append_locked(rec)

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "ProbeLog":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------- read
    @staticmethod
    def read(path: str) -> list[ProbeRecord]:
        with open(path) as f:
            return [ProbeRecord.from_json(line) for line in f if line.strip()]
