"""Structured probe-trace logging: one JSONL record per routed term.

The learned-serving-policies roadmap item wants to *learn* the
guided-vs-decode cost model instead of hand-tuning it; its training data is
exactly what the router sees plus what the probe actually cost.  Every time
``GuidedPostings`` routes a (query, term, shard) probe, it logs

  query / shard       ambient ids (set by the executor around each query)
  term, n_postings    the term and its local list length
  route               'guided' | 'decode' | 'fallback' | 'empty'
                      (decode = learned codec sent to full decode by the
                      cost model or planner hint; fallback = classical codec)
  n_cands / n_found   candidate-set size in and matches out
  eps_window          the model's expected ε-window width in ranks — the
                      feature the current hand-tuned router thresholds on
  bytes               stream bytes this probe actually touched
  wall_us             host wall clock of the probe

Records append as JSON lines (order = execution order); ``ProbeLog`` is
thread-safe, the ambient (query, shard) context is thread-local so the
shard fan-out pool attributes records correctly, and a path-less ProbeLog
collects records in memory (tests, notebooks).  ``read()`` round-trips a
file back into ``ProbeRecord``s.
"""
from __future__ import annotations

import json
import threading
from dataclasses import asdict, dataclass


@dataclass
class ProbeRecord:
    """One routed probe: the cost-model features and the measured outcome."""

    query: int
    shard: int
    term: int
    route: str
    n_cands: int
    n_found: int
    n_postings: int
    eps_window: float
    bytes: int
    wall_us: float

    def to_json(self) -> str:
        return json.dumps(asdict(self), separators=(",", ":"))

    @classmethod
    def from_json(cls, line: str) -> "ProbeRecord":
        return cls(**json.loads(line))


class _Context:
    __slots__ = ("_log", "_query", "_shard", "_prev")

    def __init__(self, log: "ProbeLog", query: int, shard: int):
        self._log = log
        self._query = query
        self._shard = shard

    def __enter__(self) -> "_Context":
        local = self._log._local
        self._prev = getattr(local, "ctx", (-1, -1))
        local.ctx = (self._query, self._shard)
        return self

    def __exit__(self, *exc) -> bool:
        self._log._local.ctx = self._prev
        return False


class ProbeLog:
    """JSONL probe-trace sink with ambient (query, shard) attribution."""

    def __init__(self, path: str | None = None):
        self.path = path
        self._fh = open(path, "w") if path else None
        self.records: list[ProbeRecord] | None = [] if path is None else None
        self._lock = threading.Lock()
        self._local = threading.local()
        self.n_records = 0

    # ------------------------------------------------------------- context
    def context(self, *, query: int = -1, shard: int = -1) -> _Context:
        """Attribute records logged inside the with-block to (query, shard)."""
        return _Context(self, query, shard)

    # ------------------------------------------------------------- write
    def log(
        self,
        term: int,
        route: str,
        *,
        n_cands: int,
        n_found: int,
        n_postings: int,
        eps_window: float,
        bytes: int,
        wall_us: float,
    ) -> None:
        query, shard = getattr(self._local, "ctx", (-1, -1))
        rec = ProbeRecord(
            query=int(query),
            shard=int(shard),
            term=int(term),
            route=route,
            n_cands=int(n_cands),
            n_found=int(n_found),
            n_postings=int(n_postings),
            eps_window=float(eps_window),
            bytes=int(bytes),
            wall_us=float(wall_us),
        )
        with self._lock:
            self.n_records += 1
            if self._fh is not None:
                self._fh.write(rec.to_json() + "\n")
            else:
                self.records.append(rec)

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "ProbeLog":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # ------------------------------------------------------------- read
    @staticmethod
    def read(path: str) -> list[ProbeRecord]:
        with open(path) as f:
            return [ProbeRecord.from_json(line) for line in f if line.strip()]
