"""Learned-postings subsystem: rank-model codecs for sorted doc-id lists.

plm    — ε-bounded piecewise-linear model (PGM-style shrinking cone)
rmi    — two-stage recursive model index (linear root + per-leaf LS in JAX)
hybrid — per-term min-bits selection over learned + classical codecs

All codecs are exactly lossless and report exact bit sizes; they register in
repro.index.compress's dispatch so gain.py / benchmarks treat them uniformly.
Batched decode runs on the Pallas kernel in repro.kernels.plm_decode.
"""
from repro.postings.hybrid import (
    CANDIDATES,
    HybridPostings,
    choose_codec,
    hybrid_decode,
    hybrid_encode,
    hybrid_size_bits,
)
from repro.postings.plm import DEFAULT_EPS, fit_segments, plm_decode, plm_encode, plm_size_bits
from repro.postings.rmi import fit_rmi, rmi_decode, rmi_encode, rmi_size_bits
from repro.postings.search import GuidedPostings, ProbeStats, TermModel, load_term_model

__all__ = [
    "CANDIDATES",
    "DEFAULT_EPS",
    "GuidedPostings",
    "HybridPostings",
    "ProbeStats",
    "TermModel",
    "choose_codec",
    "fit_rmi",
    "fit_segments",
    "hybrid_decode",
    "hybrid_encode",
    "hybrid_size_bits",
    "load_term_model",
    "plm_decode",
    "plm_encode",
    "plm_size_bits",
    "rmi_decode",
    "rmi_encode",
    "rmi_size_bits",
]
