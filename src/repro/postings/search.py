"""Model-guided search over learned posting streams — serving without decode.

PR 1 used the PLM/RMI rank models only for storage; this module uses them as
ε-bounded search structures [Kraska et al. '18; PGM-index].  A posting list
stored as segments (start, base, slope) + per-rank corrections supports

  ``rank(term, d)``     — #postings < d,
  ``contains(term, d)`` — membership,

by *predicting* the rank of d from the inverted segment model and decoding
only the correction window that the ε-bound proves can contain it — never the
full list.  The probe cost is O(window) bits instead of O(n · width):

  window ranks ≈ (corr_max − corr_min) / slope   (≤ 2ε/slope for PLM).

Exactness argument (per probe): let segment s be the one whose exact first
doc id brackets d (seg_first[s] ≤ d < seg_first[s+1]; seg_first is
materialized once per term from S single-rank decodes).  Within s every rank
r decodes to pred(r) + corr_r with corr_r ∈ [corr_min, corr_max], and decoded
ids are strictly increasing, so

  pred(r) + corr_max < d  ⇒  id(r) < d      (r below the window)
  pred(r) + corr_min > d  ⇒  id(r) > d      (r above the window)

which yields a closed-form rank bracket [r_lo, r_hi] (a float32 slack term
absorbs the single-multiply rounding of pred).  Decoding exactly that window
with the canonical plm formula reproduces the true sublist, so membership and
rank are bit-exact against full decode.  Classical-codec terms (the hybrid
store keeps whichever codec measured smallest) fall back to full decode via a
caller-supplied accessor.

``GuidedPostings`` wraps a HybridPostings store and keeps honest byte
accounting (``ProbeStats``) so benchmarks can compare the stream bytes a
guided probe touches against what a full decode would have read.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.index.compress import CODECS, unpack_bits_at
from repro.index.intersect import gallop_membership
from repro.obs import trace
from repro.postings.hybrid import HybridPostings
from repro.postings.plm import parse_segments

_LEARNED_TAGS = frozenset(CODECS.index(c) for c in ("plm", "rmi"))

# float32 slack for the rank bracket: |pred_f32 - slope*di| <= 0.5 (rint)
# plus ~2^-23 relative product error; 2 + |d-base| * 2^-22 dominates both.
_SLACK_ABS = 2.0
_SLACK_REL = 2.0**-22


@dataclass
class TermModel:
    """Parsed PLM/RMI stream metadata for one term — no corrections decoded."""

    n: int
    starts: np.ndarray  # (S,) int64 first rank per segment
    ends: np.ndarray  # (S,) int64 exclusive last rank per segment
    bases: np.ndarray  # (S,) int64 integer intercepts
    slopes: np.ndarray  # (S,) float32
    seg_first: np.ndarray  # (S,) int64 exact first doc id per segment
    corr_words: np.ndarray  # packed corrections (uint32 view into the stream)
    width: int  # correction bit width
    corr_min: int
    corr_max: int  # conservative: corr_min + 2**width - 1
    meta_bytes: int  # stream bytes touched to build this model
    avg_window: float  # expected probe-window ranks (the ε-window cost model)


def load_term_model(words: np.ndarray, n: int) -> TermModel:
    """Parse a plm/rmi stream's header + segment table (layout: plm.py).

    Touches header + segment words + one correction per segment (for the
    exact seg_first anchors); the packed correction body is kept as an
    opaque word view for windowed access.
    """
    starts, bases, slopes, width, corr_min, corr_words = parse_segments(words)
    ends = np.concatenate([starts[1:], np.array([n], np.int64)])
    # pred(start_s) = base_s exactly (di = 0), so the exact first id per
    # segment is base + correction-at-start: S point lookups, no full decode.
    first_corr = unpack_bits_at(corr_words, width, starts).astype(np.int64) + corr_min
    seg_first = bases + first_corr
    header_words = len(words) - len(corr_words)
    meta_bytes = 4 * (header_words + _touched_words(starts, width))
    # ε-window cost model: expected probe-window length in ranks is the
    # correction spread divided by the segment slope (rank-per-id inversion),
    # averaged over segments weighted by the ranks they cover.
    spread = float((1 << width) - 1)
    seg_lens = (ends - starts).astype(np.float64)
    win = spread / np.maximum(slopes.astype(np.float64), 1e-3) + 1.0
    avg_window = float((win * seg_lens).sum() / max(float(seg_lens.sum()), 1.0))
    return TermModel(
        n=n,
        starts=starts,
        ends=ends,
        bases=bases,
        slopes=slopes,
        seg_first=seg_first,
        corr_words=corr_words,
        width=width,
        corr_min=corr_min,
        corr_max=corr_min + (1 << width) - 1,
        meta_bytes=meta_bytes,
        avg_window=avg_window,
    )


def _touched_words(indices: np.ndarray, width: int) -> int:
    """#distinct 32-bit words a scattered unpack at `indices` reads."""
    if width == 0 or len(indices) == 0:
        return 0
    bitpos = np.asarray(indices, np.int64) * width
    return len(np.unique(bitpos // 32))


def rank_windows(tm: TermModel, cands: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-candidate exact rank bracket -> (seg, r_lo, r_hi) int64 arrays.

    r_hi is inclusive; an empty window (r_lo > r_hi) proves absence with
    rank(d) = r_lo.  Brackets never cross segment boundaries (the seg_first
    bracketing confines the true rank to one segment).
    """
    d = np.asarray(cands, np.int64)
    seg = np.searchsorted(tm.seg_first, d, side="right") - 1
    below = seg < 0  # d precedes the whole list
    seg = np.maximum(seg, 0)
    base = tm.bases[seg]
    lo_r = tm.starts[seg]
    hi_r = tm.ends[seg]
    slope = tm.slopes[seg].astype(np.float64)
    slack = _SLACK_ABS + np.abs(d - base).astype(np.float64) * _SLACK_REL
    ok = slope > 0
    safe = np.where(ok, slope, 1.0)
    r_hi = lo_r + np.floor((d - base - tm.corr_min + slack) / safe).astype(np.int64)
    r_lo = lo_r + np.ceil((d - base - tm.corr_max - slack) / safe).astype(np.int64)
    # degenerate slope: no inversion possible, scan the whole segment
    r_lo = np.where(ok, r_lo, lo_r)
    r_hi = np.where(ok, r_hi, hi_r - 1)
    r_lo = np.clip(r_lo, lo_r, hi_r)
    r_hi = np.clip(r_hi, lo_r - 1, hi_r - 1)
    # d below the first id: empty window at rank 0
    r_lo = np.where(below, 0, r_lo)
    r_hi = np.where(below, -1, r_hi)
    seg = np.where(below, 0, seg)
    return seg, r_lo, r_hi


def decode_window(tm: TermModel, seg: np.ndarray, ranks: np.ndarray) -> np.ndarray:
    """Exact ids at `ranks` (each inside its `seg`): canonical plm formula."""
    di = (ranks - tm.starts[seg]).astype(np.float32)
    pred = tm.bases[seg] + np.rint(tm.slopes[seg] * di).astype(np.int64)
    corr = unpack_bits_at(tm.corr_words, tm.width, ranks).astype(np.int64) + tm.corr_min
    return pred + corr


def flatten_windows(
    tm: TermModel, cands: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Rank brackets flattened to one rank vector for batched decode.

    -> (seg, r_lo, lens, probe_of, col, flat_ranks): probe_of[i] is the
    candidate index owning flat rank i, col[i] its position inside that
    candidate's window (flat_ranks = r_lo[probe_of] + col).  The single
    source of truth for the host probe, the Pallas bridge, and tests.
    """
    seg, r_lo, r_hi = rank_windows(tm, cands)
    lens = np.maximum(r_hi - r_lo + 1, 0)
    total = int(lens.sum())
    probe_of = np.repeat(np.arange(len(cands)), lens)
    offs = np.concatenate([[0], np.cumsum(lens)])[:-1]
    col = np.arange(total) - offs[probe_of]
    flat_ranks = r_lo[probe_of] + col
    return seg, r_lo, lens, probe_of, col, flat_ranks


@dataclass
class ProbeStats:
    """Stream-byte accounting for the guided-vs-full comparison."""

    probes: int = 0
    guided_terms: int = 0
    fallback_terms: int = 0
    routed_terms: int = 0  # learned terms sent to full decode by the cost model
    window_bytes: int = 0  # correction bytes decoded by ε-window probes
    metadata_bytes: int = 0  # header/segment-table bytes (once per term)
    fallback_bytes: int = 0  # full stream bytes of classical-codec decodes
    full_equiv_bytes: int = 0  # what full decode would have touched instead

    def guided_bytes(self) -> int:
        return self.window_bytes + self.metadata_bytes + self.fallback_bytes

    def as_dict(self) -> dict[str, int | float]:
        d = {k: int(getattr(self, k)) for k in (
            "probes", "guided_terms", "fallback_terms", "routed_terms",
            "window_bytes", "metadata_bytes", "fallback_bytes", "full_equiv_bytes",
        )}
        d["guided_bytes"] = int(self.guided_bytes())
        d["bytes_ratio"] = (
            self.guided_bytes() / self.full_equiv_bytes if self.full_equiv_bytes else 0.0
        )
        return d


class GuidedPostings:
    """contains/rank probes over a HybridPostings store, model-guided.

    Learned-codec terms (plm/rmi) answer from stream metadata + ε-window
    decodes; classical-codec terms fall back to `fallback(t)` (full decode).
    The fallback must cache decodes — `stats.fallback_bytes` charges each
    term's stream once, which is only honest if repeat calls don't re-decode.
    The default wraps store.postings in a per-term cache; the serving engine
    passes its decode-cost-budgeted LRU accessor instead.
    """

    def __init__(
        self,
        store: HybridPostings,
        *,
        fallback: Callable[[int], np.ndarray] | None = None,
        use_kernel: bool = False,
        probe_log=None,  # obs.probelog.ProbeLog: one record per routed term
    ):
        self.store = store
        self.probe_log = probe_log
        if fallback is None:
            cache: dict[int, np.ndarray] = {}

            def fallback(t: int) -> np.ndarray:
                p = cache.get(t)
                if p is None:
                    cache[t] = p = store.postings(t)
                return p

        self.fallback = fallback
        self.use_kernel = use_kernel
        self.stats = ProbeStats()
        self._models: dict[int, TermModel | None] = {}
        self._fallback_seen: set[int] = set()

    # ------------------------------------------------------------- models
    def term_model(self, t: int) -> TermModel | None:
        """TermModel for learned-coded term t, None for classical codecs."""
        tm = self._models.get(t, False)
        if tm is not False:
            return tm
        n = int(self.store.lens[t])
        if n == 0 or int(self.store.tags[t]) not in _LEARNED_TAGS:
            self._models[t] = None
            return None
        tm = load_term_model(self.store.streams[t][1:], n)  # strip hybrid tag
        self._models[t] = tm
        self.stats.metadata_bytes += tm.meta_bytes
        return tm

    def is_guided(self, t: int) -> bool:
        return self.term_model(t) is not None

    # ------------------------------------------------------------- probes
    def _route(
        self, t: int, n_cands: int, hint: str | None = None
    ) -> tuple[str, TermModel | None]:
        """Shared probe preamble: stats + route decision.

        Routes are 'empty' | 'fallback' (classical codec, full decode) |
        'decode' (learned codec sent to full decode by the cost model or a
        planner hint) | 'guided' (ε-window probes).  The TermModel comes
        back for both learned routes so callers can log the ε-window
        feature the router thresholds on.

        ``hint`` is a planner override ('guided' | 'decode'): the sharded
        planner runs the same cost model at plan time with its candidate
        estimate, so the executor honors its decision instead of re-deciding
        per probe.  A hint never forces a guided probe on a classical-codec
        term — absence of a TermModel always falls back.
        """
        self.stats.probes += n_cands
        if int(self.store.lens[t]) == 0:
            return "empty", None
        self.stats.full_equiv_bytes += 4 * int(self.store.streams[t].size)
        tm = self.term_model(t)
        if tm is None:
            self.stats.fallback_terms += 1
            return "fallback", None
        if hint == "decode" or (hint is None and n_cands * tm.avg_window >= tm.n):
            # cost model: the ε-windows of this many probes would decode more
            # correction bytes than the whole list — full decode is cheaper
            self.stats.routed_terms += 1
            return "decode", tm
        self.stats.guided_terms += 1
        return "guided", tm

    def _fallback_list(self, t: int) -> np.ndarray:
        """Fully-decoded postings via the (caching) fallback, bytes charged
        once per term to match the cache's decode-once behaviour."""
        p = self.fallback(t)
        if t not in self._fallback_seen:
            self._fallback_seen.add(t)
            self.stats.fallback_bytes += 4 * int(self.store.streams[t].size)
        return p

    def _probe_guided(self, tm: TermModel, cands: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        if self.use_kernel:
            from repro.kernels.guided_search.ops import probe_windows

            found, rank, touched = probe_windows(tm, cands)
            self.stats.window_bytes += touched
            return found, rank
        return self._probe_host(tm, cands)

    def _log_probe(
        self, t: int, route: str, tm: TermModel | None,
        n_cands: int, n_found: int, bytes_before: int, t0_ns: int,
    ) -> None:
        self.probe_log.log(
            t, route,
            n_cands=n_cands,
            n_found=n_found,
            n_postings=int(self.store.lens[t]),
            eps_window=tm.avg_window if tm is not None else 0.0,
            bytes=self.stats.guided_bytes() - bytes_before,
            wall_us=(time.perf_counter_ns() - t0_ns) / 1e3,
        )

    def probe(
        self, t: int, cands: np.ndarray, *, route: str | None = None
    ) -> tuple[np.ndarray, np.ndarray]:
        """-> (contains bool mask, rank int64) for every candidate.

        rank(d) = #postings of t strictly below d (searchsorted-left), exact
        whether or not d is present.
        """
        cands = np.asarray(cands)
        log = self.probe_log
        t0 = time.perf_counter_ns() if log is not None else 0
        b0 = self.stats.guided_bytes() if log is not None else 0
        route, tm = self._route(t, len(cands), route)
        with trace.span("probe.term", term=int(t), route=route, n_cands=len(cands)):
            if route == "empty":
                found = np.zeros(len(cands), bool)
                rank = np.zeros(len(cands), np.int64)
            elif route in ("fallback", "decode"):
                p = self._fallback_list(t)
                sel = np.searchsorted(p, cands)
                found = (sel < len(p)) & (p[np.minimum(sel, len(p) - 1)] == cands)
                rank = sel.astype(np.int64)
            else:
                found, rank = self._probe_guided(tm, cands)
        if log is not None:
            self._log_probe(t, route, tm, len(cands), int(found.sum()), b0, t0)
        return found, rank

    def _probe_host(self, tm: TermModel, cands: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        d = np.asarray(cands, np.int64)
        seg, r_lo, _, probe_of, _, ranks = flatten_windows(tm, d)
        if len(ranks) == 0:
            return np.zeros(len(d), bool), r_lo
        ids = decode_window(tm, seg[probe_of], ranks)
        self.stats.window_bytes += 4 * _touched_words(ranks, tm.width)
        eq = ids == d[probe_of]
        lt = ids < d[probe_of]
        found = np.zeros(len(d), bool)
        np.logical_or.at(found, probe_of, eq)
        rank = r_lo + np.bincount(probe_of, weights=lt, minlength=len(d)).astype(np.int64)
        return found, rank

    def contains(
        self, t: int, cands: np.ndarray, *, route: str | None = None
    ) -> np.ndarray:
        """Membership mask for *sorted ascending* candidates (the shape the
        verification loop produces).  Fallback terms skip rank computation
        and gallop instead of binary-searching every candidate."""
        cands = np.asarray(cands)
        log = self.probe_log
        t0 = time.perf_counter_ns() if log is not None else 0
        b0 = self.stats.guided_bytes() if log is not None else 0
        route, tm = self._route(t, len(cands), route)
        with trace.span("probe.term", term=int(t), route=route, n_cands=len(cands)):
            if route == "empty":
                found = np.zeros(len(cands), bool)
            elif route in ("fallback", "decode"):
                found = gallop_membership(self._fallback_list(t), cands)
            else:
                found = self._probe_guided(tm, cands)[0]
        if log is not None:
            self._log_probe(t, route, tm, len(cands), int(found.sum()), b0, t0)
        return found

    def rank(self, t: int, cands: np.ndarray) -> np.ndarray:
        return self.probe(t, cands)[1]

    def reset_stats(self) -> None:
        """Zero the accounting window: models and fallback decodes will both
        recharge their bytes on next use (parsed metadata is re-read too, so
        the two paths stay symmetric across a reset)."""
        self.stats = ProbeStats()
        self._fallback_seen.clear()
        self._models.clear()
