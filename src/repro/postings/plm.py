"""ε-bounded piecewise-linear model (PLM) codec for sorted doc-id lists.

The learned-index view of a posting list [Kraska et al. '18; Ferragina &
Vinciguerra's PGM-index]: the list is the graph of a monotone function
rank -> doc_id, and a piecewise-linear approximation with maximum error ε
plus one ⌈log2(2ε+1)⌉-bit correction per posting is an exact, lossless
representation — often far below bit-packed d-gaps for smooth (long, dense,
or clustered) lists.  This module provides:

  * ``fit_segments``      — O(n) shrinking-cone optimal-PLA fitter,
  * ``plm_encode/decode`` — exact lossless (de)serialization to uint32 words,
  * ``plm_size_bits``     — exact bit accounting for Eq. (2) comparisons.

Stream layout (uint32 words; shared with rmi.py via emit/parse helpers)::

  w0            n_segments S
  w1            corr_width (bits 0..7) | eps (bits 8..23)
  w2            corr_min  (int32 bit pattern)
  w3..          starts[S]  u32   first rank covered by each segment
  ..            bases[S]   i32   exact integer intercept of each segment
  ..            slopes[S]  f32   bit pattern
  ..            corrections, pack_bits(corr - corr_min, corr_width)

Decode of rank i in segment s is ``base_s + rint_f32(slope_s * (i - start_s))
+ corr_i``.  The intercept is kept integer (base) so the float step is a
single multiply: with one rounding there is no FMA-contraction ambiguity,
and host numpy, the jnp reference, and the Pallas kernel agree bit-for-bit.
Corrections are measured against the *stored* float32 slope, so quantization
error is absorbed and decode is exactly lossless for any ids < 2^31.
"""
from __future__ import annotations

import numpy as np

from repro.index.compress import pack_bits, unpack_bits

DEFAULT_EPS = 63  # 7-bit corrections; the paper's Eq.(2) sweet spot for long lists

_HEADER_WORDS = 3
_SEGMENT_WORDS = 3  # start + base + slope


# ------------------------------------------------------------------ fitting
def fit_segments(doc_ids: np.ndarray, eps: int) -> tuple[np.ndarray, ...]:
    """Greedy shrinking-cone PLA over (rank, doc_id) with |error| <= eps.

    Each segment's line is anchored at its first point (start, base), so only
    the slope is free; the feasible-slope interval shrinks as points arrive
    and a new segment opens when it empties.  O(n), provably minimal #segments
    among anchored PLAs (the cone argument of O'Rourke '81 / PGM).

    Returns (starts int64, bases int64, slopes f32).
    """
    n = len(doc_ids)
    if n == 0:
        z = np.zeros(0, np.int64)
        return z, z, np.zeros(0, np.float32)
    ys = np.asarray(doc_ids, dtype=np.int64).tolist()
    starts, bases, slopes = [0], [ys[0]], []
    lo, hi = -np.inf, np.inf
    i0, y0 = 0, ys[0]
    for i in range(1, n):
        dx = i - i0
        dy = ys[i] - y0
        nlo = max(lo, (dy - eps) / dx)
        nhi = min(hi, (dy + eps) / dx)
        if nlo > nhi:  # cone empty -> close segment, open a new one at i
            slopes.append(0.0 if lo == -np.inf else (lo + hi) / 2.0)
            i0, y0 = i, ys[i]
            starts.append(i0)
            bases.append(y0)
            lo, hi = -np.inf, np.inf
        else:
            lo, hi = nlo, nhi
    slopes.append(0.0 if lo == -np.inf else (lo + hi) / 2.0)
    return (
        np.asarray(starts, np.int64),
        np.asarray(bases, np.int64),
        np.asarray(slopes, np.float32),
    )


# ------------------------------------------------------------- shared eval
def eval_segments(
    starts: np.ndarray,
    bases: np.ndarray,
    slopes: np.ndarray,
    n: int,
) -> np.ndarray:
    """Canonical model prediction for ranks 0..n-1 (int64).

    A single float32 multiply then banker's rint: with exactly one float
    rounding the result is bit-identical across host numpy, the jnp
    reference, and the Pallas plm_decode kernel (no FMA contraction can
    change it), so corrections transfer across decode paths.
    """
    if n == 0:
        return np.zeros(0, np.int64)
    ranks = np.arange(n, dtype=np.int64)
    seg = np.searchsorted(starts.astype(np.int64), ranks, side="right") - 1
    di = (ranks - starts.astype(np.int64)[seg]).astype(np.float32)
    frac = np.rint(slopes[seg] * di).astype(np.int64)
    return bases.astype(np.int64)[seg] + frac


def emit_stream(
    doc_ids: np.ndarray,
    starts: np.ndarray,
    bases: np.ndarray,
    slopes: np.ndarray,
    eps: int,
) -> np.ndarray:
    """Serialize segments + exact corrections to a uint32 word stream."""
    n = len(doc_ids)
    pred = eval_segments(starts, bases, slopes, n)
    corr = np.asarray(doc_ids, np.int64) - pred
    corr_min = int(corr.min()) if n else 0
    spread = int(corr.max()) - corr_min if n else 0
    width = int(spread).bit_length()
    assert width <= 32, "correction spread exceeds 32 bits (degenerate fit)"
    header = np.array(
        [len(starts), (width & 0xFF) | ((eps & 0xFFFF) << 8), np.int64(corr_min) & 0xFFFFFFFF],
        dtype=np.uint32,
    )
    packed = pack_bits((corr - corr_min).astype(np.uint32), width)
    return np.concatenate(
        [
            header,
            starts.astype(np.uint32),
            (bases & 0xFFFFFFFF).astype(np.uint32),
            np.ascontiguousarray(slopes, np.float32).view(np.uint32),
            packed,
        ]
    )


def parse_segments(
    words: np.ndarray,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int, int, np.ndarray]:
    """Header + segment tables only, corrections left packed.

    -> (starts i64, bases i64, slopes f32, corr_width, corr_min, corr_words).
    The single owner of the stream layout: full decode (parse_stream) and the
    guided-search metadata loader both build on it.  bases round-trip through
    a signed int32 view (an RMI intercept fold can push a base slightly
    negative)."""
    s = int(words[0])
    width = int(words[1]) & 0xFF
    corr_min = int(np.int32(np.uint32(words[2])))
    p = _HEADER_WORDS
    starts = words[p : p + s].astype(np.int64); p += s
    bases = words[p : p + s].astype(np.uint32).view(np.int32).astype(np.int64); p += s
    slopes = words[p : p + s].view(np.float32); p += s
    return starts, bases, slopes, width, corr_min, words[p:]


def parse_stream(words: np.ndarray, n: int) -> tuple[np.ndarray, ...]:
    """Inverse of emit_stream -> (starts i64, bases i64, slopes f32, corr i64)."""
    starts, bases, slopes, width, corr_min, corr_words = parse_segments(words)
    corr = unpack_bits(corr_words, width, n).astype(np.int64) + corr_min
    return starts, bases, slopes, corr


def _stream_size_bits(n: int, n_segments: int, corr_width: int) -> int:
    return 32 * _HEADER_WORDS + _SEGMENT_WORDS * 32 * n_segments + n * corr_width


def stream_size_bits(words: np.ndarray, n: int) -> int:
    """Exact bits of an already-emitted stream (header carries S and width),
    so a caller that encodes anyway never fits the model twice to size it."""
    return _stream_size_bits(n, int(words[0]), int(words[1]) & 0xFF)


def decode_stream(words: np.ndarray, n: int) -> np.ndarray:
    starts, bases, slopes, corr = parse_stream(words, n)
    ids = eval_segments(starts, bases, slopes, n) + corr
    if n and not (0 <= ids.min() and ids.max() <= np.iinfo(np.int32).max):
        raise OverflowError("decoded doc id outside int32 range")
    return ids.astype(np.int32)


# ------------------------------------------------------------------- codec
def plm_encode(doc_ids: np.ndarray, eps: int = DEFAULT_EPS) -> np.ndarray:
    starts, bases, slopes = fit_segments(doc_ids, eps)
    return emit_stream(doc_ids, starts, bases, slopes, eps)


def plm_decode(words: np.ndarray, n: int) -> np.ndarray:
    return decode_stream(words, n)


def plm_size_bits(doc_ids: np.ndarray, eps: int = DEFAULT_EPS) -> int:
    """Exact bits: header + 96b/segment + measured correction width * n."""
    starts, bases, slopes = fit_segments(doc_ids, eps)
    n = len(doc_ids)
    pred = eval_segments(starts, bases, slopes, n)
    corr = np.asarray(doc_ids, np.int64) - pred
    width = int(int(corr.max() - corr.min()).bit_length()) if n else 0
    return _stream_size_bits(n, len(starts), width)
