"""Two-stage RMI codec for very long posting lists.

A recursive-model-index [Kraska et al. '18] specialized to the postings
setting: stage 1 is a *linear root* over rank (ranks are uniform, so the
root reduces to the exact affine bucketing ``leaf = i * L // n``); stage 2
is one linear model per leaf, trained with closed-form least squares in JAX
(segment-sum normal equations, no iterative optimizer).  Leaf models are
anchored at the leaf's first doc id and the fitted intercept is rounded into
that integer base, so the float32 regression only has to cover the
within-leaf span — corrections stay narrow even for billion-scale universes
and the decode formula is plm.py's single-multiply form.

Serialization reuses the plm.py stream layout (start, base, slope per leaf +
bit-packed corrections), so the Pallas plm_decode kernel batch-decodes RMI
streams unchanged.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.postings.plm import decode_stream, emit_stream, eval_segments, _stream_size_bits

LEAF_TARGET = 64  # target postings per leaf model
MAX_LEAVES = 4096


def n_leaves(n: int, leaf_target: int = LEAF_TARGET) -> int:
    return max(1, min(MAX_LEAVES, n // max(1, leaf_target)))


def _leaf_starts(n: int, L: int) -> np.ndarray:
    """Rank boundaries of the affine root: leaf l covers ranks with i*L//n == l."""
    l = np.arange(L, dtype=np.int64)
    return np.ceil(l * n / L).astype(np.int64)


@partial(jax.jit, static_argnames=("L",))
def _leaf_lstsq(x: jax.Array, y: jax.Array, leaf: jax.Array, L: int) -> tuple[jax.Array, jax.Array]:
    """Per-leaf 1D least squares via segment-sum normal equations.

    x, y are leaf-centered (rank - leaf_start, doc_id - leaf_base) so float32
    precision covers the within-leaf span only.  Returns (slopes, iceps).
    """
    ones = jnp.ones_like(x)
    cnt = jax.ops.segment_sum(ones, leaf, num_segments=L)
    sx = jax.ops.segment_sum(x, leaf, num_segments=L)
    sy = jax.ops.segment_sum(y, leaf, num_segments=L)
    sxx = jax.ops.segment_sum(x * x, leaf, num_segments=L)
    sxy = jax.ops.segment_sum(x * y, leaf, num_segments=L)
    denom = cnt * sxx - sx * sx
    slope = jnp.where(denom > 0, (cnt * sxy - sx * sy) / jnp.where(denom > 0, denom, 1.0), 0.0)
    icep = jnp.where(cnt > 0, (sy - slope * sx) / jnp.where(cnt > 0, cnt, 1.0), 0.0)
    return slope, icep


def fit_rmi(
    doc_ids: np.ndarray, leaf_target: int = LEAF_TARGET
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fit the two-stage model -> (starts i64, bases i64, slopes f32).

    The least-squares intercept is rounded into the integer base (plm.py's
    decode has no separate intercept term); the sub-integer remainder lands
    in the corrections, costing at most one extra correction value."""
    n = len(doc_ids)
    if n == 0:
        z = np.zeros(0, np.int64)
        return z, z, np.zeros(0, np.float32)
    L = n_leaves(n, leaf_target)
    starts = _leaf_starts(n, L)
    ids64 = np.asarray(doc_ids, np.int64)
    anchors = ids64[starts]
    ranks = np.arange(n, dtype=np.int64)
    leaf = (ranks * L) // n
    x = (ranks - starts[leaf]).astype(np.float32)
    y = (ids64 - anchors[leaf]).astype(np.float32)
    if L == 1:
        # degenerate single-leaf model: same normal equations, no JAX
        # dispatch overhead (short lists dominate a whole-index sweep)
        denom = float(n * (x * x).sum() - x.sum() ** 2)
        sl = (n * float((x * y).sum()) - float(x.sum()) * float(y.sum())) / denom if denom else 0.0
        slopes = np.array([sl], np.float32)
        iceps = np.array([(float(y.sum()) - sl * float(x.sum())) / n], np.float32)
    else:
        slopes, iceps = _leaf_lstsq(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(leaf, jnp.int32), L
        )
    i32 = np.iinfo(np.int32)
    bases = np.clip(
        anchors + np.rint(np.asarray(iceps, np.float64)).astype(np.int64), i32.min, i32.max
    )
    return starts, bases, np.asarray(slopes, np.float32)


def rmi_encode(doc_ids: np.ndarray, leaf_target: int = LEAF_TARGET) -> np.ndarray:
    starts, bases, slopes = fit_rmi(doc_ids, leaf_target)
    return emit_stream(doc_ids, starts, bases, slopes, eps=0)


def rmi_decode(words: np.ndarray, n: int) -> np.ndarray:
    return decode_stream(words, n)


def rmi_size_bits(doc_ids: np.ndarray, leaf_target: int = LEAF_TARGET) -> int:
    starts, bases, slopes = fit_rmi(doc_ids, leaf_target)
    n = len(doc_ids)
    pred = eval_segments(starts, bases, slopes, n)
    corr = np.asarray(doc_ids, np.int64) - pred
    width = int(int(corr.max() - corr.min()).bit_length()) if n else 0
    return _stream_size_bits(n, len(starts), width)
