"""Hybrid per-term codec selection: learned where it wins, classical elsewhere.

The paper's §3.3 hybrid representation, generalized: every posting list is
stored under the codec that measures smallest for *that* list, chosen among
{optpfd, varbyte, eliasfano, bitvector, plm, rmi}.  The choice is serialized
as a tag word in front of the stream (TAG_BITS in the exact-bit accounting),
so a hybrid stream is self-describing and `decode` needs no side channel.

`HybridPostings` is the tier-2 store used by serve/boolean.py's exact
verification: it keeps every term compressed and decodes on access, replacing
raw int32 arrays with the min-bits representation.

The ranked tier adds an optional *payload stream* per term: quantized BM25
impact values (repro.rank.score), bit-packed rank-aligned with the docid
stream — a guided ε-window rank probe lands directly on its payload via
``payload_at`` without decoding the list.  Alongside it, per-term score
upper bounds at *segment* granularity: for learned-codec terms the PLA/RMI
segment table partitions the rank space, so the max impact per segment is a
block-max table the store gets for free; classical-codec terms carry one
whole-list bound.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.index.compress import (
    CODECS,
    compressed_size_bits,
    decode_postings,
    encode_postings,
    pack_bits,
    unpack_bits,
    unpack_bits_at,
)
from repro.postings.plm import DEFAULT_EPS, plm_encode, stream_size_bits
from repro.postings.rmi import rmi_encode

# the tag encoding is CODECS order — compress.py owns the list; append only
CANDIDATES = CODECS
TAG_BITS = 3  # ceil(log2(len(CANDIDATES)))
RMI_MIN_N = 128  # RMI leaves only pay off on long lists

_LEARNED = {"plm": plm_encode, "rmi": rmi_encode}


def candidate_codecs(n: int) -> tuple[str, ...]:
    if n >= RMI_MIN_N:
        return CANDIDATES
    return tuple(c for c in CANDIDATES if c != "rmi")


def _measure(
    doc_ids: np.ndarray,
    universe: int,
    eps: int | None,
    candidates: tuple[str, ...],
) -> tuple[dict[str, int], dict[str, np.ndarray]]:
    """Per-candidate exact sizes.  Learned codecs are *encoded* once and sized
    from the stream header, so the winner's fit is never repeated; classical
    codecs use their closed-form size models."""
    sizes: dict[str, int] = {}
    streams: dict[str, np.ndarray] = {}
    for c in candidates:
        if c in _LEARNED:
            if c == "plm":
                words = plm_encode(doc_ids, DEFAULT_EPS if eps is None else eps)
            else:
                words = rmi_encode(doc_ids)
            streams[c] = words
            sizes[c] = stream_size_bits(words, len(doc_ids))
        else:
            sizes[c] = int(compressed_size_bits(doc_ids, universe, c, eps=eps))
    return sizes, streams


def choose_codec(
    doc_ids: np.ndarray,
    universe: int,
    *,
    eps: int | None = None,
    candidates: tuple[str, ...] | None = None,
) -> tuple[str, int, dict[str, int]]:
    """Measure every candidate and pick the min-bits codec.

    Returns (codec, bits, all measured sizes).  Ties break toward the earlier
    entry in CANDIDATES (the faster classical decoder).
    """
    doc_ids = np.asarray(doc_ids)
    cands = candidate_codecs(len(doc_ids)) if candidates is None else candidates
    sizes, _ = _measure(doc_ids, universe, eps, cands)
    best = min(cands, key=lambda c: sizes[c])
    return best, sizes[best], sizes


def hybrid_size_bits(doc_ids: np.ndarray, universe: int, *, eps: int | None = None) -> int:
    _, bits, _ = choose_codec(doc_ids, universe, eps=eps)
    return bits + TAG_BITS


def _encode_chosen(
    doc_ids: np.ndarray, universe: int, eps: int | None
) -> tuple[str, int, np.ndarray]:
    """Choose + emit the tag-prefixed stream, reusing a learned fit's words."""
    doc_ids = np.asarray(doc_ids)
    cands = candidate_codecs(len(doc_ids))
    sizes, streams = _measure(doc_ids, universe, eps, cands)
    best = min(cands, key=lambda c: sizes[c])
    body = streams.get(best)
    if body is None:
        body = encode_postings(doc_ids, best, universe=universe, eps=eps)
    tag = np.array([CANDIDATES.index(best)], dtype=np.uint32)
    return best, sizes[best], np.concatenate([tag, body])


def hybrid_encode(doc_ids: np.ndarray, universe: int, *, eps: int | None = None) -> np.ndarray:
    return _encode_chosen(doc_ids, universe, eps)[2]


def hybrid_decode(words: np.ndarray, n: int) -> np.ndarray:
    if n == 0:
        return np.zeros(0, np.int32)
    tag = int(words[0])
    if tag >= len(CANDIDATES):
        raise ValueError(f"corrupt hybrid stream: codec tag {tag}")
    return decode_postings(words[1:], n, CANDIDATES[tag])


_LEARNED_TAG_IDS = frozenset(CANDIDATES.index(c) for c in ("plm", "rmi"))


def _segment_starts(stream: np.ndarray, tag: int, n: int) -> np.ndarray:
    """Rank-space partition of one term's stream for the block-max table:
    the learned codecs' own segment table, one whole-list block otherwise."""
    if tag in _LEARNED_TAG_IDS:
        from repro.postings.plm import parse_segments

        return parse_segments(stream[1:])[0]  # strip the hybrid tag word
    return np.zeros(1, np.int64)


# ----------------------------------------------------------------- the store
@dataclass
class HybridPostings:
    """Whole-index compressed postings store with per-term codec choice."""

    universe: int
    lens: np.ndarray  # (n_terms,) int64 list lengths
    tags: np.ndarray  # (n_terms,) uint8 index into CANDIDATES
    bits: np.ndarray  # (n_terms,) int64 measured size incl. TAG_BITS
    streams: list[np.ndarray]  # per-term uint32 word streams (tag-prefixed)
    # ------- optional ranked-tier payloads (attach_payloads / store layout v2)
    payload_bits: int = 0  # quantized-impact width; 0 = no payloads
    payload_scale: float = 0.0  # dequant scale (ImpactModel.scale)
    payload_streams: "list[np.ndarray] | None" = None  # per-term packed impacts
    ub_offsets: np.ndarray | None = None  # (n_terms+1,) int64 into seg_ubs
    seg_ubs: np.ndarray | None = None  # per-segment max quantized impact (u32)
    term_ubs: np.ndarray | None = None  # (n_terms,) int64 derived whole-list max

    @classmethod
    def build(
        cls,
        term_offsets: np.ndarray,
        doc_ids: np.ndarray,
        universe: int,
        *,
        eps: int | None = None,
    ) -> "HybridPostings":
        n_terms = len(term_offsets) - 1
        lens = np.diff(term_offsets).astype(np.int64)
        tags = np.zeros(n_terms, np.uint8)
        bits = np.zeros(n_terms, np.int64)
        streams: list[np.ndarray] = []
        empty = np.zeros(0, np.uint32)
        for t in range(n_terms):
            lo, hi = int(term_offsets[t]), int(term_offsets[t + 1])
            if hi == lo:
                streams.append(empty)
                continue
            ids = doc_ids[lo:hi]
            codec, best_bits, stream = _encode_chosen(ids, universe, eps)
            tags[t] = CANDIDATES.index(codec)
            bits[t] = best_bits + TAG_BITS
            streams.append(stream)
        return cls(universe=universe, lens=lens, tags=tags, bits=bits, streams=streams)

    @classmethod
    def from_index(cls, inv, *, eps: int | None = None) -> "HybridPostings":
        return cls.build(inv.term_offsets, inv.doc_ids, inv.n_docs, eps=eps)

    def postings(self, t: int) -> np.ndarray:
        n = int(self.lens[t])
        if n == 0:
            return np.zeros(0, np.int32)
        return hybrid_decode(self.streams[t], n)

    @property
    def n_terms(self) -> int:
        return len(self.lens)

    def size_bits(self) -> int:
        return int(self.bits.sum())

    def codec_histogram(self) -> dict[str, int]:
        """How many terms each codec won — the learned-vs-classical split."""
        counts = np.bincount(self.tags[self.lens > 0], minlength=len(CANDIDATES))
        return {c: int(counts[i]) for i, c in enumerate(CANDIDATES) if counts[i]}

    # ------------------------------------------------------------- payloads
    @property
    def has_payloads(self) -> bool:
        return self.payload_bits > 0 and self.payload_streams is not None

    def attach_payloads(self, quants: np.ndarray, *, bits: int, scale: float) -> None:
        """Pack per-posting quantized impacts + build the segment-ub table.

        ``quants`` is flat, aligned with the concatenation of every term's
        postings in term order (the same order the store was built from).
        """
        quants = np.asarray(quants, np.uint32)
        if int(self.lens.sum()) != len(quants):
            raise ValueError(
                f"{len(quants)} payload values for {int(self.lens.sum())} postings"
            )
        if bits <= 0 or (len(quants) and int(quants.max()) >> bits):
            raise ValueError(f"payload values exceed {bits} bits")
        offsets = np.zeros(len(self.lens) + 1, np.int64)
        np.cumsum(self.lens, out=offsets[1:])
        streams: list[np.ndarray] = []
        ub_offsets = np.zeros(len(self.lens) + 1, np.int64)
        seg_ubs: list[np.ndarray] = []
        empty = np.zeros(0, np.uint32)
        for t in range(len(self.lens)):
            n = int(self.lens[t])
            if n == 0:
                streams.append(empty)
                ub_offsets[t + 1] = ub_offsets[t]
                continue
            q = quants[offsets[t] : offsets[t + 1]]
            streams.append(pack_bits(q, bits))
            starts = _segment_starts(self.streams[t], int(self.tags[t]), n)
            seg_ubs.append(np.maximum.reduceat(q, starts).astype(np.uint32))
            ub_offsets[t + 1] = ub_offsets[t] + len(starts)
        self.payload_bits = int(bits)
        self.payload_scale = float(scale)
        self.payload_streams = streams
        self.ub_offsets = ub_offsets
        self.seg_ubs = (
            np.concatenate(seg_ubs) if seg_ubs else np.zeros(0, np.uint32)
        )
        self.term_ubs = None  # rebuild the derived cache lazily

    def _require_payloads(self) -> None:
        if not self.has_payloads:
            raise ValueError("store carries no ranked payloads (attach_payloads)")

    def payloads(self, t: int) -> np.ndarray:
        """Full quantized-impact vector of term t, rank-aligned with postings."""
        self._require_payloads()
        return unpack_bits(self.payload_streams[t], self.payload_bits, int(self.lens[t]))

    def payload_at(self, t: int, ranks: np.ndarray) -> np.ndarray:
        """Quantized impacts at the given ranks only — the probe-path access:
        a guided rank probe reads its payload without decoding the list."""
        self._require_payloads()
        return unpack_bits_at(self.payload_streams[t], self.payload_bits, ranks)

    def term_ub(self, t: int) -> int:
        """Whole-list score upper bound (max quantized impact) of term t."""
        if self.term_ubs is None:
            self._require_payloads()
            ubs = np.zeros(len(self.lens), np.int64)
            nz = np.nonzero(np.diff(self.ub_offsets) > 0)[0]
            if len(nz):
                ubs[nz] = np.maximum.reduceat(
                    np.asarray(self.seg_ubs, np.int64), self.ub_offsets[nz]
                )[: len(nz)]
            self.term_ubs = ubs
        return int(self.term_ubs[t])

    def term_seg_ubs(self, t: int) -> np.ndarray:
        """Per-segment bounds of term t, aligned with its segment table."""
        self._require_payloads()
        return self.seg_ubs[int(self.ub_offsets[t]) : int(self.ub_offsets[t + 1])]

    def payload_size_bits(self) -> int:
        """Exact payload-tier bits as stored: packed impact words (including
        each term's trailing word padding) + 32b/segment bound."""
        if not self.has_payloads:
            return 0
        words = sum(int(s.size) for s in self.payload_streams)
        return 32 * words + 32 * len(self.seg_ubs)
