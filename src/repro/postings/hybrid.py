"""Hybrid per-term codec selection: learned where it wins, classical elsewhere.

The paper's §3.3 hybrid representation, generalized: every posting list is
stored under the codec that measures smallest for *that* list, chosen among
{optpfd, varbyte, eliasfano, bitvector, plm, rmi}.  The choice is serialized
as a tag word in front of the stream (TAG_BITS in the exact-bit accounting),
so a hybrid stream is self-describing and `decode` needs no side channel.

`HybridPostings` is the tier-2 store used by serve/boolean.py's exact
verification: it keeps every term compressed and decodes on access, replacing
raw int32 arrays with the min-bits representation.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.index.compress import (
    CODECS,
    compressed_size_bits,
    decode_postings,
    encode_postings,
)
from repro.postings.plm import DEFAULT_EPS, plm_encode, stream_size_bits
from repro.postings.rmi import rmi_encode

# the tag encoding is CODECS order — compress.py owns the list; append only
CANDIDATES = CODECS
TAG_BITS = 3  # ceil(log2(len(CANDIDATES)))
RMI_MIN_N = 128  # RMI leaves only pay off on long lists

_LEARNED = {"plm": plm_encode, "rmi": rmi_encode}


def candidate_codecs(n: int) -> tuple[str, ...]:
    if n >= RMI_MIN_N:
        return CANDIDATES
    return tuple(c for c in CANDIDATES if c != "rmi")


def _measure(
    doc_ids: np.ndarray,
    universe: int,
    eps: int | None,
    candidates: tuple[str, ...],
) -> tuple[dict[str, int], dict[str, np.ndarray]]:
    """Per-candidate exact sizes.  Learned codecs are *encoded* once and sized
    from the stream header, so the winner's fit is never repeated; classical
    codecs use their closed-form size models."""
    sizes: dict[str, int] = {}
    streams: dict[str, np.ndarray] = {}
    for c in candidates:
        if c in _LEARNED:
            if c == "plm":
                words = plm_encode(doc_ids, DEFAULT_EPS if eps is None else eps)
            else:
                words = rmi_encode(doc_ids)
            streams[c] = words
            sizes[c] = stream_size_bits(words, len(doc_ids))
        else:
            sizes[c] = int(compressed_size_bits(doc_ids, universe, c, eps=eps))
    return sizes, streams


def choose_codec(
    doc_ids: np.ndarray,
    universe: int,
    *,
    eps: int | None = None,
    candidates: tuple[str, ...] | None = None,
) -> tuple[str, int, dict[str, int]]:
    """Measure every candidate and pick the min-bits codec.

    Returns (codec, bits, all measured sizes).  Ties break toward the earlier
    entry in CANDIDATES (the faster classical decoder).
    """
    doc_ids = np.asarray(doc_ids)
    cands = candidate_codecs(len(doc_ids)) if candidates is None else candidates
    sizes, _ = _measure(doc_ids, universe, eps, cands)
    best = min(cands, key=lambda c: sizes[c])
    return best, sizes[best], sizes


def hybrid_size_bits(doc_ids: np.ndarray, universe: int, *, eps: int | None = None) -> int:
    _, bits, _ = choose_codec(doc_ids, universe, eps=eps)
    return bits + TAG_BITS


def _encode_chosen(
    doc_ids: np.ndarray, universe: int, eps: int | None
) -> tuple[str, int, np.ndarray]:
    """Choose + emit the tag-prefixed stream, reusing a learned fit's words."""
    doc_ids = np.asarray(doc_ids)
    cands = candidate_codecs(len(doc_ids))
    sizes, streams = _measure(doc_ids, universe, eps, cands)
    best = min(cands, key=lambda c: sizes[c])
    body = streams.get(best)
    if body is None:
        body = encode_postings(doc_ids, best, universe=universe, eps=eps)
    tag = np.array([CANDIDATES.index(best)], dtype=np.uint32)
    return best, sizes[best], np.concatenate([tag, body])


def hybrid_encode(doc_ids: np.ndarray, universe: int, *, eps: int | None = None) -> np.ndarray:
    return _encode_chosen(doc_ids, universe, eps)[2]


def hybrid_decode(words: np.ndarray, n: int) -> np.ndarray:
    if n == 0:
        return np.zeros(0, np.int32)
    tag = int(words[0])
    if tag >= len(CANDIDATES):
        raise ValueError(f"corrupt hybrid stream: codec tag {tag}")
    return decode_postings(words[1:], n, CANDIDATES[tag])


# ----------------------------------------------------------------- the store
@dataclass
class HybridPostings:
    """Whole-index compressed postings store with per-term codec choice."""

    universe: int
    lens: np.ndarray  # (n_terms,) int64 list lengths
    tags: np.ndarray  # (n_terms,) uint8 index into CANDIDATES
    bits: np.ndarray  # (n_terms,) int64 measured size incl. TAG_BITS
    streams: list[np.ndarray]  # per-term uint32 word streams (tag-prefixed)

    @classmethod
    def build(
        cls,
        term_offsets: np.ndarray,
        doc_ids: np.ndarray,
        universe: int,
        *,
        eps: int | None = None,
    ) -> "HybridPostings":
        n_terms = len(term_offsets) - 1
        lens = np.diff(term_offsets).astype(np.int64)
        tags = np.zeros(n_terms, np.uint8)
        bits = np.zeros(n_terms, np.int64)
        streams: list[np.ndarray] = []
        empty = np.zeros(0, np.uint32)
        for t in range(n_terms):
            lo, hi = int(term_offsets[t]), int(term_offsets[t + 1])
            if hi == lo:
                streams.append(empty)
                continue
            ids = doc_ids[lo:hi]
            codec, best_bits, stream = _encode_chosen(ids, universe, eps)
            tags[t] = CANDIDATES.index(codec)
            bits[t] = best_bits + TAG_BITS
            streams.append(stream)
        return cls(universe=universe, lens=lens, tags=tags, bits=bits, streams=streams)

    @classmethod
    def from_index(cls, inv, *, eps: int | None = None) -> "HybridPostings":
        return cls.build(inv.term_offsets, inv.doc_ids, inv.n_docs, eps=eps)

    def postings(self, t: int) -> np.ndarray:
        n = int(self.lens[t])
        if n == 0:
            return np.zeros(0, np.int32)
        return hybrid_decode(self.streams[t], n)

    @property
    def n_terms(self) -> int:
        return len(self.lens)

    def size_bits(self) -> int:
        return int(self.bits.sum())

    def codec_histogram(self) -> dict[str, int]:
        """How many terms each codec won — the learned-vs-classical split."""
        counts = np.bincount(self.tags[self.lens > 0], minlength=len(CANDIDATES))
        return {c: int(counts[i]) for i, c in enumerate(CANDIDATES) if counts[i]}
