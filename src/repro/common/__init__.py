from repro.common.config import (
    ArchConfig,
    LearnedIndexConfig,
    MeshConfig,
    OptimizerConfig,
    TrainConfig,
)
from repro.common.sharding import (
    logical_to_sharding,
    shard_params,
    with_sharding,
)

__all__ = [
    "ArchConfig",
    "LearnedIndexConfig",
    "MeshConfig",
    "OptimizerConfig",
    "TrainConfig",
    "logical_to_sharding",
    "shard_params",
    "with_sharding",
]
