"""Logical-axis sharding rules → NamedSharding, MaxText-style.

Every param/activation is annotated with *logical* axis names; a rules table
maps logical names to mesh axes per mesh. This keeps model code mesh-agnostic:
the same model def lowers on 1 CPU device, a (16,16) pod, or a (2,16,16)
multi-pod mesh.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
# "batch" folds pod+data so multi-pod meshes scale batch across pods.
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),  # ZeRO-3 parameter sharding axis
    "embed": ("pod", "data"),  # 2D weight sharding: d_model dim over data (FSDP)
    "model": "model",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": ("data", "model"),  # full EP: one/few experts per chip
    "seq": None,
    "seq_sharded": "model",  # SP: long-context KV sharding
    "layers": None,  # scanned-layer stack dim
    "opt_state": ("pod", "data", "model"),  # ZeRO: flat int8 moments over all
    "nodes": ("pod", "data", "model"),
    "edges": ("pod", "data", "model"),
    "nodes_sm": ("pod", "data"),  # small graphs: don't pay 256-way collectives
    "edges_sm": ("pod", "data"),
    "table_vocab": "model",  # recsys embedding tables sharded by row
    "candidates": "model",
    "blocks": ("pod", "data"),  # learned-index doc blocks
    "docs": ("pod", "data"),
    "terms": "model",
    None: None,
}


def concrete_mesh(shape: Sequence[int], names: Sequence[str]) -> Mesh:
    """Version-portable device-mesh constructor.

    jax >= 0.5 wants explicit axis_types (Auto) for the shard_map/pjit mix
    these modules use; 0.4.x has no AxisType and defaults to the same
    behaviour.  Tests and launchers build meshes through this so one source
    tree runs on both."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            tuple(shape), tuple(names), axis_types=(axis_type.Auto,) * len(names)
        )
    return jax.make_mesh(tuple(shape), tuple(names))


def mesh_context(mesh: Mesh):
    """`with mesh_context(mesh):` — jax.set_mesh where it exists (>= 0.6),
    falling back to the legacy Mesh context manager."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def _shard_map():
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as legacy_shard_map

    return legacy_shard_map


def shard_map(*args, **kwargs):
    """Version-portable jax.shard_map (jax.experimental.shard_map on 0.4.x)."""
    return _shard_map()(*args, **kwargs)


def axis_size(axis_name: str):
    """jax.lax.axis_size where it exists; psum(1) inside shard_map otherwise."""
    fn = getattr(jax.lax, "axis_size", None)
    return fn(axis_name) if fn is not None else jax.lax.psum(1, axis_name)


def pvary(x, axis_names):
    """jax.lax.pvary where it exists.  On 0.4.x there is no explicit-varying
    type system, so marking a value as varying is the identity."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_names) if fn is not None else x


def abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """Version-portable AbstractMesh((16, 16), ("data", "model")) constructor.

    Current JAX (0.4.36+) takes (name, size) pairs in one shape_tuple;
    later releases moved to split (axis_sizes, axis_names) positionals.
    Tests and dry-run cells use this so either signature works.
    """
    if len(shape) != len(names):
        raise ValueError(f"shape {shape} and names {names} must align")
    try:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(names))


def _mesh_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def resolve_axis(logical: str | None, mesh: Mesh, rules: Mapping[str, Any] | None = None) -> Any:
    rules = rules or DEFAULT_RULES
    target = rules.get(logical, None)
    names = set(_mesh_axes(mesh))
    if target is None:
        return None
    if isinstance(target, tuple):
        present = tuple(a for a in target if a in names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]
    return target if target in names else None


def logical_to_sharding(
    logical_axes: Sequence[str | None],
    mesh: Mesh,
    rules: Mapping[str, Any] | None = None,
) -> NamedSharding:
    """('batch', None, 'model') -> NamedSharding over the given mesh."""
    spec = P(*(resolve_axis(ax, mesh, rules) for ax in logical_axes))
    return NamedSharding(mesh, spec)


def spec_for_shape(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, Any] | None = None,
) -> P:
    """Divisibility-aware spec: mesh axes that don't divide a dim are dropped
    (trailing-first), and a mesh axis is never used twice in one spec (the
    first dim that claims it wins) — e.g. MQA's kv_heads=1 falls back to
    replicated, and MoE ('experts','embed','mlp') keeps experts on `model`
    and drops mlp's claim."""
    used: set[str] = set()
    entries: list[Any] = []
    for ax, dim in zip(logical_axes, shape):
        target = resolve_axis(ax, mesh, rules)
        if target is None:
            entries.append(None)
            continue
        t = (target,) if isinstance(target, str) else tuple(target)
        t = tuple(a for a in t if a not in used)
        while t:
            prod = 1
            for a in t:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            t = t[:-1]
        if not t:
            entries.append(None)
            continue
        used.update(t)
        entries.append(t if len(t) > 1 else t[0])
    return P(*entries)


def sharding_for_shape(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, Any] | None = None,
) -> NamedSharding:
    return NamedSharding(mesh, spec_for_shape(logical_axes, shape, mesh, rules))


def partition_spec(
    logical_axes: Sequence[str | None],
    mesh: Mesh,
    rules: Mapping[str, Any] | None = None,
) -> P:
    return P(*(resolve_axis(ax, mesh, rules) for ax in logical_axes))


def with_sharding(x: jax.Array, logical_axes: Sequence[str | None], mesh: Mesh) -> jax.Array:
    """In-graph sharding constraint by logical axes."""
    return jax.lax.with_sharding_constraint(x, logical_to_sharding(logical_axes, mesh))


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Ambient-mesh activation sharding constraint by logical axes.

    Uses the mesh installed by `jax.set_mesh` (the dry-run / launcher
    context); no-op when tracing outside a mesh or on a single device.
    Divisibility-aware like spec_for_shape, so the same model code works on
    any mesh."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or not mesh.axis_names or mesh.size <= 1:
        return x
    spec = spec_for_shape(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def shard_params(params: Any, axes_tree: Any, mesh: Mesh) -> Any:
    """device_put a param pytree according to a matching logical-axes pytree."""
    return jax.tree.map(
        lambda p, ax: jax.device_put(p, logical_to_sharding(ax, mesh)),
        params,
        axes_tree,
        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)),
    )


def sharding_tree(axes_tree: Any, mesh: Mesh) -> Any:
    """Logical-axes pytree -> NamedSharding pytree (for in_shardings)."""
    return jax.tree.map(
        lambda ax: logical_to_sharding(ax, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def abstract_like(params: Any) -> Any:
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
