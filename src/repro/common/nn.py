"""Tiny functional NN layer helpers shared across model families.

We deliberately avoid flax/haiku (not installed): params are plain pytrees of
jnp arrays, layers are pure functions. Each init returns (params, logical_axes)
twin pytrees so sharding rules can be applied mechanically.
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, axes=(None, "model"), dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)
    return {"w": w}, {"w": tuple(axes)}


def dense(params, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)


def bias_dense_init(key, d_in, d_out, *, axes=(None, "model"), dtype=jnp.float32, scale=None):
    p, a = dense_init(key, d_in, d_out, axes=axes, dtype=dtype, scale=scale)
    p["b"] = jnp.zeros((d_out,), dtype)
    a["b"] = (axes[1],)
    return p, a


def bias_dense(params, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype) + params["b"].astype(x.dtype)


def mlp_init(key, dims: Sequence[int], *, dtype=jnp.float32, hidden_axis="model"):
    """dims = [in, h1, ..., out]. Alternates sharded/replicated hidden axes."""
    params, axes = [], []
    keys = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        ax_in = hidden_axis if i % 2 == 1 else None
        ax_out = hidden_axis if i % 2 == 0 else None
        p, ax = bias_dense_init(keys[i], a, b, axes=(ax_in, ax_out), dtype=dtype)
        params.append(p)
        axes.append(ax)
    return params, axes


def mlp(params, x: jax.Array, *, act=jax.nn.relu, final_act=None) -> jax.Array:
    for i, p in enumerate(params):
        x = bias_dense(p, x)
        if i < len(params) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((dim,), dtype)}, {"scale": (None,)}


def rmsnorm(params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) so zero-init is identity
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        {"scale": (None,), "bias": (None,)},
    )


def layernorm(params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


def embedding_init(key, vocab: int, dim: int, *, axes=("vocab", None), dtype=jnp.float32, scale=0.02):
    e = jax.random.normal(key, (vocab, dim), dtype) * scale
    return {"table": e}, {"table": tuple(axes)}


def embed(params, ids: jax.Array, compute_dtype=None) -> jax.Array:
    t = params["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
