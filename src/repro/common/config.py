"""Config system: typed, frozen dataclasses for every subsystem.

Configs are plain data — no jax imports here, so any config can be built
before jax initializes (important: dryrun.py must set XLA_FLAGS before any
jax import, and configs are needed to decide what to dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence


def _freeze(obj: Any) -> Any:
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh description.

    axis order is (pod?, data, model). ``pod`` only exists multi-pod.
    """

    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> tuple[str, ...]:
        """Axes used for data parallelism (pod folds into data)."""
        return tuple(a for a in self.axes if a in ("pod", "data"))


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    # 'fp32' | 'int8' — int8 moments (block-wise scales) let 671B-scale
    # optimizer state fit 16GB/chip v5e HBM (see DESIGN.md §7).
    moment_dtype: str = "fp32"
    # int8-compressed ring all-reduce for gradients (distributed/compression.py)
    compress_grads: bool = False


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    microbatch: int | None = None  # grad accumulation if < global_batch/dp
    remat: str = "none"  # 'none' | 'full' | 'dots' (checkpoint policy)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    # straggler mitigation: abort+log if a step exceeds this multiple of the
    # trailing median step time (watchdog in launch/train.py)
    straggler_factor: float = 3.0


@dataclass(frozen=True)
class ArchConfig:
    """Superset config covering all assigned architecture families.

    family ∈ {'lm', 'gnn', 'recsys'}; unused fields stay at defaults.
    """

    name: str = "unnamed"
    family: str = "lm"

    # --- LM transformer ---
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int | None = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    activation: str = "swiglu"  # 'swiglu' | 'geglu' | 'gelu'
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # gemma-2 style
    attn_types: tuple[str, ...] = ("global",)  # cycled over layers
    window_size: int = 4096
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    # 'heads' shards attention over the head axis; 'seq' shards over the query
    # sequence axis (SP) — for head counts indivisible by the model axis
    attn_shard: str = "heads"
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int | None = None
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # MoE
    use_moe: bool = False
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 1  # deepseek: first k layers dense
    moe_aux_free: bool = True  # bias-based aux-loss-free balancing (dsv3)
    moe_capacity_factor: float = 1.25  # GShard capacity; large => dropless
    moe_a2a: bool = False  # explicit shard_map all-to-all dispatch (EP)
    # MTP (dsv3) — extra next-next-token prediction head
    use_mtp: bool = False
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d_model)

    # --- GNN ---
    gnn_layers: int = 15
    gnn_hidden: int = 128
    gnn_mlp_layers: int = 2
    gnn_aggregator: str = "sum"
    node_feat_dim: int = 128
    edge_feat_dim: int = 4
    gnn_out_dim: int = 2

    # --- RecSys ---
    n_dense: int = 0
    n_sparse: int = 26
    embed_dim: int = 128
    vocab_sizes: tuple[int, ...] = ()
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    interaction: str = "dot"  # 'dot' | 'fm-2way' | 'transformer-seq' | 'multi-interest'
    hist_len: int = 20  # BST behaviour-sequence length
    n_blocks: int = 1
    n_interests: int = 4
    capsule_iters: int = 3

    def replace(self, **kw: Any) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads


@dataclass(frozen=True)
class LearnedIndexConfig:
    """Config for the paper's contribution (core/)."""

    algorithm: str = "two_tier"  # 'exhaustive' | 'two_tier' | 'block'
    embed_dim: int = 128  # paper's s=512bit worst case = 128 fp32 units
    mlp_hidden: tuple[int, ...] = ()  # () = pure dot-product model
    truncation_k: int = 4000  # two-tier tier-1 list length
    block_size: int = 1024  # block-based approach: docs per block
    replace_df_threshold: int = 4000  # terms with df>k get replaced by f
    guarantee: bool = True  # zero-FN threshold + exact backup set
    threshold: float = 0.5
    train_negatives_per_positive: int = 4
    model_bits_per_pair: float = 512.0  # 's' in Eq.(2), upper bound


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment (arch × shape grid)."""

    name: str = "train_4k"
    kind: str = "train"  # 'train' | 'prefill' | 'decode' | 'retrieval' | 'serve'
    seq_len: int = 4096
    global_batch: int = 256
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple[int, ...] = ()
    n_graphs: int = 0
    # recsys
    n_candidates: int = 0

    def replace(self, **kw: Any) -> "ShapeSpec":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class CorpusConfig:
    """Synthetic Zipf-Mandelbrot collection calibrated to a TREC target."""

    name: str = "robust-like"
    n_docs: int = 5280  # Robust05 |D|=528k scaled 1/100
    n_terms: int = 60_000
    avg_doc_len: int = 230
    zipf_a: float = 1.2
    zipf_b: float = 2.7
    seed: int = 7


PAPER_COLLECTIONS: Mapping[str, CorpusConfig] = {
    # scaled 1/100 from published sizes; scale=1.0 reproduces full scale
    "robust": CorpusConfig(name="robust-like", n_docs=5280, n_terms=60_000, avg_doc_len=230),
    "gov2": CorpusConfig(name="gov2-like", n_docs=252_000, n_terms=390_000, avg_doc_len=410),
    "clueweb": CorpusConfig(name="clueweb-like", n_docs=502_000, n_terms=960_000, avg_doc_len=380),
}


def scaled_collection(base: CorpusConfig, scale: float) -> CorpusConfig:
    return dataclasses.replace(
        base,
        n_docs=max(64, int(base.n_docs * scale)),
        n_terms=max(256, int(base.n_terms * scale)),
    )
