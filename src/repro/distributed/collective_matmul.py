"""All-gather-overlapped matmul (collective matmul, Wang et al., MaxText).

Setting: y = x_global @ W_local where
  * x is sharded on the contraction axis k (e.g. the reduce-scattered output
    of the previous TP layer): each device holds (m, k/N);
  * W is sharded on the output axis n: each device holds ALL k rows for its
    n/N columns, (k, n/N).

The naive plan all-gathers x over k, THEN multiplies — ICI and MXU serialize.
The collective matmul rotates x shards around the ring and accumulates one
partial product per hop against the matching k-row block of the local W:
comm of hop i+1 overlaps compute of hop i, hiding (N-1)/N of gather latency.

Runs inside shard_map. The pjit path instead relies on XLA async-collective
latency hiding; both plans are compared in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.sharding import axis_size, pvary


def collective_matmul_ag(
    x_shard: jax.Array,  # (m, k_local) — k-sharded input
    w_full_k: jax.Array,  # (k_global, n_local) — output-sharded weight
    axis_name: str,
) -> jax.Array:
    """Returns y_local = x_global @ w_full_k, shape (m, n_local)."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k_local = x_shard.shape[1]
    assert w_full_k.shape[0] == k_local * n, (w_full_k.shape, k_local, n)
    # send "backwards" so after i hops we hold the shard of device idx+i
    perm = [(i, (i - 1) % n) for i in range(n)]

    def body(i, carry):
        acc, shard = carry
        origin = (idx + i) % n
        w_block = jax.lax.dynamic_slice_in_dim(w_full_k, origin * k_local, k_local, axis=0)
        acc = acc + shard.astype(jnp.float32) @ w_block.astype(jnp.float32)
        shard = jax.lax.ppermute(shard, axis_name, perm)
        return acc, shard

    acc0 = pvary(
        jnp.zeros((x_shard.shape[0], w_full_k.shape[1]), jnp.float32), (axis_name,)
    )
    acc, _ = jax.lax.fori_loop(0, n, body, (acc0, x_shard), unroll=True)
    return acc.astype(x_shard.dtype)


def matmul_reduce_scatter(
    x_shard: jax.Array,  # (m, k_local) — k-sharded input
    w_k_sharded: jax.Array,  # (k_local, n) — k-sharded weight
    axis_name: str,
) -> jax.Array:
    """y_local = reduce_scatter(x @ w) over n: the dual TP pattern.

    Ring: accumulate partial products while rotating partial sums so each
    device ends holding only its n/N output columns (wire = fp32 partials).
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    full = x_shard.astype(jnp.float32) @ w_k_sharded.astype(jnp.float32)  # (m, n)
    n_local = full.shape[1] // n
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(i, acc):
        # after hop i, acc holds the partial sum destined for device idx+i+1
        src = (idx + n - 1 - i) % n
        block = jax.lax.dynamic_slice_in_dim(full, src * n_local, n_local, axis=1)
        acc = jax.lax.ppermute(acc + block, axis_name, perm)
        return acc

    acc0 = pvary(jnp.zeros((full.shape[0], n_local), jnp.float32), (axis_name,))
    acc = jax.lax.fori_loop(0, n - 1, body, acc0, unroll=True)
    own = jax.lax.dynamic_slice_in_dim(full, idx * n_local, n_local, axis=1)
    return (acc + own).astype(x_shard.dtype)
