"""int8-compressed ring all-reduce with error feedback (gradient compression).

Why: at (2,16,16) scale the DP gradient all-reduce for a 3.8B dense model
moves ~7.6 GB/step/chip in bf16; int8 + per-chunk scales cuts wire bytes 2x
(4x vs fp32) at <1e-2 relative error, and error feedback makes the *training
trajectory* bias-free (residuals re-injected next step — Karimireddy et al.).

Implemented as a shard_map ring over the `data` axis with ppermute steps:
  reduce-scatter phase (N-1 quantized hops) then all-gather phase (N-1 hops).
This is the explicit-collective path; the default pjit path lets XLA emit its
own all-reduce. Both are selectable per-run (OptimizerConfig.compress_grads).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def quantize_chunk(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0
    q = jnp.round(x / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def dequantize_chunk(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _ring_allreduce_1d(x: jax.Array, axis_name: str, n: int) -> jax.Array:
    """Quantized ring all-reduce of a 1-D fp32 vector, length % n == 0."""
    chunks = x.reshape(n, -1)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after N-1 hops, chunk (idx+1) holds the full sum
    def rs_step(i, chunks):
        send_ix = (idx - i) % n
        q, s = quantize_chunk(chunks[send_ix])
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        recv_ix = (idx - i - 1) % n
        return chunks.at[recv_ix].add(dequantize_chunk(q, s))

    chunks = jax.lax.fori_loop(0, n - 1, rs_step, chunks)

    # all-gather: each completed chunk is quantized ONCE at its owner and the
    # (q, scale) pair circulates verbatim -> every device decodes identical
    # bytes (bitwise-consistent result, required for SPMD determinism).
    own_ix = (idx + 1) % n
    q, s = quantize_chunk(chunks[own_ix])
    chunks = chunks.at[own_ix].set(dequantize_chunk(q, s))

    def ag_step(i, carry):
        q, s, chunks = carry
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        recv_ix = (idx - i) % n
        chunks = chunks.at[recv_ix].set(dequantize_chunk(q, s))
        return q, s, chunks

    _, _, chunks = jax.lax.fori_loop(0, n - 1, ag_step, (q, s, chunks))
    return chunks.reshape(-1)


def compressed_allreduce(
    tree: Any, mesh: Mesh, axis_name: str = "data"
) -> Any:
    """All-reduce (sum) a gradient pytree over `axis_name` with int8 wire format.

    Call INSIDE shard_map. Leaves are flattened into one fp32 vector so
    quantization block = ring chunk.
    """
    n = mesh.shape[axis_name]
    if n == 1:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    out = _ring_allreduce_1d(flat, axis_name, n)
    out = out[: sum(sizes)]
    parts = []
    off = 0
    for sz, shp, l in zip(sizes, shapes, leaves):
        parts.append(out[off : off + sz].reshape(shp).astype(l.dtype))
        off += sz
    return jax.tree.unflatten(treedef, parts)


class ErrorFeedback:
    """Residual accumulator: g_compressed = C(g + e); e = (g + e) - g_compressed.

    State is a pytree matching grads; apply() returns corrected grads and the
    new residual. Used by the shard_map DP trainer when compress_grads=True.
    """

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def pre(grads: Any, residual: Any) -> Any:
        return jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, residual)

    @staticmethod
    def post(corrected: Any, compressed: Any) -> Any:
        return jax.tree.map(lambda c, q: c - q.astype(jnp.float32), corrected, compressed)
