from repro.distributed.compression import (
    ErrorFeedback,
    compressed_allreduce,
    dequantize_chunk,
    quantize_chunk,
)
from repro.distributed.collective_matmul import (
    collective_matmul_ag,
    matmul_reduce_scatter,
)
from repro.distributed.pipeline import gpipe, make_pipeline_fn

__all__ = [
    "ErrorFeedback",
    "compressed_allreduce",
    "quantize_chunk",
    "dequantize_chunk",
    "collective_matmul_ag",
    "matmul_reduce_scatter",
    "gpipe",
    "make_pipeline_fn",
]
