"""GPipe-style pipeline parallelism over a `pipe` mesh axis (shard_map).

Each device on the `pipe` axis owns one stage's params (stacked pytree,
leading axis = stage, sharded over `pipe`). Microbatches stream through the
ring: at tick t, stage s processes microbatch t-s and forwards activations
via ppermute. Bubble fraction = (S-1)/(M+S-1), the GPipe schedule.

This is the framework's PP building block; the LM archs default to TP+DP
(+EP) because at ≤61 layers and 256 chips TP×DP saturates ICI better, but
the pipeline path is available for cross-pod scaling where DCN bandwidth
makes TP across pods impractical (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.sharding import pvary, shard_map


def gpipe(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    n_stages: int,
    axis_name: str = "pipe",
):
    """Returns fn(stage_params_local, microbatches) for use INSIDE shard_map.

    stage_params_local: this device's stage params (leading stage axis
    already stripped by shard_map's sharding).
    microbatches: (M, mb, ...) — replicated input; stage 0 consumes it.
    Output: (M, mb, ...) — valid on the LAST stage (others return zeros).
    """

    def run(stage_params, microbatches):
        s_idx = jax.lax.axis_index(axis_name)
        m = microbatches.shape[0]
        ticks = m + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        mb_shape = microbatches.shape[1:]

        def tick(t, carry):
            prev_out, outputs = carry
            # stage 0 reads microbatch t (if in range); others read forwarded acts
            mb_in = jax.lax.dynamic_index_in_dim(
                microbatches, jnp.clip(t, 0, m - 1), keepdims=False
            )
            x = jnp.where(s_idx == 0, mb_in, prev_out)
            y = stage_fn(stage_params, x)
            # forward to next stage
            fwd = jax.lax.ppermute(y, axis_name, perm)
            # last stage emits microbatch t-(S-1) at tick t
            out_ix = t - (n_stages - 1)
            emit = (s_idx == n_stages - 1) & (out_ix >= 0)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_ix, 0), axis=0
                ),
                lambda o: o,
                outputs,
            )
            return fwd, outputs

        out0 = pvary(jnp.zeros((m, *mb_shape), microbatches.dtype), (axis_name,))
        prev0 = pvary(jnp.zeros(mb_shape, microbatches.dtype), (axis_name,))
        _, outputs = jax.lax.fori_loop(0, ticks, tick, (prev0, out0))
        # broadcast final outputs from last stage to all (psum over one-hot)
        mask = jnp.where(s_idx == n_stages - 1, 1.0, 0.0)
        return jax.lax.psum(outputs * mask, axis_name)

    return run


def make_pipeline_fn(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    mesh: Mesh,
    n_stages: int,
    axis_name: str = "pipe",
):
    """shard_map wrapper: stacked stage params (S, ...) -> pipelined forward."""
    inner = gpipe(stage_fn, n_stages, axis_name)

    def with_squeeze(stage_params, microbatches):
        # shard_map leaves a leading stage axis of size 1 on each device
        local = jax.tree.map(lambda a: a[0], stage_params)
        return inner(local, microbatches)

    return shard_map(
        with_squeeze,
        mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
    )
