"""Config registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import dataclasses
from importlib import import_module

from repro.common.config import ArchConfig, ShapeSpec

_MODULES = {
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "gemma-2b": "repro.configs.gemma_2b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "bst": "repro.configs.bst",
    "fm": "repro.configs.fm",
    "dlrm-mlperf": "repro.configs.dlrm_mlperf",
    "mind": "repro.configs.mind",
    "learned-index": "repro.configs.learned_index",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "learned-index")


def get_arch(name: str):
    """Returns (ArchConfig, shapes tuple, skip dict)."""
    mod = import_module(_MODULES[name])
    return mod.CONFIG, mod.SHAPES, mod.SKIP_SHAPES


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw: dict = {}
    if cfg.family == "lm":
        kw = dict(
            n_layers=2 * len(cfg.attn_types) + (cfg.first_dense_layers if cfg.use_moe else 0),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(cfg.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab_size=251,
        )
        if cfg.use_mla:
            kw.update(
                n_kv_heads=4, kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16, q_lora_rank=24 if cfg.q_lora_rank else None,
            )
        if cfg.use_moe:
            # dropless at smoke scale: decode==full-forward must hold exactly
            kw.update(n_routed_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=32,
                      moe_capacity_factor=1e9)
    elif cfg.family == "gnn":
        kw = dict(gnn_layers=3, gnn_hidden=32, node_feat_dim=16, edge_feat_dim=4)
    elif cfg.family == "recsys":
        kw = dict(vocab_sizes=tuple(min(v, 1000) for v in cfg.vocab_sizes))
    return dataclasses.replace(cfg, **kw)
