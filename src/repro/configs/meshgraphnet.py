"""meshgraphnet [arXiv:2010.03409]: 15 layers, hidden 128, sum aggregator,
2-layer MLPs. Encode-process-decode over padded graphs."""
from repro.common.config import ArchConfig
from repro.configs.shapes import GNN_SHAPES

CONFIG = ArchConfig(
    name="meshgraphnet",
    family="gnn",
    gnn_layers=15,
    gnn_hidden=128,
    gnn_mlp_layers=2,
    gnn_aggregator="sum",
    node_feat_dim=128,  # overridden per shape (d_feat)
    edge_feat_dim=4,
    gnn_out_dim=2,
)
SHAPES = GNN_SHAPES
SKIP_SHAPES = {}
