"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]: 27L d2048 16H MLA
(kv_lora=512, rope 64, nope 128, v 128), MoE 64 routed top-6 + 2 shared,
moe_ff 1408, dense ff 10944, first layer dense, vocab 102400."""
from repro.common.config import ArchConfig
from repro.configs.shapes import LM_SHAPES

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="lm",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,
    vocab_size=102400,
    use_mla=True,
    q_lora_rank=None,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    use_moe=True,
    n_routed_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    moe_aux_free=False,  # v2 uses aux-loss balancing (softmax gate)
)
SHAPES = LM_SHAPES
# MLA = compressed-KV attention; 512k latent cache fits -> long_500k runs
SKIP_SHAPES = {}
