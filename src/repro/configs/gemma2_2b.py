"""gemma2-2b [arXiv:2408.00118; hf]: 26L d2304 8H (GQA kv=4) ff9216
vocab 256000 — local(4096)+global alternating, logit softcaps, GeGLU,
head_dim 256, post-norms, embedding scale."""
from repro.common.config import ArchConfig
from repro.configs.shapes import LM_SHAPES

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="lm",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    activation="geglu",
    attn_types=("local", "global"),
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
)
SHAPES = LM_SHAPES
SKIP_SHAPES = {}  # hybrid local/global: long_500k runs (local layers keep 4k ring KV)
