"""gemma-2b [arXiv:2403.08295; hf]: 18L d2048 8H (MQA kv=1) ff16384
vocab 256000 — GeGLU, head_dim 256, embedding scale."""
from repro.common.config import ArchConfig
from repro.configs.shapes import LM_SHAPES

CONFIG = ArchConfig(
    name="gemma-2b",
    family="lm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    embed_scale=True,
    tie_embeddings=True,
)
SHAPES = LM_SHAPES
SKIP_SHAPES = {"long_500k": "pure full attention: every layer needs a 512k KV; no sub-quadratic path"}
