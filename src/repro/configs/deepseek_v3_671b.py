"""deepseek-v3-671b [arXiv:2412.19437; hf]: 61L d7168 128H MLA
(q_lora 1536, kv_lora 512, rope 64, nope 128, v 128), MoE 256 routed top-8
+ 1 shared, moe_ff 2048, dense ff 18432, first 3 layers dense,
aux-loss-free bias routing, MTP head, vocab 129280."""
from repro.common.config import ArchConfig
from repro.configs.shapes import LM_SHAPES

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="lm",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    use_moe=True,
    n_routed_experts=256,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    moe_aux_free=True,
    use_mtp=True,
    moe_a2a=True,  # explicit token a2a (EXPERIMENTS §Perf iter 5)
)
SHAPES = LM_SHAPES
SKIP_SHAPES = {}
