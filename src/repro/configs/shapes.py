"""Assigned input-shape sets (verbatim from the assignment grid)."""
from __future__ import annotations

from repro.common.config import ShapeSpec

LM_SHAPES = (
    ShapeSpec(name="train_4k", kind="train", seq_len=4096, global_batch=256),
    ShapeSpec(name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32),
    ShapeSpec(name="decode_32k", kind="decode", seq_len=32768, global_batch=128),
    ShapeSpec(name="long_500k", kind="decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeSpec(name="full_graph_sm", kind="train", n_nodes=2708, n_edges=10556, d_feat=1433),
    ShapeSpec(
        name="minibatch_lg",
        kind="train",
        n_nodes=232_965,
        n_edges=114_615_892,
        batch_nodes=1024,
        fanout=(15, 10),
    ),
    ShapeSpec(name="ogb_products", kind="train", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100),
    ShapeSpec(name="molecule", kind="train", n_nodes=30, n_edges=64, n_graphs=128),
)

RECSYS_SHAPES = (
    ShapeSpec(name="train_batch", kind="train", global_batch=65536),
    ShapeSpec(name="serve_p99", kind="serve", global_batch=512),
    ShapeSpec(name="serve_bulk", kind="serve", global_batch=262_144),
    ShapeSpec(name="retrieval_cand", kind="retrieval", global_batch=1, n_candidates=1_000_000),
)
