"""dlrm-mlperf [arXiv:1906.00091]: MLPerf DLRM (Criteo 1TB). 13 dense,
26 sparse (MLPerf vocabs, ~188M rows total), embed 128,
bot 512-256-128, top 1024-1024-512-256-1, dot interaction."""
from repro.common.config import ArchConfig
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import CRITEO_VOCABS

CONFIG = ArchConfig(
    name="dlrm-mlperf",
    family="recsys",
    n_dense=13,
    n_sparse=26,
    embed_dim=128,
    bot_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    interaction="dot",
    vocab_sizes=tuple(CRITEO_VOCABS),
)
SHAPES = RECSYS_SHAPES
SKIP_SHAPES = {}
