"""bst [arXiv:1905.06874]: Behavior Sequence Transformer (Alibaba).
embed 32, hist len 20, 1 block, 8 heads, MLP 1024-512-256. Item vocab 4M
(Taobao scale; the paper does not publish the exact cardinality)."""
from repro.common.config import ArchConfig
from repro.configs.shapes import RECSYS_SHAPES

CONFIG = ArchConfig(
    name="bst",
    family="recsys",
    embed_dim=32,
    hist_len=20,
    n_blocks=1,
    n_heads=8,
    top_mlp=(1024, 512, 256),
    interaction="transformer-seq",
    vocab_sizes=(4_000_000,),
)
SHAPES = RECSYS_SHAPES
SKIP_SHAPES = {}
