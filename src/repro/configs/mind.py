"""mind [arXiv:1904.08030]: multi-interest capsule routing. embed 64,
4 interests, 3 routing iterations, hist len 50, item vocab 1M."""
from repro.common.config import ArchConfig
from repro.configs.shapes import RECSYS_SHAPES

CONFIG = ArchConfig(
    name="mind",
    family="recsys",
    embed_dim=64,
    n_interests=4,
    capsule_iters=3,
    hist_len=50,
    interaction="multi-interest",
    vocab_sizes=(1_000_000,),
)
SHAPES = RECSYS_SHAPES
SKIP_SHAPES = {}
