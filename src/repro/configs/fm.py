"""fm [Rendle ICDM'10]: 39 sparse fields, embed 10, pairwise interactions
via the O(nk) sum-square trick. Criteo layout: 26 categorical vocabs +
13 bucketized numeric fields (1000 buckets each)."""
from repro.common.config import ArchConfig
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import CRITEO_VOCABS

CONFIG = ArchConfig(
    name="fm",
    family="recsys",
    n_sparse=39,
    embed_dim=10,
    interaction="fm-2way",
    vocab_sizes=tuple(CRITEO_VOCABS) + (1000,) * 13,
)
SHAPES = RECSYS_SHAPES
SKIP_SHAPES = {}
