"""The paper's own system config: learned index for Boolean retrieval.

Serve shapes: batched conjunctive queries against a doc-embedding index.
(The paper's s = 512-bit worst case = 128-dim fp32 embeddings.)"""
from repro.common.config import ArchConfig, LearnedIndexConfig, ShapeSpec

CONFIG = ArchConfig(name="learned-index", family="learned_index", embed_dim=128)
LEARNED_INDEX = LearnedIndexConfig(
    algorithm="two_tier",
    embed_dim=128,
    truncation_k=4000,
    block_size=1024,
    replace_df_threshold=4000,
)
# query serving over a ClueWeb-scale doc table (50.2M docs), 8-term queries
SHAPES = (
    ShapeSpec(name="serve_queries", kind="serve", global_batch=4096, seq_len=8,
              n_candidates=50_220_423),
    ShapeSpec(name="serve_block", kind="serve", global_batch=1024, seq_len=8,
              n_candidates=50_220_423),
)
SKIP_SHAPES = {}
