"""phi4-mini-3.8b [arXiv:2412.08905; hf]: 32L d3072 24H (GQA kv=8) ff8192
vocab 200064 — RoPE SwiGLU GQA, tied embeddings."""
from repro.common.config import ArchConfig
from repro.configs.shapes import LM_SHAPES

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="lm",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    activation="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    attn_shard="seq",  # 24 heads % 16 != 0: shard attention over query seq (SP)
)
SHAPES = LM_SHAPES
# pure full attention -> long_500k skipped (DESIGN.md §6)
SKIP_SHAPES = {"long_500k": "pure full attention: every layer needs a 512k KV; no sub-quadratic path"}
