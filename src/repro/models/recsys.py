"""RecSys family: DLRM (MLPerf), FM, BST, MIND.

Shared substrate:
  * EmbeddingBag — jnp.take + segment_sum (JAX has no native EmbeddingBag;
    per kernel_taxonomy §RecSys this IS part of the system). Tables are
    row-sharded over the `model` axis ("table_vocab" logical axis).
  * retrieval scoring — one user context against n_candidates items, batched
    (never a loop): models with a factorized target term (FM, BST, MIND) use
    their closed form; DLRM broadcasts the shared user-side computation.

Batch layouts:
  dlrm: dense (B,13) f32, sparse (B,26) i32, label (B,)
  fm:   sparse (B,39) i32, label (B,)
  bst:  hist (B,L) i32, target (B,) i32, label (B,)
  mind: hist (B,L) i32, target (B,) i32, label (B,)
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.common.config import ArchConfig

# MLPerf DLRM Criteo-1TB per-field vocabulary sizes (26 categorical fields)
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


# ------------------------------------------------------------ EmbeddingBag
def embedding_bag(
    table: jax.Array,  # (V, D)
    indices: jax.Array,  # (B, L) int32, -1 = pad
    *,
    mode: str = "sum",
) -> jax.Array:
    """Multi-hot lookup-reduce: (B, L) ids -> (B, D)."""
    mask = (indices >= 0).astype(table.dtype)[..., None]
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0, mode="clip") * mask
    out = rows.sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(axis=1), 1.0)
    return out


def init_tables(key, vocab_sizes, dim, dtype=jnp.float32, scale=0.01):
    tables, axes = [], []
    for i, v in enumerate(vocab_sizes):
        k = jax.random.fold_in(key, i)
        tables.append(jax.random.normal(k, (v, dim), dtype) * scale)
        axes.append(("table_vocab", None))
    return tables, axes


# ------------------------------------------------------------------ DLRM
def init_dlrm(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["tables"], axes["tables"] = init_tables(ks[0], cfg.vocab_sizes, cfg.embed_dim, dtype)
    params["bot"], axes["bot"] = nn.mlp_init(ks[1], [cfg.n_dense, *cfg.bot_mlp], dtype=dtype)
    n_f = cfg.n_sparse + 1
    n_int = n_f * (n_f - 1) // 2
    top_in = n_int + cfg.bot_mlp[-1]
    params["top"], axes["top"] = nn.mlp_init(ks[2], [top_in, *cfg.top_mlp], dtype=dtype)
    return params, axes


def _dlrm_interact(emb: jax.Array) -> jax.Array:
    """emb (B, F, D) -> upper-triangle of emb @ embᵀ, (B, F(F-1)/2)."""
    b, f, d = emb.shape
    z = jnp.einsum("bfd,bgd->bfg", emb, emb)
    iu, ju = jnp.triu_indices(f, k=1)
    return z[:, iu, ju]


def dlrm_forward(params, cfg: ArchConfig, batch) -> jax.Array:
    x = nn.mlp(params["bot"], batch["dense"], act=jax.nn.relu, final_act=jax.nn.relu)
    embs = [
        jnp.take(t, batch["sparse"][:, i], axis=0, mode="clip") for i, t in enumerate(params["tables"])
    ]
    emb = jnp.stack([x, *embs], axis=1)  # (B, 27, D)
    inter = _dlrm_interact(emb)
    top_in = jnp.concatenate([x, inter], axis=-1)
    return nn.mlp(params["top"], top_in, act=jax.nn.relu)[..., 0]


def dlrm_retrieval(params, cfg: ArchConfig, batch, candidates: jax.Array) -> jax.Array:
    """Score 1 user context x C candidate items in sparse field 0."""
    x = nn.mlp(params["bot"], batch["dense"], act=jax.nn.relu, final_act=jax.nn.relu)  # (1, D)
    fixed = [
        jnp.take(t, batch["sparse"][:, i], axis=0, mode="clip")
        for i, t in enumerate(params["tables"])
        if i != 0
    ]
    c = candidates.shape[0]
    cand_emb = jnp.take(params["tables"][0], candidates, axis=0, mode="clip")  # (C, D)
    user = jnp.stack([x[0], *[f[0] for f in fixed]], axis=0)  # (F, D)
    # broadcast: emb (C, F+1, D) with candidate in slot 1
    emb = jnp.concatenate(
        [
            jnp.broadcast_to(user[None, :1], (c, 1, user.shape[1])),
            cand_emb[:, None],
            jnp.broadcast_to(user[None, 1:], (c, user.shape[0] - 1, user.shape[1])),
        ],
        axis=1,
    )
    inter = _dlrm_interact(emb)
    top_in = jnp.concatenate([jnp.broadcast_to(x, (c, x.shape[1])), inter], axis=-1)
    return nn.mlp(params["top"], top_in, act=jax.nn.relu)[..., 0]


# ------------------------------------------------------------------ FM
def init_fm(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    params: dict[str, Any] = {"w0": jnp.zeros((), dtype)}
    axes: dict[str, Any] = {"w0": ()}
    params["tables"], axes["tables"] = init_tables(ks[0], cfg.vocab_sizes, cfg.embed_dim, dtype)
    params["linear"], axes["linear"] = init_tables(ks[1], cfg.vocab_sizes, 1, dtype)
    return params, axes


def fm_forward(params, cfg: ArchConfig, batch) -> jax.Array:
    """Rendle's O(nk) sum-square trick: ½[(Σv)² − Σv²]."""
    vs = jnp.stack(
        [jnp.take(t, batch["sparse"][:, i], axis=0, mode="clip") for i, t in enumerate(params["tables"])],
        axis=1,
    )  # (B, F, K)
    lin = jnp.stack(
        [jnp.take(t, batch["sparse"][:, i], axis=0, mode="clip") for i, t in enumerate(params["linear"])],
        axis=1,
    ).sum(axis=(1, 2))
    s = vs.sum(axis=1)
    pair = 0.5 * (jnp.square(s) - jnp.square(vs).sum(axis=1)).sum(axis=-1)
    return params["w0"] + lin + pair


def fm_retrieval(params, cfg: ArchConfig, batch, candidates: jax.Array) -> jax.Array:
    """Factorized: score(c) = base + lin_c + v_c·S, S = Σ_{f≠0} v_f."""
    vs = jnp.stack(
        [jnp.take(t, batch["sparse"][:, i], axis=0, mode="clip") for i, t in enumerate(params["tables"])],
        axis=1,
    )[0]  # (F, K) single user
    lin_fixed = jnp.stack(
        [jnp.take(t, batch["sparse"][:, i], axis=0, mode="clip") for i, t in enumerate(params["linear"])],
        axis=1,
    )[0, 1:].sum()
    s_fixed = vs[1:].sum(axis=0)  # (K,)
    pair_fixed = 0.5 * (jnp.square(s_fixed) - jnp.square(vs[1:]).sum(axis=0)).sum()
    v_c = jnp.take(params["tables"][0], candidates, axis=0, mode="clip")  # (C, K)
    lin_c = jnp.take(params["linear"][0], candidates, axis=0, mode="clip")[:, 0]
    return params["w0"] + lin_fixed + pair_fixed + lin_c + v_c @ s_fixed


# ------------------------------------------------------------------ BST
def init_bst(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    d = cfg.embed_dim
    seq = cfg.hist_len + 1
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["item_table"] = jax.random.normal(ks[0], (cfg.vocab_sizes[0], d), dtype) * 0.01
    axes["item_table"] = ("table_vocab", None)
    params["pos_table"] = jax.random.normal(ks[1], (seq, d), dtype) * 0.01
    axes["pos_table"] = (None, None)
    s = 1.0 / math.sqrt(d)
    params["attn"] = {
        "wq": jax.random.normal(ks[2], (d, cfg.n_heads, d // cfg.n_heads), dtype) * s,
        "wk": jax.random.normal(jax.random.fold_in(ks[2], 1), (d, cfg.n_heads, d // cfg.n_heads), dtype) * s,
        "wv": jax.random.normal(jax.random.fold_in(ks[2], 2), (d, cfg.n_heads, d // cfg.n_heads), dtype) * s,
        "wo": jax.random.normal(jax.random.fold_in(ks[2], 3), (cfg.n_heads, d // cfg.n_heads, d), dtype) * s,
    }
    axes["attn"] = {
        "wq": (None, "heads", None),
        "wk": (None, "heads", None),
        "wv": (None, "heads", None),
        "wo": ("heads", None, None),
    }
    params["ffn"], axes["ffn"] = nn.mlp_init(ks[3], [d, 4 * d, d], dtype=dtype)
    params["ln1"], _ = nn.layernorm_init(d, dtype)
    params["ln2"], _ = nn.layernorm_init(d, dtype)
    axes["ln1"] = {"scale": (None,), "bias": (None,)}
    axes["ln2"] = {"scale": (None,), "bias": (None,)}
    params["mlp"], axes["mlp"] = nn.mlp_init(ks[4], [seq * d, *cfg.top_mlp, 1], dtype=dtype)
    return params, axes


def _bst_encode(params, cfg: ArchConfig, items: jax.Array) -> jax.Array:
    """items (B, L+1) -> transformer output (B, (L+1)·D)."""
    d = cfg.embed_dim
    x = jnp.take(params["item_table"], items, axis=0, mode="clip") + params["pos_table"][None]
    h = nn.layernorm(params["ln1"], x)
    q = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wv"])
    sc = jnp.einsum("bshk,bthk->bhst", q, k) / math.sqrt(d // cfg.n_heads)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhst,bthk->bshk", p, v)
    x = x + jnp.einsum("bshk,hkd->bsd", o, params["attn"]["wo"])
    h = nn.layernorm(params["ln2"], x)
    x = x + nn.mlp(params["ffn"], h, act=jax.nn.leaky_relu)
    return x.reshape(x.shape[0], -1)


def bst_forward(params, cfg: ArchConfig, batch) -> jax.Array:
    items = jnp.concatenate([batch["hist"], batch["target"][:, None]], axis=1)
    flat = _bst_encode(params, cfg, items)
    return nn.mlp(params["mlp"], flat, act=jax.nn.leaky_relu)[..., 0]


def bst_retrieval(params, cfg: ArchConfig, batch, candidates: jax.Array) -> jax.Array:
    """1 user history x C candidates: target slot varies over candidates."""
    c = candidates.shape[0]
    hist = jnp.broadcast_to(batch["hist"][:1], (c, batch["hist"].shape[1]))
    return bst_forward(params, cfg, {"hist": hist, "target": candidates})


# ------------------------------------------------------------------ MIND
def init_mind(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    d = cfg.embed_dim
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["item_table"] = jax.random.normal(ks[0], (cfg.vocab_sizes[0], d), dtype) * 0.01
    axes["item_table"] = ("table_vocab", None)
    # shared bilinear map S (capsule routing, B2I variant)
    params["s_map"] = jax.random.normal(ks[1], (d, d), dtype) / math.sqrt(d)
    axes["s_map"] = (None, None)
    # fixed (non-trainable in paper; trainable here) routing init logits
    params["b_init"] = jax.random.normal(ks[2], (cfg.n_interests, cfg.hist_len), dtype) * 0.1
    axes["b_init"] = (None, None)
    return params, axes


def _squash(x: jax.Array) -> jax.Array:
    n2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_interests(params, cfg: ArchConfig, hist: jax.Array) -> jax.Array:
    """Behavior→Interest dynamic routing: (B, L) ids -> (B, J, D) capsules."""
    e = jnp.take(params["item_table"], hist, axis=0, mode="clip")  # (B, L, D)
    eh = e @ params["s_map"]  # (B, L, D)
    mask = (hist >= 0).astype(eh.dtype)
    b_log = jnp.broadcast_to(params["b_init"][None], (e.shape[0], *params["b_init"].shape))
    u = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b_log, axis=1)  # over interests
        w = w * mask[:, None, :]
        z = jnp.einsum("bjl,bld->bjd", w, eh)
        u = _squash(z)
        b_log = b_log + jnp.einsum("bjd,bld->bjl", u, eh)
    return u


def mind_forward(params, cfg: ArchConfig, batch) -> jax.Array:
    """Label-aware: score = max_j u_j · target (serving form, MIND §4)."""
    u = mind_interests(params, cfg, batch["hist"])  # (B, J, D)
    t = jnp.take(params["item_table"], batch["target"], axis=0, mode="clip")  # (B, D)
    scores = jnp.einsum("bjd,bd->bj", u, t)
    return scores.max(axis=-1)


def mind_retrieval(params, cfg: ArchConfig, batch, candidates: jax.Array) -> jax.Array:
    u = mind_interests(params, cfg, batch["hist"][:1])  # (1, J, D)
    cand = jnp.take(params["item_table"], candidates, axis=0, mode="clip")  # (C, D)
    scores = jnp.einsum("jd,cd->cj", u[0], cand)
    return scores.max(axis=-1)


# ------------------------------------------------------------------ losses
def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean(
        -(labels * jax.nn.log_sigmoid(logits) + (1 - labels) * jax.nn.log_sigmoid(-logits))
    )


FORWARD = {"dlrm-mlperf": dlrm_forward, "fm": fm_forward, "bst": bst_forward, "mind": mind_forward}
RETRIEVAL = {
    "dlrm-mlperf": dlrm_retrieval,
    "fm": fm_retrieval,
    "bst": bst_retrieval,
    "mind": mind_retrieval,
}
INIT = {"dlrm-mlperf": init_dlrm, "fm": init_fm, "bst": init_bst, "mind": init_mind}


def recsys_loss(params, cfg: ArchConfig, batch) -> jax.Array:
    return bce_loss(FORWARD[cfg.name](params, cfg, batch), batch["label"])
