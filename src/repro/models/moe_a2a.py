"""Expert-parallel MoE FFN with an EXPLICIT token all-to-all (shard_map).

The pjit moe_ffn relies on the SPMD partitioner to move tokens across the
data→expert sharding boundary; XLA cannot partition the scatter and
replicates activations instead (measured: ~3.4 TB/dev/step on dsv3 —
EXPERIMENTS.md §Perf iter 1 follow-up). This module moves ONLY routed tokens:

  per device (combined expert axis = data×model, n_ep devices):
    1. own a disjoint slice of the local tokens (model-axis round-robin);
    2. route top-k, bucket slots by destination device with per-(src,dst)
       capacity C = ceil(n·k/n_ep·cf) (+1 trash row);
    3. all_to_all the (n_ep, C+1, d) buckets + metadata;
    4. run the resident expert(s) on arrivals; all_to_all back;
    5. combine k weighted returns, psum-merge the model-axis slices.

Wire cost per device per layer ≈ 2 · n·k/n_ep · d bytes — for dsv3/train_4k
≈ 1 GB vs the partitioner's ~59 GB of replication.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.config import ArchConfig
from repro.common.sharding import shard_map

CAPACITY_FACTOR = 1.25


def _axes_present(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("data", "model") if a in mesh.axis_names)


def moe_a2a_applicable(cfg: ArchConfig) -> bool:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return False
    if mesh is None or mesh.size <= 1:
        return False
    axes = _axes_present(mesh)
    if not axes:
        return False
    n_ep = 1
    for a in axes:
        n_ep *= mesh.shape[a]
    return cfg.n_routed_experts % n_ep == 0


def moe_ffn_a2a(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Routed-expert part only (shared experts are dense pjit ops outside).

    x (B,S,D) data-sharded -> y (B,S,D). Call only when moe_a2a_applicable.
    """
    mesh = jax.sharding.get_abstract_mesh()
    axes = _axes_present(mesh)
    ep_axes = axes if len(axes) > 1 else axes[0]
    n_ep = 1
    for a in axes:
        n_ep *= mesh.shape[a]
    e, k, d = cfg.n_routed_experts, cfg.top_k, cfg.d_model
    e_loc = e // n_ep
    mp = mesh.shape.get("model", 1)
    dtype = x.dtype

    def inner(xs, router, bias, wg, wu, wd):
        # xs: (B_loc, S, D); w*: (E_loc, d, f)
        b_loc, s, _ = xs.shape
        flat = xs.reshape(-1, d)
        mj = jax.lax.axis_index("model") if "model" in axes else 0
        if mp > 1:  # disjoint token slice per model shard (reshape-mod ownership)
            grouped = flat.reshape(-1, mp, d)
            mine = jax.lax.dynamic_index_in_dim(grouped, mj, axis=1, keepdims=False)
        else:
            mine = flat
        n = mine.shape[0]

        logits = mine.astype(jnp.float32) @ router
        gate = jax.nn.sigmoid(logits) if cfg.moe_aux_free else jax.nn.softmax(logits, -1)
        sel = gate + bias[None, :] if cfg.moe_aux_free else gate
        _, top_idx = jax.lax.top_k(sel, k)  # (n, k)
        top_w = jnp.take_along_axis(gate, top_idx, axis=1)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        cf = getattr(cfg, "moe_capacity_factor", CAPACITY_FACTOR)
        cap = max(1, min(int(math.ceil(n * k / n_ep * cf)), n * k))
        flat_e = top_idx.reshape(-1)  # (n*k,)
        dest = flat_e // e_loc
        le = flat_e % e_loc
        onehot = jax.nn.one_hot(dest, n_ep, dtype=jnp.int32)
        pos = ((jnp.cumsum(onehot, axis=0) - 1) * onehot).sum(-1)
        dropped = pos >= cap
        pos_c = jnp.where(dropped, cap, pos)

        tok = jnp.arange(n * k) // k
        send = jnp.zeros((n_ep, cap + 1, d), dtype).at[dest, pos_c].set(mine[tok])
        send_le = jnp.zeros((n_ep, cap + 1), jnp.int32).at[dest, pos_c].set(le)
        send_ok = jnp.zeros((n_ep, cap + 1), jnp.bool_).at[dest, pos_c].set(~dropped)
        send_ok = send_ok.at[:, cap].set(False)  # trash row never valid

        recv = jax.lax.all_to_all(send, ep_axes, 0, 0, tiled=True)
        recv_le = jax.lax.all_to_all(send_le, ep_axes, 0, 0, tiled=True)
        recv_ok = jax.lax.all_to_all(send_ok, ep_axes, 0, 0, tiled=True)

        rows = recv.reshape(-1, d)  # (n_ep*(cap+1), d)
        rle = recv_le.reshape(-1)
        rok = recv_ok.reshape(-1)
        out_rows = jnp.zeros_like(rows)
        for j in range(e_loc):  # e_loc is tiny (1 for dsv3 @ 256 chips)
            h = jax.nn.silu(rows @ wg[j].astype(dtype)) * (rows @ wu[j].astype(dtype))
            yj = h @ wd[j].astype(dtype)
            out_rows = jnp.where(((rle == j) & rok)[:, None], yj, out_rows)

        back = jax.lax.all_to_all(out_rows.reshape(n_ep, cap + 1, d), ep_axes, 0, 0, tiled=True)
        slot_out = back[dest, pos_c]  # (n*k, d) aligned with send slots
        slot_out = jnp.where(dropped[:, None], 0.0, slot_out)
        y_mine = (slot_out.reshape(n, k, d) * top_w[..., None].astype(dtype)).sum(1)

        if mp > 1:  # merge the model-axis slices
            y_full = jnp.zeros((flat.shape[0] // mp, mp, d), dtype)
            y_full = jax.lax.dynamic_update_index_in_dim(y_full, y_mine[:, None], mj, axis=1)
            y_full = jax.lax.psum(y_full, "model").reshape(-1, d)
        else:
            y_full = y_mine
        return y_full.reshape(b_loc, s, d)

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    x_spec = P(data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None), None, None)
    w_spec = P(ep_axes, None, None)
    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None), w_spec, w_spec, w_spec),
        out_specs=x_spec,
    )(x, params["router"], params["bias"], params["w_gate"], params["w_up"], params["w_down"])
