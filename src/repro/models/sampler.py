"""Neighbor sampler for minibatch GNN training (GraphSAGE-style fanout).

Host-side numpy over a CSR adjacency; emits fixed-size padded subgraphs so
the jitted train step sees static shapes. Fanout (15, 10) over batch_nodes
seeds gives ≤ seeds·(1 + 15 + 150) nodes and ≤ seeds·(15 + 150) edges;
padding fills the rest.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (nnz,)
    n_nodes: int

    @staticmethod
    def random(n_nodes: int, avg_degree: int, seed: int = 0) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        deg = rng.poisson(avg_degree, n_nodes).astype(np.int64)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        indices = rng.integers(0, n_nodes, size=int(indptr[-1])).astype(np.int32)
        return CSRGraph(indptr, indices, n_nodes)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanout: tuple[int, ...],
    *,
    max_nodes: int,
    max_edges: int,
    rng: np.random.Generator,
) -> dict[str, np.ndarray]:
    """Returns padded {senders, receivers, node_ids, node_mask, edge_mask}."""
    node_ids: list[int] = list(dict.fromkeys(int(s) for s in seeds))
    local = {v: i for i, v in enumerate(node_ids)}
    senders: list[int] = []
    receivers: list[int] = []
    frontier = list(node_ids)
    for f in fanout:
        nxt: list[int] = []
        for v in frontier:
            nbrs = graph.neighbors(v)
            if len(nbrs) == 0:
                continue
            pick = rng.choice(nbrs, size=min(f, len(nbrs)), replace=False)
            for u in pick:
                u = int(u)
                if u not in local:
                    if len(node_ids) >= max_nodes:
                        continue
                    local[u] = len(node_ids)
                    node_ids.append(u)
                    nxt.append(u)
                if len(senders) < max_edges:
                    senders.append(local[u])
                    receivers.append(local[v])
        frontier = nxt
    n, m = len(node_ids), len(senders)
    out = {
        "node_ids": np.zeros(max_nodes, np.int32),
        "senders": np.zeros(max_edges, np.int32),
        "receivers": np.zeros(max_edges, np.int32),
        "node_mask": np.zeros(max_nodes, np.float32),
        "edge_mask": np.zeros(max_edges, np.float32),
    }
    out["node_ids"][:n] = node_ids
    out["senders"][:m] = senders
    out["receivers"][:m] = receivers
    out["node_mask"][:n] = 1.0
    out["edge_mask"][:m] = 1.0
    return out


def subgraph_budget(batch_nodes: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    """(max_nodes, max_edges) for a fanout sample from batch_nodes seeds."""
    nodes, frontier, edges = batch_nodes, batch_nodes, 0
    for f in fanout:
        edges += frontier * f
        frontier *= f
        nodes += frontier
    return nodes, edges
