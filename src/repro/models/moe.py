"""Mixture-of-Experts FFN with grouped capacity dispatch (GShard-style) and
aux-loss-free bias balancing (DeepSeek-V3).

Dispatch strategy (TPU-native, see DESIGN.md §3): routing groups are batch
rows, so position-within-expert is a cumsum along the LOCAL sequence axis —
no cross-device scan, no sort. A batched scatter builds (B, E, C, d) expert
buffers; expert GEMMs run all experts in parallel with E sharded over the
`model` axis (EP) — XLA inserts the data→expert all-to-all at the sharding
boundary. Combine is a k-way weighted gather back.

Capacity C = ceil(top_k · S / E · capacity_factor) per group; overflow drops
to a trash slot (GShard semantics) — the aux-free bias keeps loads even so
drops are rare.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.config import ArchConfig
from repro.common.sharding import constrain

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32):
    d, e, f = cfg.d_model, cfg.n_routed_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    params = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s,
        "bias": jnp.zeros((e,), jnp.float32),  # aux-loss-free balancing bias
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * s,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) / math.sqrt(f),
    }
    axes = {
        "router": (None, None),
        "bias": (None,),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        params["shared_gate"] = jax.random.normal(ks[4], (d, fs), dtype) * s
        params["shared_up"] = jax.random.normal(jax.random.fold_in(ks[4], 1), (d, fs), dtype) * s
        params["shared_down"] = jax.random.normal(
            jax.random.fold_in(ks[4], 2), (fs, d), dtype
        ) / math.sqrt(fs)
        axes["shared_gate"] = ("embed", "mlp")
        axes["shared_up"] = ("embed", "mlp")
        axes["shared_down"] = ("mlp", "embed")
    return params, axes


def moe_dispatch(params: Any, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Entry point: explicit-a2a expert parallelism when enabled+applicable
    (token counts and expert counts must divide the mesh), else the grouped
    pjit path. Both produce identical outputs at equal capacity (tested)."""
    if getattr(cfg, "moe_a2a", False):
        from repro.models.moe_a2a import moe_a2a_applicable, moe_ffn_a2a

        try:
            mesh = jax.sharding.get_abstract_mesh()
        except Exception:
            mesh = None
        if mesh is not None and mesh.size > 1 and moe_a2a_applicable(cfg):
            b, s, d = x.shape
            dp = 1
            for a in ("pod", "data"):
                if a in mesh.axis_names:
                    dp *= mesh.shape[a]
            mp = mesh.shape.get("model", 1)
            if b % dp == 0 and (b // dp) * s % mp == 0:
                y = moe_ffn_a2a(params, cfg, x)
                if cfg.n_shared_experts:
                    dtype = x.dtype
                    hs = jax.nn.silu(x @ params["shared_gate"].astype(dtype)) * (
                        x @ params["shared_up"].astype(dtype)
                    )
                    y = y + hs @ params["shared_down"].astype(dtype)
                return y
    return moe_ffn(params, cfg, x)


def moe_ffn(params: Any, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). Dispatch groups = batch rows."""
    dtype = x.dtype
    b, s, d = x.shape
    e, k = cfg.n_routed_experts, cfg.top_k

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"]
    )  # (B,S,E) fp32
    gate = jax.nn.sigmoid(logits) if cfg.moe_aux_free else jax.nn.softmax(logits, -1)
    # aux-loss-free: bias steers SELECTION only, not combine weights (dsv3 §3.2)
    sel = gate + params["bias"][None, None, :] if cfg.moe_aux_free else gate
    _, top_idx = jax.lax.top_k(sel, k)  # (B,S,k)
    top_w = jnp.take_along_axis(gate, top_idx, axis=2)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    cf = getattr(cfg, "moe_capacity_factor", CAPACITY_FACTOR)
    cap = max(1, min(int(math.ceil(k * s / e * cf)), s * k))
    flat_e = top_idx.reshape(b, s * k)  # (B, S*k) expert of each slot
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (B, S*k, E)
    pos = ((jnp.cumsum(onehot, axis=1) - 1) * onehot).sum(-1)  # (B, S*k)
    dropped = pos >= cap
    pos_c = jnp.where(dropped, cap, pos)  # slot `cap` = trash row

    tok = jnp.arange(s * k) // k  # slot -> token within row
    bidx = jnp.arange(b)[:, None]
    buf = jnp.zeros((b, e, cap + 1, d), dtype)
    buf = buf.at[bidx, flat_e, pos_c].set(x[:, tok])  # slot `cap` collects drops
    buf = constrain(buf, None, "experts", None, None)  # token a2a to expert shards

    # expert GEMMs — E sharded over `model` (EP); all-to-all at the boundary
    h_g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(dtype))
    h_u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(dtype))
    h = jax.nn.silu(h_g) * h_u
    out = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dtype))
    out = constrain(out, None, "experts", None, None)

    slot_out = out[bidx, flat_e, pos_c]  # (B, S*k, d)
    slot_out = jnp.where(dropped[..., None], 0.0, slot_out)
    y = (slot_out.reshape(b, s, k, d) * top_w[..., None].astype(dtype)).sum(axis=2)

    if cfg.n_shared_experts:
        hs = jax.nn.silu(x @ params["shared_gate"].astype(dtype)) * (
            x @ params["shared_up"].astype(dtype)
        )
        y = y + hs @ params["shared_down"].astype(dtype)
    return y


def load_balance_stats(params: Any, cfg: ArchConfig, x: jax.Array) -> dict[str, jax.Array]:
    """Expert load histogram (for the bias-update controller in train.py)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gate = jax.nn.sigmoid(logits) if cfg.moe_aux_free else jax.nn.softmax(logits, -1)
    _, top_idx = jax.lax.top_k(gate + params["bias"][None, None, :], cfg.top_k)
    load = jnp.zeros(cfg.n_routed_experts).at[top_idx.reshape(-1)].add(1.0)
    return {"load": load, "mean": load.mean()}


def update_balance_bias(bias: jax.Array, load: jax.Array, lr: float = 1e-3) -> jax.Array:
    """dsv3 §3.2: nudge bias down for overloaded experts, up for underloaded."""
    err = load.mean() - load
    return bias + lr * jnp.sign(err)
