"""The LM family: one decoder implementation covering all five assigned archs.

Features selected per ArchConfig:
  * GQA / MQA (phi4-mini, gemma, gemma2) or MLA (deepseek-v2-lite, -v3)
  * RoPE, SwiGLU / GeGLU, RMSNorm (gemma (1+scale) convention)
  * gemma2: local(window)+global alternation, attn & final logit softcaps,
    post-attention/post-ffn norms, embedding scale sqrt(d_model)
  * deepseek MoE: shared+routed experts, top-k, aux-loss-free bias, first
    k layers dense; dsv3 MTP head (one extra block predicting token t+2)
  * scan-over-layers (one scan per homogeneous layer group) keeps HLO size
    and compile time bounded at 61 layers

Layer groups: layers are partitioned into (dense-prefix, scanned-periodic)
groups; within a scan step all `period` attention types run (gemma2: local
then global), so stacked params have leading dim n_layers // period.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.common.config import ArchConfig
from repro.common.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod


# ------------------------------------------------------------------ FFN
def init_ffn(key, cfg: ArchConfig, dtype=jnp.float32, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    params = {
        "w_gate": jax.random.normal(ks[0], (d, f), dtype) * s,
        "w_up": jax.random.normal(ks[1], (d, f), dtype) * s,
        "w_down": jax.random.normal(ks[2], (f, d), dtype) / math.sqrt(f),
    }
    axes = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    return params, axes


def ffn(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    g = constrain(x @ params["w_gate"].astype(dtype), "batch", None, "mlp")
    u = constrain(x @ params["w_up"].astype(dtype), "batch", None, "mlp")
    act = jax.nn.gelu(g, approximate=True) if cfg.activation == "geglu" else jax.nn.silu(g)
    return (act * u) @ params["w_down"].astype(dtype)


# ------------------------------------------------------------------ block
def init_block(key, cfg: ArchConfig, layer_idx: int, dtype=jnp.float32):
    """One transformer block; layer_idx selects attn type + dense/moe ffn."""
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    if cfg.use_mla:
        params["attn"], axes["attn"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        params["attn"], axes["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    use_moe = cfg.use_moe and layer_idx >= cfg.first_dense_layers
    if use_moe:
        params["ffn"], axes["ffn"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        params["ffn"], axes["ffn"] = init_ffn(ks[1], cfg, dtype)
    params["ln1"], _ = nn.rmsnorm_init(cfg.d_model, dtype)
    params["ln2"], _ = nn.rmsnorm_init(cfg.d_model, dtype)
    axes["ln1"] = {"scale": (None,)}
    axes["ln2"] = {"scale": (None,)}
    if cfg.name.startswith("gemma2"):  # post-norms (gemma2 only)
        params["post_ln1"], _ = nn.rmsnorm_init(cfg.d_model, dtype)
        params["post_ln2"], _ = nn.rmsnorm_init(cfg.d_model, dtype)
        axes["post_ln1"] = {"scale": (None,)}
        axes["post_ln2"] = {"scale": (None,)}
    return params, axes


def block_forward(
    params,
    cfg: ArchConfig,
    layer_idx: int,
    x: jax.Array,
    q_pos: jax.Array,
    cache: attn.KVCache | None = None,
) -> tuple[jax.Array, attn.KVCache | None]:
    a_type = cfg.attn_types[layer_idx % len(cfg.attn_types)]
    window = cfg.window_size if a_type == "local" else None
    x = constrain(x, "batch", None, None)
    h = nn.rmsnorm(params["ln1"], x, eps=cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = attn.mla_attention(params["attn"], cfg, h, q_pos, cache=cache)
    else:
        a, new_cache = attn.gqa_attention(
            params["attn"], cfg, h, q_pos, window=window, cache=cache
        )
    if "post_ln1" in params:
        a = nn.rmsnorm(params["post_ln1"], a, eps=cfg.norm_eps)
    x = x + a
    h = nn.rmsnorm(params["ln2"], x, eps=cfg.norm_eps)
    use_moe = cfg.use_moe and layer_idx >= cfg.first_dense_layers
    f = moe_mod.moe_dispatch(params["ffn"], cfg, h) if use_moe else ffn(params["ffn"], cfg, h)
    if "post_ln2" in params:
        f = nn.rmsnorm(params["post_ln2"], f, eps=cfg.norm_eps)
    return x + f, new_cache


# ------------------------------------------------------------------ model
class LMParams(NamedTuple):
    embed: Any
    prefix: list  # unstacked dense-prefix blocks
    stacked: Any  # scanned blocks: leaves have leading dim n_scan
    final_norm: Any
    lm_head: Any | None  # None = tied embeddings
    mtp: Any | None  # dsv3 multi-token-prediction block


def _layer_split(cfg: ArchConfig) -> tuple[int, int, int]:
    """(n_prefix, n_scan_groups, period)."""
    period = len(cfg.attn_types)
    n_prefix = cfg.first_dense_layers if cfg.use_moe else 0
    rest = cfg.n_layers - n_prefix
    assert rest % period == 0, (cfg.n_layers, n_prefix, period)
    return n_prefix, rest // period, period


def init_lm(key, cfg: ArchConfig, dtype=jnp.float32) -> tuple[LMParams, LMParams]:
    n_prefix, n_groups, period = _layer_split(cfg)
    keys = jax.random.split(key, 4 + n_prefix)
    embed_p, embed_a = nn.embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype=dtype)

    prefix_p, prefix_a = [], []
    for i in range(n_prefix):
        p, a = init_block(keys[4 + i], cfg, i, dtype)
        prefix_p.append(p)
        prefix_a.append(a)

    # stacked groups: init one group then vmap-stack across n_groups
    def init_group(k):
        ps, as_ = [], []
        for j in range(period):
            p, a = init_block(jax.random.fold_in(k, j), cfg, n_prefix + j, dtype)
            ps.append(p)
            as_.append(a)
        return ps, as_

    group_keys = jax.random.split(keys[1], max(n_groups, 1))
    _, group_axes = init_group(group_keys[0])
    stacked_p = jax.vmap(lambda k: init_group(k)[0])(group_keys)
    stacked_a = jax.tree.map(lambda ax: ("layers", *ax) if isinstance(ax, tuple) else ax, group_axes,
                             is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x))

    fn_p, _ = nn.rmsnorm_init(cfg.d_model, dtype)
    head_p = None
    head_a = None
    if not cfg.tie_embeddings:
        head_p, head_a = nn.dense_init(
            keys[2], cfg.d_model, cfg.vocab_size, axes=(None, "vocab"), dtype=dtype
        )
    mtp_p = mtp_a = None
    if cfg.use_mtp:
        mtp_p, mtp_a = init_block(keys[3], cfg, cfg.n_layers - 1, dtype)

    params = LMParams(embed_p, prefix_p, stacked_p, fn_p, head_p, mtp_p)
    axes = LMParams(
        embed_a,
        prefix_a,
        stacked_a,
        {"scale": (None,)},
        head_a,
        mtp_a,
    )
    return params, axes


def _maybe_remat(fn, remat: str):
    """Per-BLOCK remat. Must wrap the scan body — an outer jax.checkpoint
    around the whole loss cannot stop scan from stacking every step's
    residuals (measured: 18-layer gemma-2b saves 4x (L,B,S,D) f32 without it).
    """
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # 'full': save nothing, recompute the block


def _scan_groups(params: LMParams, cfg: ArchConfig, x, q_pos, caches=None, remat: str = "none"):
    """Run prefix blocks then the scanned periodic groups."""
    n_prefix, n_groups, period = _layer_split(cfg)
    new_caches: list[Any] = []
    ci = 0
    for i, bp in enumerate(params.prefix):
        c = caches[ci] if caches is not None else None
        if c is None:
            fn = _maybe_remat(
                lambda x, sub, i=i: block_forward(sub, cfg, i, x, q_pos, None)[0], remat
            )
            x, nc = fn(x, bp), None
        else:
            x, nc = block_forward(bp, cfg, i, x, q_pos, c)
        new_caches.append(nc)
        ci += 1

    if n_groups > 0:
        if caches is None:

            def step(x, group_p):
                for j in range(period):
                    fn = _maybe_remat(
                        lambda x, sub, j=j: block_forward(
                            sub, cfg, n_prefix + j, x, q_pos, None
                        )[0],
                        remat,
                    )
                    x = fn(x, group_p[j])
                return x, None

            x, _ = jax.lax.scan(step, x, params.stacked)
        else:
            # caches for scanned layers are stacked (n_groups, ...) pytrees
            def step(x, xs):
                group_p, group_c = xs
                ncs = []
                for j in range(period):
                    x, nc = block_forward(group_p[j], cfg, n_prefix + j, x, q_pos, group_c[j])
                    ncs.append(nc)
                return x, ncs

            x, scanned_caches = jax.lax.scan(step, x, (params.stacked, caches[ci]))
            new_caches.append(scanned_caches)
    return x, new_caches


def lm_logits(params: LMParams, cfg: ArchConfig, tokens: jax.Array, compute_dtype=jnp.bfloat16,
              remat: str = "none"):
    """tokens (B, S) -> logits (B, S, V). Training/prefill path (no cache)."""
    x = nn.embed(params.embed, tokens, compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    b, s = tokens.shape
    # row-shared positions: (1,S) keeps the causal mask batch-free (1,1,S,S)
    q_pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    x, _ = _scan_groups(params, cfg, x, q_pos, remat=remat)
    x = nn.rmsnorm(params.final_norm, x, eps=cfg.norm_eps)
    table = params.embed["table"] if params.lm_head is None else params.lm_head["w"]
    logits = x @ (table.T if params.lm_head is None else table).astype(compute_dtype)
    return nn.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def lm_loss(params: LMParams, cfg: ArchConfig, batch: dict, compute_dtype=jnp.bfloat16,
            remat: str = "none"):
    logits = lm_logits(params, cfg, batch["tokens"], compute_dtype, remat=remat)
    labels = batch["labels"]
    # CE via logsumexp: avoids materializing a second (B,S,V) log-softmax buffer
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    loss = (lse - picked).mean()
    if cfg.use_mtp and params.mtp is not None:
        # MTP: predict t+2 from the backbone's hidden states via one extra
        # block (dsv3 §2.2, single-depth variant). Shares embed/head.
        x = nn.embed(params.embed, batch["tokens"], compute_dtype)
        b, s = batch["tokens"].shape
        q_pos = jnp.arange(s, dtype=jnp.int32)[None, :]
        h, _ = block_forward(params.mtp, cfg, cfg.n_layers - 1, x, q_pos)
        h = nn.rmsnorm(params.final_norm, h, eps=cfg.norm_eps)
        table = params.embed["table"] if params.lm_head is None else params.lm_head["w"]
        mtp_logits = h @ (table.T if params.lm_head is None else table).astype(compute_dtype)
        mtp_logits = nn.softcap(mtp_logits.astype(jnp.float32), cfg.logit_softcap)
        # labels shifted one extra step
        mtp_lse = jax.nn.logsumexp(mtp_logits[:, :-1], axis=-1)
        mtp_labels = labels[:, 1:]
        mtp_picked = jnp.take_along_axis(
            mtp_logits[:, :-1], mtp_labels[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
        loss = loss + 0.3 * (mtp_lse - mtp_picked).mean()
    return loss


# ------------------------------------------------------------------ serving
def cache_spec(cfg: ArchConfig, batch: int, max_len: int) -> list:
    """Layer-ordered list of KVCache shapes (prefix..., stacked-group)."""
    n_prefix, n_groups, period = _layer_split(cfg)
    specs = []

    def one(layer_idx):
        a_type = cfg.attn_types[layer_idx % len(cfg.attn_types)]
        s_cache = min(max_len, cfg.window_size) if a_type == "local" else max_len
        if cfg.use_mla:
            return (
                (batch, s_cache, cfg.kv_lora_rank),
                (batch, s_cache, cfg.qk_rope_head_dim),
            )
        hd = cfg.resolved_head_dim
        return (
            (batch, s_cache, cfg.n_kv_heads, hd),
            (batch, s_cache, cfg.n_kv_heads, hd),
        )

    for i in range(n_prefix):
        specs.append(one(i))
    group = [one(n_prefix + j) for j in range(period)]
    if n_groups > 0:
        specs.append([((n_groups, *k), (n_groups, *v)) for k, v in group])
    return specs


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    specs = cache_spec(cfg, batch, max_len)
    out = []
    for sp in specs[:-1] if _layer_split(cfg)[1] > 0 else specs:
        out.append(attn.KVCache(jnp.zeros(sp[0], dtype), jnp.zeros(sp[1], dtype)))
    if _layer_split(cfg)[1] > 0:
        group = specs[-1]
        out.append([attn.KVCache(jnp.zeros(k, dtype), jnp.zeros(v, dtype)) for k, v in group])
    return out


def lm_decode_step(
    params: LMParams,
    cfg: ArchConfig,
    token: jax.Array,  # (B, 1) int32
    pos: jax.Array,  # (B, 1) int32 absolute position of `token`
    caches,
    compute_dtype=jnp.bfloat16,
):
    """One serving step: new token + caches -> (logits (B, V), new caches)."""
    x = nn.embed(params.embed, token, compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    x, new_caches = _scan_groups(params, cfg, x, pos, caches)
    x = nn.rmsnorm(params.final_norm, x, eps=cfg.norm_eps)
    table = params.embed["table"] if params.lm_head is None else params.lm_head["w"]
    logits = x[:, 0] @ (table.T if params.lm_head is None else table).astype(compute_dtype)
    return nn.softcap(logits.astype(jnp.float32), cfg.logit_softcap), new_caches


def lm_prefill(
    params: LMParams,
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, S)
    caches,
    compute_dtype=jnp.bfloat16,
):
    """Prefill: run the full prompt, writing caches; returns last-pos logits."""
    x = nn.embed(params.embed, tokens, compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    b, s = tokens.shape
    q_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    x, new_caches = _scan_groups(params, cfg, x, q_pos, caches)
    x = nn.rmsnorm(params.final_norm, x, eps=cfg.norm_eps)
    table = params.embed["table"] if params.lm_head is None else params.lm_head["w"]
    logits = x[:, -1] @ (table.T if params.lm_head is None else table).astype(compute_dtype)
    return nn.softcap(logits.astype(jnp.float32), cfg.logit_softcap), new_caches
