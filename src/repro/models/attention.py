"""Attention variants for the LM family: GQA/MQA, sliding-window, softcap, MLA.

Pure functions over param pytrees. Shapes follow (B, S, H, hd) with GQA via
head-group einsum (no kv repeat materialization). All masks are additive
float32 -inf biases computed from position indices so the same code path
serves train (full causal), prefill, and single-token decode against a cache.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.common.config import ArchConfig
from repro.common.sharding import constrain

NEG_INF = -2.0e38


# ------------------------------------------------------------------ RoPE
def rope_freqs(dim: int, theta: float, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd) — rotate pairs (x[..., ::2], x[..., 1::2])."""
    x1, x2 = x[..., ::2], x[..., 1::2]
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.stack([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ------------------------------------------------------------------ masks
def causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: int | None = None) -> jax.Array:
    """(B?, Sq) x (B?, Sk) position ids -> (.., Sq, Sk) additive mask.

    Negative k positions are always masked (ring-buffer slots not yet
    written report pos < 0 — see _ring_positions).
    """
    ok = (k_pos[..., None, :] <= q_pos[..., :, None]) & (k_pos[..., None, :] >= 0)
    if window is not None:
        ok &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ------------------------------------------------------------------ GQA
def init_gqa(key, cfg: ArchConfig, dtype=jnp.float32):
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    params = {
        "wq": jax.random.normal(ks[0], (d, hq, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (hq, hd, d), dtype) * (1.0 / math.sqrt(hq * hd)),
    }
    axes = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    return params, axes


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_cache, Hkv, hd) or MLA: c_kv (B, S_cache, kv_lora)
    v: jax.Array  # (B, S_cache, Hkv, hd) or MLA: k_rope (B, S_cache, rope_dim)


def _gqa_scores(q: jax.Array, k: jax.Array, n_rep: int) -> jax.Array:
    """q: (B,Sq,Hq,hd), k: (B,Sk,Hkv,hd) -> (B,Hq,Sq,Sk) without kv repeat."""
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    qg = q.reshape(b, sq, hkv, n_rep, hd)
    sc = jnp.einsum("bsgrh,btgh->bgrst", qg, k, preferred_element_type=jnp.float32)
    return sc.reshape(b, hq, sq, sk)


def _gqa_out(probs: jax.Array, v: jax.Array, n_rep: int) -> jax.Array:
    b, hq, sq, sk = probs.shape
    hkv = v.shape[2]
    pg = probs.reshape(b, hkv, n_rep, sq, sk)
    out = jnp.einsum("bgrst,btgh->bsgrh", pg, v.astype(probs.dtype))
    return out.reshape(b, sq, hq, v.shape[3])


def gqa_attention(
    params: Any,
    cfg: ArchConfig,
    x: jax.Array,  # (B, Sq, D)
    q_pos: jax.Array,  # (B, Sq) absolute positions
    *,
    window: int | None = None,
    cache: KVCache | None = None,
    cache_len: jax.Array | None = None,  # filled length incl. current tokens
) -> tuple[jax.Array, KVCache | None]:
    dtype = x.dtype
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    n_rep = hq // hkv
    seq_mode = cfg.attn_shard == "seq"
    q_ax = ("batch", "seq_sharded", "heads", None) if seq_mode else ("batch", None, "heads", None)
    kv_ax = ("batch", None, "kv_heads", None)  # keys stay seq-replicated (full attn)
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype)), *q_ax)
    k = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype)), *kv_ax)
    v = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype)), *kv_ax)
    cos, sin = rope_freqs(hd, cfg.rope_theta, q_pos)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    sq = x.shape[1]
    ring = cache is not None and window is not None and cache.k.shape[1] <= window
    if cache is not None and ring and sq > 1:
        # local-layer PREFILL: attend in-sequence (mask enforces the window),
        # then write only the last `cache_len` tokens — their ring slots are
        # unique, so the scatter is well-defined.
        mask = causal_mask(q_pos, q_pos, window)[:, None, :, :]
        k_use, v_use = k, v
        s_cache = cache.k.shape[1]
        tail = min(s_cache, sq)
        slot = q_pos[:, -tail:] % s_cache
        k_all = _scatter_cache(cache.k, k[:, -tail:], slot)
        v_all = _scatter_cache(cache.v, v[:, -tail:], slot)
        new_cache = KVCache(k_all, v_all)
    elif cache is not None:
        s_cache = cache.k.shape[1]
        if ring:
            slot = q_pos % s_cache  # decode: one unique slot per new token
            k_pos = _ring_positions(q_pos, s_cache)
        else:
            slot = q_pos
            k_pos = jnp.broadcast_to(
                jnp.arange(s_cache, dtype=q_pos.dtype)[None, :], (x.shape[0], s_cache)
            )
        k_all = _scatter_cache(cache.k, k, slot)
        v_all = _scatter_cache(cache.v, v, slot)
        new_cache = KVCache(k_all, v_all)
        mask = causal_mask(q_pos, k_pos, window)[:, None, :, :]
        k_use, v_use = k_all, v_all
    else:
        new_cache = None
        mask = causal_mask(q_pos, q_pos, window)[:, None, :, :]
        k_use, v_use = k, v

    scale = 1.0 / math.sqrt(hd)
    scores = _gqa_scores(q, k_use, n_rep) * scale  # (B,Hq,Sq,Sk) fp32
    if seq_mode:
        scores = constrain(scores, "batch", None, "seq_sharded", None)
    else:
        scores = constrain(scores, "batch", "heads", "seq_sharded", None)
    scores = nn.softcap(scores, cfg.attn_softcap)
    probs = jax.nn.softmax(scores + mask, axis=-1).astype(dtype)
    out = _gqa_out(probs, v_use, n_rep)  # (B,Sq,Hq,hd)
    out = constrain(out, *q_ax)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return y, new_cache


def _scatter_cache(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """cache (B,Sc,...), new (B,Sq,...), slot (B,Sq) -> cache with rows written."""
    b = cache.shape[0]
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], slot.shape)
    return cache.at[bidx, slot].set(new.astype(cache.dtype))


def _ring_positions(q_pos: jax.Array, s_cache: int) -> jax.Array:
    """Absolute positions currently living in each ring slot.

    After writing token t at slot t % Sc, slot j holds the largest position
    p <= max(q_pos) with p % Sc == j.
    """
    cur = q_pos.max(axis=-1, keepdims=True)  # (B,1) newest position
    slots = jnp.arange(s_cache, dtype=q_pos.dtype)[None, :]
    delta = (cur % s_cache - slots) % s_cache
    pos = cur - delta
    return pos  # (B, Sc); positions > cur can't occur; stale slots map to old p


# ------------------------------------------------------------------ MLA
def init_mla(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    if cfg.q_lora_rank:
        ql = cfg.q_lora_rank
        params["wdq"] = jax.random.normal(ks[0], (d, ql), dtype) * s
        params["q_norm"], _ = nn.rmsnorm_init(ql, dtype)
        params["wuq"] = jax.random.normal(ks[1], (ql, h, nope + rope), dtype) / math.sqrt(ql)
        axes["wdq"] = ("embed", None)
        axes["q_norm"] = {"scale": (None,)}
        axes["wuq"] = (None, "heads", None)
    else:
        params["wq"] = jax.random.normal(ks[1], (d, h, nope + rope), dtype) * s
        axes["wq"] = ("embed", "heads", None)
    params["wdkv"] = jax.random.normal(ks[2], (d, kvl), dtype) * s
    params["kv_norm"], _ = nn.rmsnorm_init(kvl, dtype)
    params["wkr"] = jax.random.normal(ks[3], (d, rope), dtype) * s
    params["wuk"] = jax.random.normal(ks[4], (kvl, h, nope), dtype) / math.sqrt(kvl)
    params["wuv"] = jax.random.normal(ks[5], (kvl, h, vdim), dtype) / math.sqrt(kvl)
    params["wo"] = jax.random.normal(ks[6], (h, vdim, d), dtype) / math.sqrt(h * vdim)
    axes.update(
        {
            "wdkv": ("embed", None),
            "kv_norm": {"scale": (None,)},
            "wkr": ("embed", None),
            "wuk": (None, "heads", None),
            "wuv": (None, "heads", None),
            "wo": ("heads", None, "embed"),
        }
    )
    return params, axes


def mla_attention(
    params: Any,
    cfg: ArchConfig,
    x: jax.Array,
    q_pos: jax.Array,
    *,
    cache: KVCache | None = None,
    window: int | None = None,  # unused (MLA layers are global)
) -> tuple[jax.Array, KVCache | None]:
    """Multi-head Latent Attention (DeepSeek-V2/V3).

    Cache stores the COMPRESSED latent (c_kv, k_rope) — the paper's memory
    saving — and decode re-expands per step via wuk/wuv.
    """
    dtype = x.dtype
    h = cfg.n_heads
    nope, rope, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    b, sq, _ = x.shape

    if cfg.q_lora_rank:
        cq = nn.rmsnorm(params["q_norm"], x @ params["wdq"].astype(dtype))
        q = jnp.einsum("bsl,lhk->bshk", cq, params["wuq"].astype(dtype))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_freqs(rope, cfg.rope_theta, q_pos)
    q_rope = apply_rope(q_rope, cos, sin)

    c_kv = x @ params["wdkv"].astype(dtype)  # (B,S,kvl)
    k_r = (x @ params["wkr"].astype(dtype))[:, :, None, :]  # (B,S,1,rope)
    k_r = apply_rope(k_r, cos, sin)[:, :, 0, :]  # (B,S,rope)

    if cache is not None:
        s_cache = cache.k.shape[1]
        ckv_all = _scatter_cache(cache.k, c_kv, q_pos)
        kr_all = _scatter_cache(cache.v, k_r, q_pos)
        new_cache = KVCache(ckv_all, kr_all)
        k_pos = jnp.broadcast_to(jnp.arange(s_cache, dtype=q_pos.dtype)[None, :], (b, s_cache))
        c_use, kr_use = ckv_all, kr_all
    else:
        new_cache = None
        k_pos = q_pos
        c_use, kr_use = c_kv, k_r

    c_n = nn.rmsnorm(params["kv_norm"], c_use)
    k_nope = constrain(jnp.einsum("btl,lhk->bthk", c_n, params["wuk"].astype(dtype)),
                       "batch", None, "heads", None)
    v = constrain(jnp.einsum("btl,lhv->bthv", c_n, params["wuv"].astype(dtype)),
                  "batch", None, "heads", None)

    scale = 1.0 / math.sqrt(nope + rope)
    sc = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope, preferred_element_type=jnp.float32)
    sc = sc + jnp.einsum("bshk,btk->bhst", q_rope, kr_use, preferred_element_type=jnp.float32)
    sc = constrain(sc, "batch", "heads", "seq_sharded", None)
    mask = causal_mask(q_pos, k_pos)[:, None, :, :]
    probs = jax.nn.softmax(sc * scale + mask, axis=-1).astype(dtype)
    out = jnp.einsum("bhst,bthv->bshv", probs, v)
    y = jnp.einsum("bshv,hvd->bsd", out, params["wo"].astype(dtype))
    return y, new_cache
