from repro.models import attention, gnn, moe, recsys, sampler, transformer

__all__ = ["attention", "gnn", "moe", "recsys", "sampler", "transformer"]
