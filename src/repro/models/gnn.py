"""MeshGraphNet (Pfaff et al., arXiv:2010.03409): encode-process-decode GNN.

Message passing uses jax.ops.segment_sum over an edge-index array — the
TPU-native form of SpMM aggregation (kernel_taxonomy §GNN): gather node
states at edge endpoints, MLP the concatenation, scatter-add back.

Graphs are padded to static (n_nodes, n_edges); `node_mask`/`edge_mask`
zero out padding. The neighbor sampler (minibatch_lg shape) lives in
sampler.py and produces these padded subgraphs.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import nn
from repro.common.config import ArchConfig


def _mlp_dims(cfg: ArchConfig, d_in: int) -> list[int]:
    return [d_in] + [cfg.gnn_hidden] * cfg.gnn_mlp_layers


def init_mgn(key, cfg: ArchConfig, dtype=jnp.float32) -> tuple[Any, Any]:
    ks = jax.random.split(key, 4 + cfg.gnn_layers)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    ln_axes = {"scale": (None,), "bias": (None,)}
    params["node_enc"], axes["node_enc"] = nn.mlp_init(
        ks[0], _mlp_dims(cfg, cfg.node_feat_dim), dtype=dtype
    )
    params["edge_enc"], axes["edge_enc"] = nn.mlp_init(
        ks[1], _mlp_dims(cfg, cfg.edge_feat_dim), dtype=dtype
    )
    # MGN paper: every MLP output is LayerNorm'd except the decoder's
    params["node_enc_ln"], _ = nn.layernorm_init(cfg.gnn_hidden, dtype)
    params["edge_enc_ln"], _ = nn.layernorm_init(cfg.gnn_hidden, dtype)
    axes["node_enc_ln"] = ln_axes
    axes["edge_enc_ln"] = ln_axes
    layers = []
    layer_axes = []
    for i in range(cfg.gnn_layers):
        k1, k2 = jax.random.split(ks[2 + i])
        ep, ea = nn.mlp_init(k1, _mlp_dims(cfg, 3 * cfg.gnn_hidden), dtype=dtype)
        npp, na = nn.mlp_init(k2, _mlp_dims(cfg, 2 * cfg.gnn_hidden), dtype=dtype)
        eln, _ = nn.layernorm_init(cfg.gnn_hidden, dtype)
        nln, _ = nn.layernorm_init(cfg.gnn_hidden, dtype)
        layers.append({"edge_mlp": ep, "node_mlp": npp, "edge_ln": eln, "node_ln": nln})
        layer_axes.append(
            {"edge_mlp": ea, "node_mlp": na, "edge_ln": ln_axes, "node_ln": ln_axes}
        )
    params["layers"] = layers
    axes["layers"] = layer_axes
    params["decoder"], axes["decoder"] = nn.mlp_init(
        ks[3], [cfg.gnn_hidden, cfg.gnn_hidden, cfg.gnn_out_dim], dtype=dtype
    )
    return params, axes


def mgn_forward(
    params: Any,
    cfg: ArchConfig,
    batch: dict[str, jax.Array],
    remat: bool = False,
) -> jax.Array:
    """batch: node_feat (N, F), edge_feat (E, Fe), senders (E,), receivers (E,),
    node_mask (N,), edge_mask (E,). Returns (N, out_dim)."""
    v = nn.layernorm(
        params["node_enc_ln"], nn.mlp(params["node_enc"], batch["node_feat"], act=jax.nn.relu)
    )
    e = nn.layernorm(
        params["edge_enc_ln"], nn.mlp(params["edge_enc"], batch["edge_feat"], act=jax.nn.relu)
    )
    snd, rcv = batch["senders"], batch["receivers"]
    emask = batch["edge_mask"][:, None].astype(v.dtype)
    n = v.shape[0]

    def one_layer(v, e, layer):
        # edge update: e' = e + LN(MLP([e, v_src, v_dst]))
        msg_in = jnp.concatenate([e, v[snd], v[rcv]], axis=-1)
        upd = nn.layernorm(layer["edge_ln"], nn.mlp(layer["edge_mlp"], msg_in, act=jax.nn.relu))
        e = e + upd * emask
        # node update: v' = v + LN(MLP([v, Σ_incoming e']))
        agg = jax.ops.segment_sum(e * emask, rcv, num_segments=n)
        if cfg.gnn_aggregator == "mean":
            deg = jax.ops.segment_sum(emask, rcv, num_segments=n)
            agg = agg / jnp.maximum(deg, 1.0)
        v = v + nn.layernorm(
            layer["node_ln"], nn.mlp(layer["node_mlp"], jnp.concatenate([v, agg], axis=-1), act=jax.nn.relu)
        )
        return v, e

    step = jax.checkpoint(one_layer) if remat else one_layer
    for layer in params["layers"]:
        v, e = step(v, e, layer)

    return nn.mlp(params["decoder"], v, act=jax.nn.relu)


def mgn_loss(params: Any, cfg: ArchConfig, batch: dict[str, jax.Array], remat: bool = False) -> jax.Array:
    """MSE on node targets, masked over padding."""
    pred = mgn_forward(params, cfg, batch, remat=remat)
    mask = batch["node_mask"][:, None].astype(pred.dtype)
    err = jnp.square(pred - batch["node_targets"]) * mask
    return err.sum() / jnp.maximum(mask.sum() * cfg.gnn_out_dim, 1.0)
