"""Model-guided intersection: probe bit-exactness vs full decode on every
codec, the guided_search kernel vs its jnp reference, the cost-keyed LRU,
galloping membership (incl. candidates beyond the list max — the _verify
clipping shadow), the Zipf conjunctive workload generator, and end-to-end
query_batch agreement between hybrid and raw tier-2 stores."""
import numpy as np
import pytest

from repro.index.build import InvertedIndex
from repro.index.compress import decode_postings
from repro.index.intersect import gallop_membership, membership_mask
from repro.postings import GuidedPostings, HybridPostings, load_term_model
from repro.postings.plm import plm_encode
from repro.postings.rmi import rmi_encode
from repro.serve.cache import CostLRU


def _random_list(rng, n, universe):
    n = min(n, universe)
    return np.sort(rng.choice(universe, size=n, replace=False)).astype(np.int32)


def _probe_set(rng, ids, universe):
    """Present + absent + boundary candidates (0, below min, beyond max)."""
    extremes = [0, universe - 1, universe + 1000]
    if len(ids):
        extremes += [int(ids[0]) - 1, int(ids[-1]) + 1]
    return np.unique(np.concatenate([
        ids[:: max(1, len(ids) // 40)].astype(np.int64),
        rng.integers(0, universe + 10, 120),
        np.array(extremes, np.int64).clip(0),
    ]))


# ------------------------------------------------------- guided probes
@pytest.mark.parametrize("enc,codec", [(plm_encode, "plm"), (rmi_encode, "rmi")])
@pytest.mark.parametrize("n", [1, 5, 129, 1000, 4000])
def test_guided_probe_bit_exact_vs_full_decode(enc, codec, n):
    """Acceptance: contains()/rank() from stream metadata == full decode."""
    rng = np.random.default_rng(n)
    universe = 1 << 22
    ids = _random_list(rng, n, universe)
    words = enc(ids)
    assert np.array_equal(decode_postings(words, n, codec), ids)
    tm = load_term_model(words, n)
    cands = _probe_set(rng, ids, universe)
    gp = GuidedPostings.__new__(GuidedPostings)
    from repro.postings.search import ProbeStats

    gp.stats = ProbeStats()
    found, rank = gp._probe_host(tm, cands)
    ids64 = ids.astype(np.int64)
    assert np.array_equal(found, np.isin(cands, ids64))
    assert np.array_equal(rank, np.searchsorted(ids64, cands, side="left"))


def test_guided_probe_smooth_lists_window_is_tiny():
    """The ε-window cost model: near-linear lists probe in O(1) ranks."""
    ids = (np.arange(5000, dtype=np.int64) * 64 + 7).astype(np.int32)
    tm = load_term_model(plm_encode(ids), len(ids))
    assert tm.avg_window < 4.0


@pytest.mark.parametrize("store_seed", [3, 4])
def test_guided_store_probes_match_postings_every_codec(store_seed):
    """Acceptance: GuidedPostings over a hybrid store (learned probes +
    classical fallback) agrees with store.postings membership everywhere."""
    rng = np.random.default_rng(store_seed)
    lists = [
        _random_list(rng, 300, 1 << 20),  # random sparse -> classical codec
        np.arange(0, 6000, 3, dtype=np.int32),  # arithmetic -> plm, width 0
        (np.arange(2000, dtype=np.int64) * 50
         + rng.integers(0, 12, 2000)).astype(np.int32),  # smooth -> learned
        _random_list(rng, 5, 1 << 20),  # tiny list
        np.zeros(0, np.int32),  # empty term
    ]
    universe = 1 << 21
    offsets = np.zeros(len(lists) + 1, np.int64)
    np.cumsum([len(x) for x in lists], out=offsets[1:])
    store = HybridPostings.build(offsets, np.concatenate(lists), universe)
    gp = GuidedPostings(store)
    assert len(store.codec_histogram()) >= 2  # both learned and classical hit
    for t, ids in enumerate(lists):
        cands = _probe_set(rng, ids, universe)
        found, rank = gp.probe(t, cands)
        ids64 = ids.astype(np.int64)
        assert np.array_equal(found, np.isin(cands, ids64)), f"term {t}"
        assert np.array_equal(rank, np.searchsorted(ids64, cands)), f"term {t}"
    assert gp.stats.probes > 0
    assert gp.stats.guided_bytes() > 0


def test_guided_cost_model_routes_huge_candidate_sets():
    """Probing more windows than the list has ranks must fall back."""
    ids = (np.arange(500, dtype=np.int64) * 40
           + np.random.default_rng(0).integers(0, 9, 500)).astype(np.int32)
    offsets = np.array([0, len(ids)], np.int64)
    store = HybridPostings.build(offsets, ids, 1 << 18)
    gp = GuidedPostings(store)
    assert gp.is_guided(0)  # learned-coded term...
    cands = np.arange(0, 1 << 16, dtype=np.int64)
    found, rank = gp.probe(0, cands)
    assert gp.stats.routed_terms == 1  # ...but this probe full-decoded
    assert np.array_equal(found, np.isin(cands, ids.astype(np.int64)))
    assert np.array_equal(rank, np.searchsorted(ids.astype(np.int64), cands))


def test_guided_byte_accounting_monotone():
    """Stats must grow with probing and stay below full-decode equivalents
    for small candidate sets on long smooth lists."""
    ids = (np.arange(20000, dtype=np.int64) * 100
           + np.random.default_rng(1).integers(0, 20, 20000)).astype(np.int32)
    store = HybridPostings.build(np.array([0, len(ids)], np.int64), ids, 1 << 22)
    gp = GuidedPostings(store)
    cands = ids[::1000].astype(np.int64)
    gp.probe(0, cands)
    s = gp.stats
    assert s.window_bytes > 0
    assert s.guided_bytes() < s.full_equiv_bytes / 10


# ------------------------------------------------------------- kernel
def test_guided_kernel_matches_host_and_ref():
    import jax.numpy as jnp

    from repro.index.compress import unpack_bits_at
    from repro.kernels.guided_search.kernel import probe_batch
    from repro.kernels.guided_search.ops import probe_windows
    from repro.kernels.guided_search.ref import probe_ref
    from repro.postings.search import ProbeStats, flatten_windows

    rng = np.random.default_rng(7)
    for enc in (plm_encode, rmi_encode):
        ids = _random_list(rng, 2500, 1 << 22)
        tm = load_term_model(enc(ids), len(ids))
        cands = _probe_set(rng, ids, 1 << 22)
        gp = GuidedPostings.__new__(GuidedPostings)
        gp.stats = ProbeStats()
        hf, hr = gp._probe_host(tm, cands)
        kf, kr, touched = probe_windows(tm, cands)
        assert np.array_equal(hf, kf)
        assert np.array_equal(hr, kr)
        assert touched >= 0
        # ref vs kernel on identical padded inputs
        seg, r_lo, lens, probe_of, col, flat = flatten_windows(tm, cands)
        P, W = len(cands), 128
        corr = np.zeros((P, W), np.int32)
        corr[probe_of, col] = (
            unpack_bits_at(tm.corr_words, tm.width, flat).astype(np.int64) + tm.corr_min
        ).astype(np.int32)
        cv = lambda a, d: jnp.asarray(np.asarray(a, d).reshape(P, 1))
        args = (cv(tm.starts[seg], np.int32), cv(tm.bases[seg], np.int32),
                cv(tm.slopes[seg], np.float32), cv(r_lo, np.int32),
                cv(lens, np.int32), cv(cands, np.int32), jnp.asarray(corr))
        rf, rl = probe_ref(*args)
        bf, bl = probe_batch(*args)
        assert np.array_equal(np.asarray(rf), np.asarray(bf))
        assert np.array_equal(np.asarray(rl), np.asarray(bl))


def test_guided_kernel_wide_window_split_matches_host():
    """Brackets wider than MAX_W (degenerate slope -> whole-segment scan)
    must be host-decoded without widening the kernel batch, bit-exactly."""
    from repro.kernels.guided_search.ops import MAX_W, probe_windows
    from repro.postings.plm import emit_stream
    from repro.postings.search import ProbeStats, rank_windows

    rng = np.random.default_rng(19)
    ids = _random_list(rng, 1500, 1 << 21)
    # a valid lossless stream with slope 0: corrections carry everything,
    # so every probe bracket is the whole list (1500 > MAX_W ranks)
    words = emit_stream(ids, np.array([0], np.int64),
                        np.array([int(ids[0])], np.int64),
                        np.array([0.0], np.float32), eps=0)
    assert np.array_equal(decode_postings(words, len(ids), "plm"), ids)
    tm = load_term_model(words, len(ids))
    cands = _probe_set(rng, ids, 1 << 21)
    _, r_lo, r_hi = rank_windows(tm, cands)
    assert (np.maximum(r_hi - r_lo + 1, 0) > MAX_W).any()
    gp = GuidedPostings.__new__(GuidedPostings)
    gp.stats = ProbeStats()
    hf, hr = gp._probe_host(tm, cands)
    kf, kr, _ = probe_windows(tm, cands)
    assert np.array_equal(hf, kf)
    assert np.array_equal(hr, kr)
    ids64 = ids.astype(np.int64)
    assert np.array_equal(hf, np.isin(cands, ids64))
    assert np.array_equal(hr, np.searchsorted(ids64, cands))


def test_engine_guided_kernel_path_matches_host():
    """GuidedPostings(use_kernel=True) must agree with the host path."""
    rng = np.random.default_rng(11)
    ids = (np.arange(3000, dtype=np.int64) * 30
           + rng.integers(0, 7, 3000)).astype(np.int32)
    store = HybridPostings.build(np.array([0, len(ids)], np.int64), ids, 1 << 18)
    cands = _probe_set(rng, ids, 1 << 18)
    f1, r1 = GuidedPostings(store).probe(0, cands)
    f2, r2 = GuidedPostings(store, use_kernel=True).probe(0, cands)
    assert np.array_equal(f1, f2)
    assert np.array_equal(r1, r2)


# ----------------------------------------------------- gallop / clipping
def test_membership_beyond_list_max_clipping_shadow():
    """sel == len(p) candidates must clamp to p[-1] and only match equals."""
    p = np.array([2, 5, 9, 14], np.int64)
    cands = np.array([1, 2, 14, 15, 100, 10_000], np.int64)
    expect = np.array([False, True, True, False, False, False])
    assert np.array_equal(membership_mask(p, cands), expect)
    assert np.array_equal(gallop_membership(p, cands), expect)
    # degenerate: all candidates beyond the max
    far = np.array([20, 21, 22], np.int64)
    assert not membership_mask(p, far).any()
    assert not gallop_membership(p, far).any()


@pytest.mark.parametrize("n_cands", [3, 50, 3000])
def test_gallop_matches_binary_search(n_cands):
    rng = np.random.default_rng(n_cands)
    p = np.sort(rng.choice(1 << 20, 4000, replace=False)).astype(np.int64)
    cands = np.sort(np.unique(np.concatenate([
        rng.choice(p, min(n_cands, len(p)) // 2 + 1),
        rng.integers(0, (1 << 20) + 50, n_cands),
    ])))
    assert np.array_equal(gallop_membership(p, cands), membership_mask(p, cands))


def test_verify_candidates_beyond_list_max():
    """_verify with candidate ids above every posting (the clip shadow)."""
    from repro.serve.boolean import ServeConfig
    from tests.test_postings import _bare_engine

    inv = InvertedIndex(
        n_docs=1000,
        n_terms=2,
        term_offsets=np.array([0, 4, 8], np.int64),
        doc_ids=np.array([1, 5, 9, 20, 5, 9, 20, 900], np.int32),
    )
    for cfg in (ServeConfig(postings_store="raw"), ServeConfig(postings_store="hybrid")):
        eng = _bare_engine(inv, cfg)
        cands = np.array([5, 9, 21, 500, 900, 999], np.int32)  # 21.. > term-0 max
        out = eng._verify(np.array([0, 1], np.int32), cands)
        assert out.tolist() == [5, 9]


# ----------------------------------------------------------------- LRU
def test_cost_lru_evicts_by_cost_and_recency():
    lru = CostLRU(100)
    lru.put("a", "A", 40)
    lru.put("b", "B", 40)
    assert lru.get("a") == "A"  # a is now MRU
    lru.put("c", "C", 40)  # budget forces one eviction: LRU is b
    assert lru.get("b") is None
    assert lru.get("a") == "A"
    assert lru.get("c") == "C"
    assert lru.evictions == 1
    assert lru.total_cost == 80


def test_cost_lru_always_keeps_newest():
    lru = CostLRU(10)
    lru.put("big", "X", 10_000)  # over budget alone: still resident
    assert lru.get("big") == "X"
    lru.put("next", "Y", 5)
    assert lru.get("big") is None  # evicted once something newer lands
    assert lru.get("next") == "Y"


def test_cost_lru_rejects_nonpositive_budget():
    """Zero and negative budgets are config errors, not empty caches: every
    serving path assumes the just-decoded entry can be retained, so a
    budget that could never hold anything must fail loudly at construction."""
    for bad in (0, -1, -(1 << 40)):
        with pytest.raises(ValueError, match="budget"):
            CostLRU(bad)


def test_cost_lru_oversized_entry_evicts_everything_else():
    """A single entry larger than the whole budget stays resident (the
    verification round needs the list it just decoded) but evicts every
    other entry; counters and cost accounting must reflect that exactly."""
    lru = CostLRU(100)
    lru.put("a", "A", 30)
    lru.put("b", "B", 30)
    lru.put("huge", "H", 1_000)
    assert lru.get("huge") == "H"
    assert lru.get("a") is None and lru.get("b") is None
    assert lru.evictions == 2
    assert len(lru) == 1
    assert lru.total_cost == 1_000  # over budget by design, but accounted
    s = lru.stats()
    assert s["cost_bytes"] == 1_000 and s["entries"] == 1
    # the oversized entry is itself evictable once anything newer lands
    lru.put("tiny", "T", 1)
    assert lru.get("huge") is None and lru.get("tiny") == "T"
    assert lru.total_cost == 1


def test_cost_lru_oversized_reput_updates_cost():
    """Re-putting a key replaces its cost instead of double counting, even
    across the oversized boundary in both directions."""
    lru = CostLRU(100)
    lru.put("k", "v1", 500)
    assert lru.total_cost == 500
    lru.put("k", "v2", 10)  # shrink back under budget
    assert lru.total_cost == 10 and len(lru) == 1
    assert lru.get("k") == "v2"
    lru.put("k", "v3", 700)  # grow over budget again: still the sole entry
    assert lru.total_cost == 700 and len(lru) == 1
    assert lru.evictions == 0  # replacement is not an eviction


# ------------------------------------------------------------ workload
def test_zipf_conjunctions_shape_and_validity():
    from repro.data.queries import zipf_conjunctions

    dfs = np.concatenate([np.zeros(5, np.int64), np.arange(1, 200)])
    q = zipf_conjunctions(dfs, 64, seed=5)
    assert q.shape == (64, 5)
    assert q.dtype == np.int32
    for row in q:
        terms = row[row >= 0]
        assert 2 <= len(terms) <= 5
        assert len(np.unique(terms)) == len(terms)  # distinct within a query
        assert (dfs[terms] > 0).all()  # never draws empty terms
    # -1 padding is a suffix
    assert all((row[row.argmin():] < 0).all() or (row >= 0).all() for row in q)


def test_zipf_conjunctions_biases_frequent_terms():
    from repro.data.queries import zipf_conjunctions

    dfs = np.arange(1, 501)  # term 499 is the most frequent
    q = zipf_conjunctions(dfs, 400, seed=6)
    drawn = q[q >= 0]
    # the most frequent decile must dominate the draws
    assert (dfs[drawn] > 450).mean() > 0.5


# --------------------------------------------------- engine end-to-end
@pytest.fixture(scope="module")
def tiny_system():
    import jax

    from repro.common.config import CorpusConfig, LearnedIndexConfig
    from repro.core import fit_thresholds, init_membership
    from repro.data.corpus import synthesize_corpus
    from repro.index.build import build_inverted_index

    corpus = synthesize_corpus(CorpusConfig(n_docs=400, n_terms=1600, avg_doc_len=50, seed=31))
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=64)
    params, _ = init_membership(jax.random.key(2), li_cfg, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)  # untrained: zero FN still guaranteed
    return corpus, inv, li_cfg, lb


def test_query_batch_hybrid_vs_raw_agree_exactly(tiny_system):
    """Acceptance (serve path): verified results over the compressed hybrid
    store must equal the raw-store results, and both the brute-force AND."""
    from repro.data.queries import brute_force_answers, sample_queries
    from repro.serve import BooleanEngine, ServeConfig

    corpus, inv, li_cfg, lb = tiny_system
    q = sample_queries(corpus, 24, seed=8)
    hybrid = BooleanEngine(lb, inv, li_cfg,
                           ServeConfig(algorithm="block", verified=True,
                                       postings_store="hybrid"))
    raw = BooleanEngine(lb, inv, li_cfg,
                        ServeConfig(algorithm="block", verified=True,
                                    postings_store="raw"))
    rh = hybrid.query_batch(q)
    rr = raw.query_batch(q)
    exact = brute_force_answers(corpus, q)
    for h, r, e in zip(rh, rr, exact):
        assert np.array_equal(h, r)
        assert np.array_equal(h, e)
    stats = hybrid.serving_stats()
    assert stats["guided"]["probes"] > 0
    assert "decode_cache" in stats


def test_query_batch_guided_vs_unguided_agree(tiny_system):
    from repro.data.queries import sample_queries
    from repro.serve import BooleanEngine, ServeConfig

    corpus, inv, li_cfg, lb = tiny_system
    q = sample_queries(corpus, 16, seed=9)
    guided = BooleanEngine(lb, inv, li_cfg,
                           ServeConfig(verified=True, use_guided=True))
    plain = BooleanEngine(lb, inv, li_cfg,
                          ServeConfig(verified=True, use_guided=False))
    for a, b in zip(guided.query_batch(q), plain.query_batch(q)):
        assert np.array_equal(a, b)
