"""Per-architecture smoke tests (reduced configs, one step, shape+finite
asserts) + model-level invariants (decode==forward, MoE combine weights,
EmbeddingBag vs manual, neighbor sampler)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import ShapeSpec
from repro.configs import ARCH_IDS, get_arch, reduce_config
from repro.launch.steps import build_cell, gnn_graph_dims, skeleton
from repro.models import recsys as rec_mod
from repro.models import sampler as sampler_mod
from repro.models import transformer as tf_mod
from repro.train import init_train_state

rng = np.random.default_rng(11)

SMALL = {
    "train": ShapeSpec(name="train_4k", kind="train", seq_len=32, global_batch=4),
    "prefill": ShapeSpec(name="prefill_32k", kind="prefill", seq_len=32, global_batch=2),
    "decode": ShapeSpec(name="decode_32k", kind="decode", seq_len=64, global_batch=2),
    "gnn": ShapeSpec(name="full_graph_sm", kind="train", n_nodes=60, n_edges=240, d_feat=16),
    "rec_train": ShapeSpec(name="train_batch", kind="train", global_batch=16),
    "rec_serve": ShapeSpec(name="serve_p99", kind="serve", global_batch=8),
    "rec_ret": ShapeSpec(name="retrieval_cand", kind="retrieval", global_batch=1, n_candidates=300),
}


def _concrete(spec, masks_binary=True):
    def mk(path, s):
        name = "/".join(str(getattr(p, "key", "")) for p in path)
        if s.dtype == jnp.int32:
            return jnp.asarray(rng.integers(0, 3, size=s.shape).astype(np.int32))
        if masks_binary and "mask" in name:
            return jnp.ones(s.shape, s.dtype)
        if "label" in name:
            return jnp.asarray(rng.integers(0, 2, size=s.shape)).astype(s.dtype)
        return jnp.asarray(rng.standard_normal(s.shape), dtype=s.dtype)

    return jax.tree_util.tree_map_with_path(mk, spec)


def _cases_for(family):
    if family == "lm":
        return ["train", "prefill", "decode"]
    if family == "gnn":
        return ["gnn"]
    return ["rec_train", "rec_serve", "rec_ret"]


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke(arch_id):
    cfg, shapes, skips = get_arch(arch_id)
    rc = reduce_config(cfg)
    for case in _cases_for(rc.family):
        sh = SMALL[case]
        cell = build_cell(rc, sh)
        params = cell.init_fn(jax.random.key(0))
        # axes tree must mirror the param tree exactly (sharding correctness)
        is_ax = lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
        assert jax.tree.structure(params) == jax.tree.structure(cell.param_axes, is_leaf=is_ax)
        inputs = _concrete(cell.input_specs)
        if cell.kind == "train":
            opt = init_train_state(params, cell.opt_cfg)
            p2, o2, m = jax.jit(cell.step)(params, opt, inputs)
            assert np.isfinite(float(m["loss"]))
        elif cell.kind == "decode":
            lg, _ = jax.jit(cell.step)(params, inputs["token"], inputs["pos"], inputs["caches"])
            assert lg.shape == (sh.global_batch, rc.vocab_size)
            assert np.isfinite(np.asarray(lg)).all()
        elif cell.kind == "prefill":
            lg, caches = jax.jit(cell.step)(params, inputs["tokens"])
            assert lg.shape == (sh.global_batch, rc.vocab_size)
            assert np.isfinite(np.asarray(lg)).all()
        else:
            out = jax.tree.leaves(jax.jit(cell.step)(params, inputs))
            assert all(np.isfinite(np.asarray(a)).all() for a in out)


@pytest.mark.parametrize("arch_id", ["gemma2-2b", "deepseek-v2-lite-16b"])
def test_lm_decode_matches_full_forward(arch_id):
    """Prefill + decode against the cache == full forward (exactness of the
    serving path, incl. local-window ring cache and MLA latent cache)."""
    cfg, _, _ = get_arch(arch_id)
    rc = reduce_config(cfg)
    params = tf_mod.init_lm(jax.random.key(0), rc)[0]
    toks = jnp.asarray(rng.integers(0, rc.vocab_size, (2, 20)).astype(np.int32))
    caches = tf_mod.init_cache(rc, 2, 32, jnp.float32)
    lg_pre, caches = tf_mod.lm_prefill(params, rc, toks, caches, jnp.float32)
    full = tf_mod.lm_logits(params, rc, toks, jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, -1]), atol=2e-4)
    nxt = jnp.asarray(rng.integers(0, rc.vocab_size, (2, 3)).astype(np.int32))
    seq = toks
    for i in range(3):
        pos = jnp.full((2, 1), 20 + i, jnp.int32)
        lg_dec, caches = tf_mod.lm_decode_step(params, rc, nxt[:, i : i + 1], pos, caches, jnp.float32)
        seq = jnp.concatenate([seq, nxt[:, i : i + 1]], axis=1)
        full = tf_mod.lm_logits(params, rc, seq, jnp.float32)
        np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, -1]), atol=2e-4)


def test_moe_combine_weights_sum_to_one():
    from repro.models import moe as moe_mod

    cfg, _, _ = get_arch("deepseek-v2-lite-16b")
    rc = reduce_config(cfg)
    p, _ = moe_mod.init_moe(jax.random.key(0), rc)
    x = jnp.asarray(rng.standard_normal((2, 8, rc.d_model)).astype(np.float32))
    y = moe_mod.moe_ffn(p, rc, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_moe_matches_dense_reference():
    """With capacity ≥ all tokens, grouped dispatch must equal the dense
    per-token expert sum (oracle)."""
    from repro.common.config import ArchConfig
    from repro.models import moe as moe_mod

    cfg = ArchConfig(name="moe-test", d_model=16, n_routed_experts=4, top_k=2,
                     moe_d_ff=8, use_moe=True, moe_aux_free=False, n_shared_experts=0,
                     moe_capacity_factor=1e9)  # dropless: oracle has no drops
    p, _ = moe_mod.init_moe(jax.random.key(1), cfg)
    x = jnp.asarray(rng.standard_normal((2, 6, 16)).astype(np.float32))
    y = moe_mod.moe_ffn(p, cfg, x)

    # dense oracle
    logits = np.einsum("bsd,de->bse", np.asarray(x), np.asarray(p["router"]))
    gate = jax.nn.softmax(jnp.asarray(logits), -1)
    top_w, top_i = jax.lax.top_k(gate, 2)
    top_w = np.asarray(top_w / top_w.sum(-1, keepdims=True))
    top_i = np.asarray(top_i)
    ref = np.zeros_like(np.asarray(x))
    for b in range(2):
        for s in range(6):
            for k in range(2):
                e = top_i[b, s, k]
                h = np.asarray(x)[b, s] @ np.asarray(p["w_gate"])[e]
                u = np.asarray(x)[b, s] @ np.asarray(p["w_up"])[e]
                act = np.asarray(jax.nn.silu(jnp.asarray(h))) * u
                ref[b, s] += top_w[b, s, k] * (act @ np.asarray(p["w_down"])[e])
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-5)


def test_embedding_bag_matches_manual():
    table = jnp.asarray(rng.standard_normal((20, 6)).astype(np.float32))
    idx = jnp.asarray(np.array([[1, 3, -1], [0, -1, -1]], np.int32))
    out = rec_mod.embedding_bag(table, idx, mode="sum")
    t = np.asarray(table)
    np.testing.assert_allclose(np.asarray(out)[0], t[1] + t[3], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out)[1], t[0], rtol=1e-6)
    mean = rec_mod.embedding_bag(table, idx, mode="mean")
    np.testing.assert_allclose(np.asarray(mean)[0], (t[1] + t[3]) / 2, rtol=1e-6)


def test_fm_retrieval_matches_forward():
    """Factorized retrieval must equal brute-force forward with target swapped."""
    cfg, _, _ = get_arch("fm")
    rc = reduce_config(cfg)
    params = rec_mod.init_fm(jax.random.key(2), rc)[0]
    base = rng.integers(0, 5, size=(1, rc.n_sparse)).astype(np.int32)
    cands = np.arange(6, dtype=np.int32)
    fast = np.asarray(rec_mod.fm_retrieval(params, rc, {"sparse": jnp.asarray(base)}, jnp.asarray(cands)))
    slow = []
    for c in cands:
        row = base.copy()
        row[0, 0] = c
        slow.append(float(rec_mod.fm_forward(params, rc, {"sparse": jnp.asarray(row)})[0]))
    np.testing.assert_allclose(fast, np.array(slow), rtol=1e-4, atol=1e-5)


def test_mind_retrieval_matches_forward():
    cfg, _, _ = get_arch("mind")
    rc = reduce_config(cfg)
    params = rec_mod.init_mind(jax.random.key(3), rc)[0]
    hist = rng.integers(0, 50, size=(1, rc.hist_len)).astype(np.int32)
    cands = np.arange(8, dtype=np.int32)
    fast = np.asarray(rec_mod.mind_retrieval(params, rc, {"hist": jnp.asarray(hist)}, jnp.asarray(cands)))
    slow = np.asarray(rec_mod.mind_forward(
        params, rc,
        {"hist": jnp.asarray(np.repeat(hist, 8, 0)), "target": jnp.asarray(cands)},
    ))
    np.testing.assert_allclose(fast, slow, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- sampler
def test_neighbor_sampler_budget_and_validity():
    g = sampler_mod.CSRGraph.random(500, avg_degree=8, seed=3)
    max_n, max_e = sampler_mod.subgraph_budget(16, (5, 3))
    sub = sampler_mod.sample_subgraph(
        g, np.arange(16), (5, 3), max_nodes=max_n, max_edges=max_e,
        rng=np.random.default_rng(0),
    )
    n_valid = int(sub["node_mask"].sum())
    e_valid = int(sub["edge_mask"].sum())
    assert 16 <= n_valid <= max_n
    assert e_valid <= max_e
    # all edges reference in-subgraph local node ids
    assert sub["senders"][:e_valid].max() < n_valid
    assert sub["receivers"][:e_valid].max() < n_valid
    # every sampled edge exists in the original graph
    for s_, r_ in zip(sub["senders"][:10], sub["receivers"][:10]):
        if sub["edge_mask"][0] == 0:
            break
        src_global = sub["node_ids"][s_]
        dst_global = sub["node_ids"][r_]
        assert src_global in g.neighbors(int(dst_global))


@given(st.integers(2, 64), st.tuples(st.integers(1, 6), st.integers(1, 6)))
@settings(max_examples=10, deadline=None)
def test_subgraph_budget_formula(seeds, fanout):
    n, e = sampler_mod.subgraph_budget(seeds, fanout)
    assert n == seeds * (1 + fanout[0] + fanout[0] * fanout[1])
    assert e == seeds * (fanout[0] + fanout[0] * fanout[1])
