"""Doc-partitioned serving + persistent store tests.

Covers the acceptance edges of the planner/executor refactor: shard-range
geometry (boundary docs, empty shards, 32-word alignment), K=1 exact
agreement with brute force and bit-identity across K, store round-trip
bit-exactness per codec, planner liveness/skip logic, empty query batches,
and per-shard stats aggregation.
"""
import jax
import numpy as np
import pytest

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import fit_thresholds, init_membership
from repro.data.corpus import synthesize_corpus
from repro.data.queries import brute_force_answers, sample_queries, zipf_conjunctions
from repro.index.build import InvertedIndex, build_inverted_index, slice_index
from repro.index.store import load_index, load_sharded, save_index, save_sharded
from repro.postings import HybridPostings
from repro.serve import BooleanEngine, ServeConfig, plan_batch, shard_ranges
from repro.serve.shard import pack_ids, unpack_row


# ------------------------------------------------------------------ fixtures
@pytest.fixture(scope="module")
def system():
    corpus = synthesize_corpus(CorpusConfig(n_docs=400, n_terms=1600, avg_doc_len=50, seed=31))
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=64)
    params, _ = init_membership(jax.random.key(2), li_cfg, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)  # untrained: zero FN still guaranteed
    return corpus, inv, li_cfg, lb


def _mixed_store(universe=6000):
    """HybridPostings whose terms exercise several codecs."""
    rng = np.random.default_rng(7)
    lists = [
        np.arange(100, 1700, 4, dtype=np.int32),  # arithmetic run: learned wins
        (np.arange(300) * 17 + rng.integers(0, 4, 300)).astype(np.int32),  # smooth
        np.sort(rng.choice(universe, 60, replace=False)).astype(np.int32),  # rough
        np.sort(rng.choice(universe, 5000, replace=False)).astype(np.int32),  # dense
        np.array([5, 900], np.int32),  # tiny
        np.zeros(0, np.int32),  # empty term
    ]
    lists = [np.unique(x) for x in lists]
    offsets = np.zeros(len(lists) + 1, np.int64)
    np.cumsum([len(x) for x in lists], out=offsets[1:])
    doc_ids = np.concatenate(lists).astype(np.int32)
    inv = InvertedIndex(universe, len(lists), offsets, doc_ids)
    return inv, HybridPostings.build(offsets, doc_ids, universe)


# ------------------------------------------------------------------ geometry
def test_shard_ranges_cover_and_align():
    for n_docs, k in [(400, 1), (400, 4), (4096, 8), (1000, 3), (31, 2)]:
        r = shard_ranges(n_docs, k)
        assert len(r) == k
        assert r[0][0] == 0 and r[-1][1] == n_docs
        for (a, b), (c, d) in zip(r, r[1:]):
            assert b == c and a <= b  # contiguous, monotone
        for lo, hi in r[:-1]:
            assert hi % 32 == 0  # interior boundaries word-aligned


def test_shard_ranges_small_collection_empty_shards():
    r = shard_ranges(40, 8)
    assert sum(hi - lo for lo, hi in r) == 40
    assert any(lo == hi for lo, hi in r)  # tiny collection: some shards empty


def test_slice_index_boundaries():
    inv, _ = _mixed_store()
    lo, hi = 96, 1696
    sl = slice_index(inv, lo, hi)
    assert sl.n_docs == hi - lo
    for t in range(inv.n_terms):
        p = inv.postings(t)
        expect = p[(p >= lo) & (p < hi)] - lo
        assert np.array_equal(sl.postings(t), expect)
    # identity slice preserves everything
    ident = slice_index(inv, 0, inv.n_docs)
    assert np.array_equal(ident.doc_ids, inv.doc_ids)
    assert np.array_equal(ident.term_offsets, inv.term_offsets)


def test_pack_unpack_round_trip_boundary_bits():
    n = 100
    ids = np.array([0, 31, 32, 63, 64, 99], np.int32)  # word-boundary docs
    assert np.array_equal(unpack_row(pack_ids(ids, n), n), ids)
    assert np.array_equal(unpack_row(pack_ids(np.zeros(0, np.int32), n), n),
                          np.zeros(0, np.int32))


# ------------------------------------------------------------------- serving
def test_k1_exact_and_all_k_bit_identical(system):
    corpus, inv, li_cfg, lb = system
    q = np.vstack([sample_queries(corpus, 12, seed=8),
                   zipf_conjunctions(inv.dfs, 8, seed=3)[:, :5]])
    exact = brute_force_answers(corpus, q)
    ref = None
    for k in (1, 2, 4, 8):
        eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=k))
        res = eng.query_batch(q)
        if k == 1:
            ref = res
            for r, e in zip(res, exact):
                assert np.array_equal(r, e)  # K=1 ≡ unsharded engine ≡ exact
        else:
            for r, e in zip(res, ref):
                assert np.array_equal(r, e)  # sharded results bit-identical
        bm = eng.query_batch_bitmap(q)
        for i in range(len(q)):
            assert np.array_equal(unpack_row(bm[i], eng.n_docs), res[i])


def test_boundary_docs_served_exactly(system):
    """Docs sitting exactly on shard boundaries survive the bitmap merge."""
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=4))
    boundary_docs = {lo for lo, hi in eng._ranges} | {hi - 1 for lo, hi in eng._ranges if hi > lo}
    # single-term queries whose postings include boundary docs
    hits = []
    for t in range(inv.n_terms):
        if set(inv.postings(t).tolist()) & boundary_docs:
            hits.append(t)
        if len(hits) >= 8:
            break
    assert hits, "no term touches a shard boundary doc"
    q = np.full((len(hits), 1), -1, np.int32)
    q[:, 0] = hits
    res = eng.query_batch(q)
    for t, r in zip(hits, res):
        assert np.array_equal(r, inv.postings(t))  # boundary docs included


def test_raw_store_sharded_agrees(system):
    corpus, inv, li_cfg, lb = system
    q = sample_queries(corpus, 10, seed=5)
    raw = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=3, postings_store="raw"))
    hyb = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=3))
    for a, b in zip(raw.query_batch(q), hyb.query_batch(q)):
        assert np.array_equal(a, b)


def test_empty_query_batches(system):
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=2))
    assert eng.query_batch(np.zeros((0, 5), np.int32)) == []
    assert eng.query_batch_bitmap(np.zeros((0, 5), np.int32)).shape[0] == 0
    allpad = np.full((3, 5), -1, np.int32)
    res = eng.query_batch(allpad)
    assert all(len(r) == 0 for r in res)
    assert not eng.query_batch_bitmap(allpad).any()
    s = eng.serving_stats()["summary"]
    assert s["probe_bytes"] == 0 and s["cache_misses"] == 0  # probe path untouched


def test_mixed_padding_and_dead_terms(system):
    """All-pad rows and zero-df terms inside a live batch stay empty."""
    corpus, inv, li_cfg, lb = system
    dead = int(np.nonzero(inv.dfs == 0)[0][0]) if (inv.dfs == 0).any() else None
    live = int(np.argmax(inv.dfs))
    rows = [[live, -1], [-1, -1]]
    if dead is not None:
        rows.append([live, dead])
    q = np.asarray(rows, np.int32)
    res = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=2)).query_batch(q)
    assert np.array_equal(res[0], inv.postings(live))
    assert len(res[1]) == 0
    if dead is not None:
        assert len(res[2]) == 0


def test_serving_stats_aggregation(system):
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=4))
    eng.query_batch(zipf_conjunctions(inv.dfs, 8, seed=11))
    stats = eng.serving_stats()
    assert len(stats["shards"]) == len(eng.shards)
    for key in ("hits", "misses", "evictions"):
        assert stats["decode_cache"][key] == sum(
            s["decode_cache"][key] for s in stats["shards"]
        )
    if "guided" in stats:
        assert stats["guided"]["probes"] == sum(
            s["guided"]["probes"] for s in stats["shards"] if "guided" in s
        )
    summary = stats["summary"]
    assert summary["cache_hits"] == stats["decode_cache"]["hits"]
    assert summary["n_shards"] == len(eng.shards)
    assert summary["probe_bytes"] >= 0


def test_planner_skips_shards_missing_terms(system):
    """A shard where some query term has zero local df must not run it."""
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=4))
    shards = eng.shards
    # find a term present on shard 0 but absent on some other shard
    target = None
    for t in np.argsort(-inv.dfs)[:400]:
        t = int(t)
        present = [int(sh.local_dfs[t]) > 0 for sh in shards]
        if present[0] and not all(present):
            target = t
            break
    if target is None:
        pytest.skip("synthetic corpus too dense: every term on every shard")
    q = np.array([[target]], np.int32)
    plan = plan_batch(eng._padded(q), inv.dfs, shards)
    for sh, sp in zip(shards, plan.shard_plans):
        assert sp.run[0] == (int(sh.local_dfs[target]) > 0)
    res = eng.query_batch(q)
    assert np.array_equal(res[0], inv.postings(target))


def test_planner_orders_terms_by_global_df(system):
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=2))
    q = zipf_conjunctions(inv.dfs, 4, seed=13)
    plan = plan_batch(eng._padded(q), inv.dfs, eng.shards)
    for qp in plan.qplans:
        dfs = [int(inv.dfs[t]) for t in qp.terms]
        assert dfs == sorted(dfs)


# -------------------------------------------------------------------- store
def test_store_round_trip_bit_exact_per_codec(tmp_path):
    inv, store = _mixed_store()
    assert len(store.codec_histogram()) >= 2  # several codecs exercised
    save_index(str(tmp_path / "idx"), inv, store)
    inv2, store2 = load_index(str(tmp_path / "idx"), verify=True)
    assert inv2.n_docs == inv.n_docs and inv2.n_terms == inv.n_terms
    assert np.array_equal(np.asarray(inv2.doc_ids), inv.doc_ids)
    assert np.array_equal(np.asarray(store2.tags), store.tags)
    assert np.array_equal(np.asarray(store2.bits), store.bits)
    for t in range(inv.n_terms):
        assert np.array_equal(np.asarray(store2.streams[t]), store.streams[t])
        assert np.array_equal(store2.postings(t), store.postings(t))  # bit-exact decode
    assert store2.size_bits() == store.size_bits()


def test_store_version_and_corruption_guards(tmp_path):
    import json

    inv, store = _mixed_store()
    p = tmp_path / "idx"
    save_index(str(p), inv, store)
    meta = json.loads((p / "meta.json").read_text())
    meta["version"] = 999
    (p / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="version"):
        load_index(str(p))
    with pytest.raises(FileNotFoundError):
        load_index(str(tmp_path / "nope"))


def test_sharded_store_round_trip_with_empty_shard(tmp_path):
    inv, store = _mixed_store()
    ranges = [(0, 2016), (2016, 2016), (2016, 6000)]  # middle shard empty
    entries = []
    for lo, hi in ranges:
        sl = slice_index(inv, lo, hi)
        entries.append(((lo, hi), sl, HybridPostings.from_index(sl)))
    save_sharded(str(tmp_path / "sh"), inv.n_docs, entries)
    n_docs, loaded = load_sharded(str(tmp_path / "sh"))
    assert n_docs == inv.n_docs
    assert loaded[1][1] is None and loaded[1][2] is None  # empty shard
    for ((lo, hi), linv, lstore), (_, orig_inv, orig_store) in zip(loaded, entries):
        if linv is None:
            continue
        for t in range(orig_inv.n_terms):
            assert np.array_equal(np.asarray(linv.postings(t)), orig_inv.postings(t))
            assert np.array_equal(lstore.postings(t), orig_store.postings(t))


def test_engine_save_reload_identical_results(system, tmp_path):
    corpus, inv, li_cfg, lb = system
    cfg = ServeConfig(n_shards=4)
    eng = BooleanEngine(lb, inv, li_cfg, cfg)
    q = sample_queries(corpus, 12, seed=21)
    ref = eng.query_batch(q)
    eng.save(str(tmp_path / "idx"))
    loaded = BooleanEngine.from_store(lb, li_cfg, cfg, str(tmp_path / "idx"))
    for a, b in zip(loaded.query_batch(q), ref):
        assert np.array_equal(a, b)
    # reloaded stores must be byte-identical to the built ones, per shard
    for sh_new, sh_old in zip(loaded.shards, eng.shards):
        assert np.array_equal(np.asarray(sh_new.tier2.tags), np.asarray(sh_old.tier2.tags))
        assert sh_new.tier2.size_bits() == sh_old.tier2.size_bits()
