"""Index substrate: corpus synthesis, codecs, truncation, block lists."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import CorpusConfig
from repro.data.corpus import document_frequencies, synthesize_corpus, zipf_mandelbrot_probs
from repro.index.build import block_lists, build_inverted_index, truncate_index
from repro.index.compress import (
    compressed_size_bits,
    decode_postings,
    dgaps,
    encode_postings,
    optpfd_size_bits,
    pack_bits,
    undgaps,
    unpack_bits,
    varbyte_size_bits,
)


@pytest.fixture(scope="module")
def corpus():
    return synthesize_corpus(CorpusConfig(n_docs=600, n_terms=3000, avg_doc_len=50, seed=1))


@pytest.fixture(scope="module")
def inv(corpus):
    return build_inverted_index(corpus)


def test_corpus_structure(corpus):
    assert corpus.doc_offsets[0] == 0
    assert corpus.doc_offsets[-1] == corpus.n_postings
    # per-doc term lists sorted + unique
    for d in range(0, corpus.n_docs, 97):
        terms = corpus.doc_terms(d)
        assert (np.diff(terms) > 0).all()


def test_zipf_probs_normalized():
    p = zipf_mandelbrot_probs(1000, 1.2, 2.7)
    assert abs(p.sum() - 1.0) < 1e-9
    assert (np.diff(p) <= 0).all()  # monotone decreasing in rank


def test_inverted_index_is_exact_transpose(corpus, inv):
    assert inv.n_postings == corpus.n_postings
    rng = np.random.default_rng(0)
    for d in rng.integers(0, corpus.n_docs, 30):
        for t in corpus.doc_terms(int(d))[:5]:
            assert int(d) in inv.postings(int(t))


def test_postings_sorted_unique(inv):
    for t in np.nonzero(inv.dfs > 1)[0][:50]:
        p = inv.postings(int(t))
        assert (np.diff(p) > 0).all()


@given(st.lists(st.integers(0, 2**27), min_size=1, max_size=400, unique=True))
@settings(max_examples=30, deadline=None)
def test_codec_roundtrip(ids):
    docs = np.sort(np.array(ids, dtype=np.int32))
    for codec in ("optpfd", "varbyte"):
        enc = encode_postings(docs, codec)
        dec = decode_postings(enc, len(docs), codec)
        assert np.array_equal(dec, docs), codec


@given(st.integers(1, 32), st.integers(1, 500))
@settings(max_examples=30, deadline=None)
def test_pack_unpack_roundtrip(width, n):
    rng = np.random.default_rng(width * 1000 + n)
    hi = 2**width if width < 32 else 2**32
    vals = rng.integers(0, hi, size=n, dtype=np.uint64).astype(np.uint32)
    assert np.array_equal(unpack_bits(pack_bits(vals, width), width, n), vals)


def test_size_models_are_bit_exact_for_encoders(inv):
    rng = np.random.default_rng(3)
    for t in rng.choice(np.nonzero(inv.dfs > 4)[0], 20):
        g = dgaps(inv.postings(int(t)))
        # size model counts exact bits; encoder pads to u32 words
        assert optpfd_size_bits(g) <= encode_postings(undgaps(g)).size * 32 + 31


def test_optpfd_beats_raw(inv):
    sizes = [compressed_size_bits(inv.postings(int(t)), inv.n_docs, "optpfd")
             for t in np.nonzero(inv.dfs > 16)[0][:30]]
    raws = [32 * int(inv.dfs[t]) for t in np.nonzero(inv.dfs > 16)[0][:30]]
    assert sum(sizes) < sum(raws)


def test_truncate_index(inv):
    tr = truncate_index(inv, 7)
    assert (tr.dfs <= 7).all()
    assert (tr.dfs == np.minimum(inv.dfs, 7)).all()
    for t in np.nonzero(inv.dfs > 7)[0][:10]:
        assert np.array_equal(tr.postings(int(t)), inv.postings(int(t))[:7])


def test_block_lists_bits(inv):
    bm, n_blocks = block_lists(inv, 64)
    assert n_blocks == -(-inv.n_docs // 64)
    rng = np.random.default_rng(5)
    for t in rng.choice(np.nonzero(inv.dfs > 0)[0], 20):
        blocks = set((inv.postings(int(t)) // 64).tolist())
        for b in range(n_blocks):
            bit = bool((bm[t, b // 32] >> np.uint32(b % 32)) & 1)
            assert bit == (b in blocks)
