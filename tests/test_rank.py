"""Ranked top-k retrieval: payloads, scoring, MaxScore pruning, sharded merge.

The load-bearing property is *bit-exactness*: `query_topk` must reproduce
the brute-force quantized-BM25 oracle — ids and integer scores — for every
shard count, query mode, pruning configuration, and the persistent-store
round trip.  Scores are integer impact sums with ties broken by ascending
doc id, so equality here is array equality, not allclose.
"""
import json
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import fit_thresholds, init_membership
from repro.data.corpus import synthesize_corpus
from repro.data.queries import zipf_disjunctions
from repro.index.build import build_inverted_index, slice_index
from repro.index.store import UnsupportedVersionError, load_index, save_index
from repro.postings.hybrid import HybridPostings
from repro.rank.score import BM25Params, ImpactModel, brute_force_topk, select_topk
from repro.rank.topk import topk_query
from repro.serve import BooleanEngine, ServeConfig, plan_ranked, ranked_run_mask

K = 10


@pytest.fixture(scope="module")
def system():
    corpus = synthesize_corpus(
        CorpusConfig(n_docs=800, n_terms=3000, avg_doc_len=50, seed=11)
    )
    inv = build_inverted_index(corpus)
    li = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=128)
    params, _ = init_membership(jax.random.key(0), li, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)
    im = ImpactModel.build(inv, BM25Params())
    return corpus, inv, li, lb, im


@pytest.fixture(scope="module")
def queries(system):
    _, inv, _, _, _ = system
    q, _ = zipf_disjunctions(inv.dfs, 24, seed=5)
    return q


# ---------------------------------------------------------------- payloads
def test_corpus_carries_tfs(system):
    corpus, inv, *_ = system
    assert corpus.term_freqs is not None and corpus.term_freqs.min() >= 1
    assert inv.tfs is not None and len(inv.tfs) == inv.n_postings
    # tf of a (term, doc) posting matches the corpus multiplicity
    t = int(np.argmax(inv.dfs))
    assert np.array_equal(np.sort(inv.postings(t)), inv.postings(t))
    assert len(inv.term_tfs(t)) == int(inv.dfs[t])


def test_slice_index_carries_tfs(system):
    _, inv, *_ = system
    sl = slice_index(inv, 32, 416)
    sel = (inv.doc_ids >= 32) & (inv.doc_ids < 416)
    assert np.array_equal(sl.tfs, inv.tfs[sel])


def test_quantization_range_and_determinism(system):
    _, inv, _, _, im = system
    q = im.quantize_index(inv)
    assert q.min() >= 1 and q.max() == im.max_quant
    # shard slice of the global payload stream == locally quantized slice
    sl = slice_index(inv, 96, 512)
    local = im.quantize_index(sl, lo=96)
    term_of = np.repeat(np.arange(inv.n_terms), inv.dfs)
    sel = (inv.doc_ids >= 96) & (inv.doc_ids < 512)
    assert np.array_equal(local, q[sel]), "shard quantization must be a slice"
    del term_of


def test_payload_streams_roundtrip(system):
    _, inv, _, _, im = system
    store = HybridPostings.from_index(inv)
    quants = im.quantize_index(inv)
    store.attach_payloads(quants, bits=im.params.bits, scale=im.scale)
    offs = np.zeros(inv.n_terms + 1, np.int64)
    np.cumsum(store.lens, out=offs[1:])
    rng = np.random.default_rng(0)
    for t in rng.choice(inv.n_terms, 60, replace=False):
        t = int(t)
        n = int(store.lens[t])
        expect = quants[offs[t] : offs[t + 1]]
        if n == 0:
            continue
        assert np.array_equal(store.payloads(t), expect)
        ranks = rng.integers(0, n, size=min(8, n))
        assert np.array_equal(store.payload_at(t, ranks), expect[ranks])
        assert store.term_ub(t) == int(expect.max())
        # segment bounds are true maxima over their rank ranges
        subs = store.term_seg_ubs(t)
        assert subs.max() == expect.max()
        assert all(int(u) <= store.term_ub(t) for u in subs)


def test_attach_payloads_validates(system):
    _, inv, *_ = system
    store = HybridPostings.from_index(inv)
    with pytest.raises(ValueError):
        store.attach_payloads(np.ones(3, np.uint32), bits=8, scale=1.0)
    with pytest.raises(ValueError):
        store.attach_payloads(
            np.full(inv.n_postings, 256, np.uint32), bits=8, scale=1.0
        )
    with pytest.raises(ValueError):
        store.payloads(0)  # nothing attached yet


# ---------------------------------------------------------------- planner
def test_plan_ranked_modes(system):
    _, inv, *_ = system
    zero_df = int(np.nonzero(inv.dfs == 0)[0][0])
    live = np.nonzero(inv.dfs > 0)[0][:3].astype(np.int32)
    q = np.array([
        [live[0], live[1], live[1], -1],  # dupes collapse
        [zero_df, live[2], -1, -1],  # dead term drops
        [-1, -1, -1, -1],  # all padding
        [zero_df, -1, -1, -1],  # nothing live
    ], np.int32)
    plans = plan_ranked(q, inv.dfs, mode="or")
    assert plans[0].terms == tuple(sorted((int(live[0]), int(live[1]))))
    assert plans[1].terms == (int(live[2]),) and not plans[1].dead
    assert plans[2].dead and plans[3].dead
    # AND: a zero-df term kills the query
    plans = plan_ranked(q, inv.dfs, mode="and")
    assert plans[1].dead
    assert plans[0].required == plans[0].terms
    # mixed via required mask
    req = np.zeros(q.shape, bool)
    req[0, 0] = True
    plans = plan_ranked(q, inv.dfs, required=req)
    assert plans[0].required == (int(live[0]),)
    with pytest.raises(ValueError):
        plan_ranked(q, inv.dfs, mode="nope")


def test_ranked_run_mask_skips_locally_absent(system):
    _, inv, *_ = system
    live = np.nonzero(inv.dfs > 0)[0][:2].astype(np.int32)
    q = np.array([[live[0], live[1], -1, -1]], np.int32)
    plans = plan_ranked(q, inv.dfs, mode="and")
    local = inv.dfs.copy()
    local[live[0]] = 0  # required term absent on this "shard"
    assert not ranked_run_mask(plans, local)[0]
    plans = plan_ranked(q, inv.dfs, mode="or")
    assert ranked_run_mask(plans, local)[0]  # other term still scores
    local[live[1]] = 0
    assert not ranked_run_mask(plans, local)[0]


# ---------------------------------------------------------------- exactness
def _check(results, oracle):
    for r, e in zip(results, oracle):
        assert np.array_equal(r.ids, e.ids), (r.ids, e.ids)
        assert np.array_equal(r.scores, e.scores)


@pytest.mark.parametrize("n_shards", [1, 4])
def test_topk_exact_vs_brute_force(system, queries, n_shards):
    _, inv, li, lb, im = system
    oracle = brute_force_topk(inv, im, queries, K)
    eng = BooleanEngine(
        lb, inv, li, ServeConfig(n_shards=n_shards, ranked=dict(topk_exhaustive_cutoff=64))
    )
    _check(eng.query_topk(queries, K), oracle)
    stats = eng.serving_stats()["ranked"]
    assert stats["touched_postings"] < stats["exhaustive_postings"]


def test_topk_k1_matches_k4_bitwise(system, queries):
    _, inv, li, lb, im = system
    cfg = dict(topk_exhaustive_cutoff=64)
    r1 = BooleanEngine(lb, inv, li, ServeConfig(n_shards=1, **cfg)).query_topk(queries, K)
    r4 = BooleanEngine(lb, inv, li, ServeConfig(n_shards=4, **cfg)).query_topk(queries, K)
    _check(r1, r4)


@pytest.mark.parametrize("k", [1, 3])
def test_topk_small_k(system, queries, k):
    _, inv, li, lb, im = system
    oracle = brute_force_topk(inv, im, queries, k)
    eng = BooleanEngine(lb, inv, li, ServeConfig(n_shards=4, ranked=dict(topk_exhaustive_cutoff=0)))
    _check(eng.query_topk(queries, k), oracle)


def test_topk_conjunctive_and_mixed(system, queries):
    _, inv, li, lb, im = system
    eng = BooleanEngine(lb, inv, li, ServeConfig(n_shards=4))
    _check(eng.query_topk(queries, K, mode="and"),
           brute_force_topk(inv, im, queries, K, mode="and"))
    q2, req = zipf_disjunctions(inv.dfs, 16, n_required=1, seed=7)
    _check(eng.query_topk(q2, K, required=req),
           brute_force_topk(inv, im, q2, K, required=req))


def test_topk_pruned_equals_exhaustive(system, queries):
    _, inv, li, lb, _ = system
    pruned = BooleanEngine(
        lb, inv, li, ServeConfig(n_shards=1, ranked=dict(topk_exhaustive_cutoff=0))
    )
    exhaustive = BooleanEngine(
        lb, inv, li, ServeConfig(n_shards=1, ranked=dict(topk_exhaustive_cutoff=1 << 30))
    )
    _check(pruned.query_topk(queries, K), exhaustive.query_topk(queries, K))
    ps = pruned.serving_stats()["ranked"]
    es = exhaustive.serving_stats()["ranked"]
    assert es["exhaustive_queries"] == es["queries"]
    assert ps["touched_postings"] < es["touched_postings"]


def test_topk_score_kernel_path(system, queries):
    _, inv, li, lb, im = system
    oracle = brute_force_topk(inv, im, queries, K)
    eng = BooleanEngine(
        lb, inv, li,
        ServeConfig(n_shards=1, ranked=dict(score_kernel=True, topk_exhaustive_cutoff=1 << 30)),
    )
    _check(eng.query_topk(queries, K), oracle)


def test_topk_ties_break_by_doc_id():
    """Handmade source where every doc scores identically: top-k must be the
    k smallest doc ids, under pruning and under floors."""

    class Flat:
        ids = np.arange(0, 400, 2, np.int32)

        def n(self, t):
            return len(self.ids)

        def ub(self, t):
            return 7

        def full(self, t):
            return self.ids, np.full(len(self.ids), 7, np.int64)

        def probe(self, t, cands):
            found = np.isin(cands, self.ids)
            return found, np.where(found, 7, 0).astype(np.int64)

        def seg_ub(self, t, cands):
            return np.full(len(cands), 7, np.int64)

    src = Flat()
    ans = topk_query(src, [0, 1], 5, exhaustive_cutoff=0)
    assert np.array_equal(ans.ids, src.ids[:5])
    assert np.array_equal(ans.scores, np.full(5, 14, np.int64))
    # floor equal to the tied score excludes everything (later shards lose ties)
    ans = topk_query(src, [0, 1], 5, floor=14, exhaustive_cutoff=0)
    assert len(ans.ids) == 0
    ans = topk_query(src, [0, 1], 5, floor=13, exhaustive_cutoff=0)
    assert np.array_equal(ans.ids, src.ids[:5])


def test_select_topk_ordering():
    ids = np.array([5, 3, 9, 1], np.int32)
    scores = np.array([4, 7, 7, 2], np.int64)
    ans = select_topk(ids, scores, 3)
    assert ans.ids.tolist() == [3, 9, 5]  # ties ascending id
    assert ans.scores.tolist() == [7, 7, 4]
    assert select_topk(ids, scores, 3, floor=7).ids.tolist() == []


def test_ranked_stats_accounting(system, queries):
    _, inv, li, lb, _ = system
    eng = BooleanEngine(lb, inv, li, ServeConfig(n_shards=1, ranked=dict(topk_exhaustive_cutoff=0)))
    eng.query_topk(queries[:4], K)
    s = eng.serving_stats()
    assert s["ranked"]["queries"] == 4
    assert s["ranked"]["shard_queries"] == 4  # K=1: pairs == queries
    assert s["summary"]["scored_fraction"] == s["ranked"]["scored_fraction"]
    eng.reset_stats()
    assert "ranked" not in eng.serving_stats()
    # K>1: 'queries' stays the facade count; shard pairs may exceed it
    eng4 = BooleanEngine(lb, inv, li, ServeConfig(n_shards=4, ranked=dict(topk_exhaustive_cutoff=0)))
    eng4.query_topk(queries[:4], K)
    s4 = eng4.serving_stats()["ranked"]
    assert s4["queries"] == 4
    assert s4["shard_queries"] >= s4["queries"]


def test_memory_report_includes_payloads(system):
    _, inv, li, lb, _ = system
    eng = BooleanEngine(lb, inv, li, ServeConfig(n_shards=1))
    eng.query_topk(np.array([[0, 1, -1]], np.int32), 3)
    report = eng.memory_report()
    assert report.get("payload_bits", 0) > 0


def test_topk_without_tfs_raises(system):
    _, inv, li, lb, _ = system
    from dataclasses import replace

    no_tf = replace(inv, tfs=None)
    eng = BooleanEngine(lb, no_tf, li, ServeConfig(n_shards=1))
    with pytest.raises(ValueError, match="payload"):
        eng.query_topk(np.array([[0, 1, -1]], np.int32), 3)


# ---------------------------------------------------------------- kernel
def test_bm25_kernel_bit_exact():
    from repro.kernels.bm25_score.ops import score_candidates
    from repro.kernels.bm25_score.ref import score_ref

    rng = np.random.default_rng(3)
    for P, T in [(1, 1), (7, 3), (64, 8), (33, 5)]:
        imp = rng.integers(0, 256, (P, T)).astype(np.int32)
        scale = float(rng.uniform(0.001, 0.1))
        ki, kf = score_candidates(imp, scale)
        ri, rf = score_ref(imp, scale)
        assert np.array_equal(ki, ri)
        assert np.array_equal(kf.view(np.int32), rf.view(np.int32))
    ki, kf = score_candidates(np.zeros((0, 4), np.int32), 0.5)
    assert len(ki) == 0 and len(kf) == 0


# ---------------------------------------------------------------- store v2
def test_store_roundtrip_with_payloads(system, queries):
    _, inv, li, lb, im = system
    cfg = ServeConfig(n_shards=4, ranked=dict(topk_exhaustive_cutoff=64))
    eng = BooleanEngine(lb, inv, li, cfg)
    oracle = brute_force_topk(inv, im, queries, K)
    with tempfile.TemporaryDirectory() as d:
        eng.save(d)
        loaded = BooleanEngine.from_store(lb, li, cfg, d)
        _check(loaded.query_topk(queries, K), oracle)
        store = loaded.shards[0].tier2
        assert store.has_payloads and store.payload_bits == 8
        assert store.payload_scale == pytest.approx(im.scale)


def test_store_newer_version_raises(system):
    _, inv, *_ = system
    store = HybridPostings.from_index(inv)
    with tempfile.TemporaryDirectory() as d:
        save_index(d, inv, store)
        meta_path = os.path.join(d, "meta.json")
        with open(meta_path) as f:
            meta = json.load(f)
        meta["version"] = 99
        with open(meta_path, "w") as f:
            json.dump(meta, f)
        with pytest.raises(UnsupportedVersionError, match="newer repro"):
            load_index(d)
        # and an UnsupportedVersionError is still a ValueError for old callers
        with pytest.raises(ValueError):
            load_index(d)


def test_store_v1_layout_still_loads(system):
    """A v1 directory (no payload arrays in the manifest) loads Boolean-only."""
    _, inv, *_ = system
    store = HybridPostings.from_index(inv)
    with tempfile.TemporaryDirectory() as d:
        save_index(d, inv, store)
        meta_path = os.path.join(d, "meta.json")
        with open(meta_path) as f:
            meta = json.load(f)
        meta["version"] = 1
        for name in ("tfs", "payload_offsets", "payloads", "ub_offsets", "seg_ubs"):
            del meta["arrays"][name]
            os.unlink(os.path.join(d, f"{name}.bin"))
        del meta["payload_bits"], meta["payload_scale"]
        with open(meta_path, "w") as f:
            json.dump(meta, f)
        inv2, store2 = load_index(d, verify=True)
        assert inv2.tfs is None and not store2.has_payloads
        t = int(np.argmax(inv.dfs))
        assert np.array_equal(store2.postings(t), store.postings(t))


# ---------------------------------------------------------------- queries
def test_zipf_disjunctions_shapes(system):
    _, inv, *_ = system
    q, req = zipf_disjunctions(inv.dfs, 32, min_terms=2, max_terms=6, seed=1)
    assert q.shape == (32, 6) and req.shape == q.shape
    assert not req.any()
    lens = (q >= 0).sum(axis=1)
    assert lens.min() >= 2 and lens.max() <= 6
    for row in q:
        terms = row[row >= 0]
        assert len(np.unique(terms)) == len(terms)
        assert (inv.dfs[terms] > 0).all()
    q2, req2 = zipf_disjunctions(inv.dfs, 8, n_required=2, seed=2)
    assert (req2[:, :2] == (q2[:, :2] >= 0)).all()
    assert not req2[:, 2:].any()
