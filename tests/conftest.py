"""Test bootstrap: provide a minimal `hypothesis` fallback when the real
package is absent (the CI/container image may not ship it).

The shim implements just the subset this suite uses — `given`, `settings`,
and `strategies.{integers,lists,tuples}` — by drawing a deterministic batch
of pseudo-random examples per test. It is NOT a replacement for hypothesis
(no shrinking, no database); when the real package is installed it is used
untouched.
"""
from __future__ import annotations

import sys
import types
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng) -> value

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _lists(elem, min_size=0, max_size=None, unique=False):
        cap = 50 if max_size is None else max_size

        def draw(rng):
            n = int(rng.integers(min_size, cap + 1))
            if not unique:
                return [elem.draw(rng) for _ in range(n)]
            vals, attempts = set(), 0
            while len(vals) < n and attempts < 50 * (n + 1):
                vals.add(elem.draw(rng))
                attempts += 1
            return list(vals)

        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def _settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def _given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_shim_max_examples", None) or getattr(
                    wrapper, "_shim_max_examples", 20
                )
                seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = _np.random.default_rng(seed)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.lists = _lists
    _st.tuples = _tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
