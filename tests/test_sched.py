"""Continuous-batching scheduler tests (serve/sched).

Covers the acceptance edges of the scheduler subsystem: legacy-wrapper
bit-parity (inline and through real process workers), queue saturation
shedding lowest-priority first, expired deadlines never reaching a worker,
crash retry-once-then-typed-error (fakes and the real process crash hook),
all-pad short-circuits, tenant quotas, same-mode batch coalescing, and the
ServeConfig legacy-kwarg shim.
"""
import threading
import time
import warnings
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import fit_thresholds, init_membership
from repro.data.corpus import synthesize_corpus
from repro.data.queries import sample_queries, zipf_conjunctions
from repro.index.build import build_inverted_index
from repro.obs.metrics import Registry
from repro.serve import (
    BooleanEngine,
    QueryRequest,
    QueryResult,
    Rejected,
    ServeConfig,
    Session,
)
from repro.serve.config import ObsConfig, RankedConfig, SchedConfig
from repro.serve.sched import (
    MODE_RANKED,
    REJECT_DEADLINE,
    REJECT_QUEUE_FULL,
    REJECT_SHUTDOWN,
    REJECT_TENANT_QUOTA,
    REJECT_WORKER_FAILED,
    AdmissionQueue,
    Pending,
    ProcessReplica,
    ReplicaGroup,
    WorkerFailure,
)
from repro.serve.sched.replica import ReplicaError


# ------------------------------------------------------------------ fixtures
@pytest.fixture(scope="module")
def system():
    corpus = synthesize_corpus(
        CorpusConfig(n_docs=400, n_terms=1600, avg_doc_len=50, seed=31)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=64)
    params, _ = init_membership(jax.random.key(2), li_cfg, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)
    return corpus, inv, li_cfg, lb


def _engine(system, **cfg_kwargs):
    corpus, inv, li_cfg, lb = system
    return BooleanEngine(lb, inv, li_cfg, ServeConfig(**cfg_kwargs))


def _queries(system):
    corpus, inv, *_ = system
    q = sample_queries(corpus, 10, max_terms=4, seed=5)
    rq = zipf_conjunctions(inv.dfs, 8, max_terms=4, seed=9)
    return q, rq


# ------------------------------------------------------- wrapper bit-parity
def test_legacy_wrappers_bit_identical_inline(system):
    eng = _engine(system, n_shards=3)
    q, rq = _queries(system)
    want_bool = eng.query_batch(q)
    want_bm = eng.query_batch_bitmap(q)
    want_or = eng.query_topk(rq, k=10, mode="or")
    want_and = eng.query_topk(rq, k=10, mode="and")
    with Session(eng) as s:
        got_bool = s.query_batch(q)
        got_bm = s.query_batch_bitmap(q)
        got_or = s.query_topk(rq, k=10, mode="or")
        got_and = s.query_topk(rq, k=10, mode="and")
    for a, b in zip(want_bool, got_bool):
        assert np.array_equal(a, b)
    assert got_bm.dtype == np.uint32 and np.array_equal(want_bm, got_bm)
    for a, b in zip(want_or + want_and, got_or + got_and):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.scores, b.scores)


def test_submit_matches_wrapper_and_carries_timing(system):
    eng = _engine(system, n_shards=2)
    q, rq = _queries(system)
    with Session(eng) as s:
        r = s.submit(QueryRequest(terms=q[0]))
        assert isinstance(r, QueryResult) and r.ok
        assert np.array_equal(r.ids, eng.query_batch(q[:1])[0])
        assert r.scores is None and r.service_us > 0
        rr = s.submit(QueryRequest(terms=rq[0], mode=MODE_RANKED, k=5))
        want = eng.query_topk(rq[:1], k=5, mode="or")[0]
        assert np.array_equal(rr.ids, want.ids)
        assert np.array_equal(rr.scores, want.scores)


def test_legacy_wrappers_bit_identical_process_workers(system, tmp_path):
    """The acceptance edge: process replicas plan with global dfs, so the
    parallel path is bit-identical to in-process serving."""
    eng = _engine(system, n_shards=2, sched=dict(n_replicas=1))
    q, rq = _queries(system)
    want_bool = eng.query_batch(q)
    want_or = eng.query_topk(rq, k=10, mode="or")
    want_and = eng.query_topk(rq, k=10, mode="and")
    with Session(eng, store_dir=str(tmp_path)) as s:
        s.warm()
        got_bool = s.query_batch(q)
        got_or = s.query_topk(rq, k=10, mode="or")
        got_and = s.query_topk(rq, k=10, mode="and")
    for a, b in zip(want_bool, got_bool):
        assert np.array_equal(a, b)
    for a, b in zip(want_or + want_and, got_or + got_and):
        assert np.array_equal(a.ids, b.ids)
        assert np.array_equal(a.scores, b.scores)


# --------------------------------------------------------------- fake parts
class RecordingReplica:
    """Answers empty bitmaps / empty heaps; records every dispatch."""

    def __init__(self, n_docs=64):
        self.calls = []
        self.inflight = 0
        self.n_docs = n_docs

    def call(self, msg):
        self.calls.append(msg)
        if msg[0] == "bool":
            words = (self.n_docs + 31) // 32
            return np.zeros((len(msg[1]), words), dtype=np.uint32)
        if msg[0] == "topk":
            return [(np.zeros(0, np.int32), np.zeros(0, np.int64))] * len(msg[1])
        return "pong"

    def close(self):
        pass


class FlakyReplica(RecordingReplica):
    """Raises ReplicaError for the first ``fail_n`` calls, then recovers."""

    def __init__(self, fail_n, **kw):
        super().__init__(**kw)
        self.fail_n = fail_n

    def call(self, msg):
        if len(self.calls) < self.fail_n:
            self.calls.append(msg)
            raise ReplicaError("injected")
        return super().call(msg)


def _fake_session(eng, replica, **sched_kwargs):
    eng.cfg.sched = SchedConfig(**sched_kwargs)
    group = ReplicaGroup(
        0,
        [replica],
        lo=0,
        n_docs=eng.n_docs,
        retries=eng.cfg.sched.worker_retries,
        metrics=eng.metrics,
    )
    return Session(eng, replica_groups=[group], auto_start=False)


# -------------------------------------------------------- admission control
def test_saturation_sheds_lowest_priority_first(system):
    eng = _engine(system, n_shards=1)
    q, _ = _queries(system)
    s = _fake_session(eng, RecordingReplica(), max_queue=2)
    try:
        f_low_old = s.submit_async(QueryRequest(terms=q[0], priority=0, tenant="low"))
        f_low_new = s.submit_async(QueryRequest(terms=q[1], priority=0, tenant="low"))
        # queue full; a higher-priority arrival displaces the YOUNGEST
        # lowest-priority entry, preserving the FIFO head
        f_high = s.submit_async(QueryRequest(terms=q[2], priority=1, tenant="vip"))
        shed = f_low_new.result(timeout=1)
        assert isinstance(shed, Rejected) and shed.reason == REJECT_QUEUE_FULL
        assert shed.tenant == "low"
        assert not f_low_old.done() and not f_high.done()
        # next priority-1 arrival displaces the remaining priority-0 entry
        f_eq = s.submit_async(QueryRequest(terms=q[3], priority=1))
        assert f_low_old.result(timeout=1).reason == REJECT_QUEUE_FULL
        assert not f_eq.done()
        # queue is now all priority 1: an equal-priority arrival is rejected
        # itself — it may not churn the queue
        f_eq2 = s.submit_async(QueryRequest(terms=q[4], priority=1))
        eq2 = f_eq2.result(timeout=1)
        assert isinstance(eq2, Rejected) and eq2.reason == REJECT_QUEUE_FULL
        assert not f_high.done() and not f_eq.done()
        snap = eng.metrics.snapshot()["sched"]
        assert snap["shed"]["queue_full"] == 3
    finally:
        s.close()
    assert f_high.result(timeout=1).reason == REJECT_SHUTDOWN
    assert f_eq.result(timeout=1).reason == REJECT_SHUTDOWN


def test_tenant_quota_caps_queued_requests(system):
    eng = _engine(system, n_shards=1)
    q, _ = _queries(system)
    s = _fake_session(eng, RecordingReplica(), tenant_quota=1, max_queue=16)
    try:
        f1 = s.submit_async(QueryRequest(terms=q[0], tenant="chatty"))
        f2 = s.submit_async(QueryRequest(terms=q[1], tenant="chatty"))
        f3 = s.submit_async(QueryRequest(terms=q[2], tenant="other"))
        over = f2.result(timeout=1)
        assert isinstance(over, Rejected) and over.reason == REJECT_TENANT_QUOTA
        assert over.tenant == "chatty"
        assert not f1.done() and not f3.done()  # quota is per tenant
    finally:
        s.close()


def test_expired_deadline_never_reaches_a_worker(system):
    eng = _engine(system, n_shards=1)
    q, _ = _queries(system)
    replica = RecordingReplica()
    s = _fake_session(eng, replica)
    try:
        f_dead = s.submit_async(QueryRequest(terms=q[0], deadline_ms=1))
        f_live = s.submit_async(QueryRequest(terms=q[1]))
        time.sleep(0.02)  # deadline passes while the scheduler is held
        s._loop_thread.start()
        shed = f_dead.result(timeout=2)
        assert isinstance(shed, Rejected) and shed.reason == REJECT_DEADLINE
        assert f_live.result(timeout=2).ok
        # the expired request was shed at take_batch: no dispatch carried it
        assert all(len(msg[1]) == 1 for msg in replica.calls if msg[0] == "bool")
        assert eng.metrics.snapshot()["sched"]["shed"]["deadline"] == 1
    finally:
        s.close()


def test_default_deadline_from_config(system):
    eng = _engine(system, n_shards=1)
    q, _ = _queries(system)
    s = _fake_session(eng, RecordingReplica(), default_deadline_ms=1)
    try:
        f = s.submit_async(QueryRequest(terms=q[0]))
        time.sleep(0.02)
        s._loop_thread.start()
        assert f.result(timeout=2).reason == REJECT_DEADLINE
    finally:
        s.close()


# ------------------------------------------------------------- crash paths
def test_flaky_replica_retries_once_then_succeeds(system):
    eng = _engine(system, n_shards=1)
    q, _ = _queries(system)
    replica = FlakyReplica(fail_n=1)
    s = _fake_session(eng, replica)
    s._loop_thread.start()
    try:
        assert s.submit(QueryRequest(terms=q[0]), timeout=2).ok
        snap = eng.metrics.snapshot()["sched"]
        assert snap["worker_retries"] == 1
        assert snap["worker_failures"] == 0
    finally:
        s.close()


def test_dead_replica_exhausts_retries_then_typed_rejection(system):
    eng = _engine(system, n_shards=1)
    q, _ = _queries(system)
    s = _fake_session(eng, FlakyReplica(fail_n=10**6))  # never recovers
    s._loop_thread.start()
    try:
        r = s.submit(QueryRequest(terms=q[0]), timeout=2)
        assert isinstance(r, Rejected) and r.reason == REJECT_WORKER_FAILED
        assert eng.metrics.snapshot()["sched"]["worker_failures"] == 1
    finally:
        s.close()


def test_replica_group_prefers_sibling_on_retry():
    bad, good = FlakyReplica(fail_n=10**6), RecordingReplica()
    good.inflight = 5  # least-loaded picks `bad` first...
    group = ReplicaGroup(0, [bad, good], retries=1)
    assert group.call(("ping",)) == "pong"  # ...retry lands on the sibling
    assert len(bad.calls) == 1 and len(good.calls) == 1
    with pytest.raises(WorkerFailure):
        ReplicaGroup(0, [FlakyReplica(fail_n=10**6)], retries=1).call(("ping",))


def test_process_worker_crash_retry_then_typed_failure(system, tmp_path):
    """The real crash hook: ("crash",) hard-exits the worker; the group
    respawns and retries, the retry crashes again, the failure is typed."""
    eng = _engine(system, n_shards=1, sched=dict(n_replicas=1))
    with Session(eng, store_dir=str(tmp_path)) as s:
        s.warm()
        group = s._groups[0]
        with pytest.raises(WorkerFailure) as ei:
            group.call(("crash",))
        assert ei.value.attempts == 2  # retry budget spent
        # the group recovered: next dispatch respawns and serves
        assert group.call(("ping",)) == "pong"
        snap = eng.metrics.snapshot()["sched"]
        assert snap["worker_retries"] == 1 and snap["worker_failures"] == 1


# ---------------------------------------------------------- short-circuits
def test_all_pad_and_k0_short_circuit_without_dispatch(system):
    eng = _engine(system, n_shards=1)
    replica = RecordingReplica()
    s = _fake_session(eng, replica)
    try:
        pad = np.full(4, -1, np.int32)
        r = s.submit_async(QueryRequest(terms=pad)).result(timeout=1)
        assert r.ok and r.ids.size == 0 and r.scores is None
        r = s.submit_async(QueryRequest(terms=pad, mode=MODE_RANKED)).result(timeout=1)
        assert r.ok and r.ids.size == 0 and r.scores is not None and r.scores.size == 0
        r = s.submit_async(
            QueryRequest(terms=np.array([3], np.int32), mode=MODE_RANKED, k=0)
        ).result(timeout=1)
        assert r.ok and r.ids.size == 0
        assert replica.calls == []  # resolved at submit: nothing was enqueued
        snap = eng.metrics.snapshot()["sched"]
        assert snap["short_circuit"] == 3 and snap["enqueued"] == 0
    finally:
        s.close()


# -------------------------------------------------------------- coalescing
def _pending(mode="boolean", tenant="default", priority=0, deadline=None, seq=0):
    req = QueryRequest(terms=np.array([1], np.int32), mode=mode, tenant=tenant,
                       priority=priority)
    return Pending(req=req, future=Future(), row=req.terms,
                   t_submit=time.monotonic(), deadline=deadline, seq=seq)


def test_take_batch_coalesces_head_mode_across_queue():
    queue = AdmissionQueue(SchedConfig(max_batch=16, max_queue=16), Registry())
    for mode in ["boolean", "boolean", "ranked", "boolean"]:
        queue.offer(_pending(mode=mode))
    # the head's mode coalesces past the other mode (FIFO within a mode);
    # the skipped ranked entry is left at the head for the next round
    batch = queue.take_batch(16)
    assert [p.req.mode for p in batch] == ["boolean"] * 3
    assert [p.seq for p in batch] == sorted(p.seq for p in batch)
    assert [p.req.mode for p in queue.take_batch(16)] == ["ranked"]
    # max_batch still caps a same-mode pull mid-queue
    for mode in ["ranked", "boolean", "ranked", "ranked"]:
        queue.offer(_pending(mode=mode))
    assert [p.req.mode for p in queue.take_batch(2)] == ["ranked"] * 2
    # the un-pulled entries keep arrival order: boolean is now the head
    assert [p.req.mode for p in queue.take_batch(16)] == ["boolean"]
    assert [p.req.mode for p in queue.take_batch(16)] == ["ranked"]


def test_take_batch_respects_max_batch_and_arrival_order():
    queue = AdmissionQueue(SchedConfig(max_batch=16, max_queue=64), Registry())
    for _ in range(5):
        queue.offer(_pending())
    batch = queue.take_batch(3)
    assert len(batch) == 3
    assert [p.seq for p in batch] == sorted(p.seq for p in batch)  # FIFO
    assert len(queue.take_batch(16)) == 2


def test_continuous_batching_coalesces_arrivals_while_busy(system):
    """Arrivals during an in-flight dispatch pile up and go out as one batch."""
    eng = _engine(system, n_shards=1)
    q, _ = _queries(system)

    gate = threading.Event()
    class SlowReplica(RecordingReplica):
        def call(self, msg):
            if msg[0] == "bool" and not gate.is_set():
                self.calls.append(msg)
                gate.wait(timeout=5)  # hold the batch in flight
                words = (self.n_docs + 31) // 32
                return np.zeros((len(msg[1]), words), dtype=np.uint32)
            return super().call(msg)

    def _wait(cond, timeout=5.0):
        deadline = time.monotonic() + timeout
        while not cond() and time.monotonic() < deadline:
            time.sleep(0.005)
        assert cond()

    replica = SlowReplica()
    s = _fake_session(eng, replica, max_batch=16)
    s._loop_thread.start()
    try:
        # occupy every runner slot with a gated in-flight batch, one at a
        # time so they cannot coalesce with each other
        n_slots = 2 * max(1, s.sched_cfg.n_replicas)
        first = []
        for i in range(n_slots):
            first.append(s.submit_async(QueryRequest(terms=q[i])))
            _wait(lambda: len(replica.calls) == len(first))
        # all slots busy -> the loop is parked on the slot semaphore and
        # these five arrivals pile up in the admission queue
        rest = [s.submit_async(QueryRequest(terms=q[i]))
                for i in range(n_slots, n_slots + 5)]
        _wait(lambda: len(s._queue._items) == 5)
        gate.set()
        assert all(f.result(timeout=5).ok for f in first)
        assert all(f.result(timeout=5).ok for f in rest)
        sizes = [len(msg[1]) for msg in replica.calls if msg[0] == "bool"]
        # the gated slot-fillers went out alone; the five arrivals went out
        # as ONE coalesced batch (its row matrix padded up to the 8-row
        # power-of-two bucket, so count batches, not rows)
        assert sizes[:n_slots] == [1] * n_slots
        assert len(sizes) == n_slots + 1 and sizes[n_slots] == 8
        snap = eng.metrics.snapshot()["sched"]
        assert snap["batches"] == n_slots + 1
        assert snap["dispatched"] == n_slots + 5
    finally:
        s.close()


# ------------------------------------------------------------- config shim
def test_flat_kwargs_deprecated_but_land_in_subconfigs():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = ServeConfig(payload_bits=4, topk_exhaustive_cutoff=0)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert cfg.ranked.payload_bits == 4
    assert cfg.ranked.topk_exhaustive_cutoff == 0
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = ServeConfig(ranked=False)  # old boolean flag
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert cfg.ranked.enabled is False and not cfg.ranked
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ServeConfig(shard_workers=4)  # retired knob: warned, ignored
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    with pytest.raises(TypeError):
        ServeConfig(not_a_knob=1)


def test_flat_kwarg_warning_cached_per_call_site():
    """A hot loop re-building configs warns once per call site, not per call."""
    from repro.serve import config as cfg_mod

    cfg_mod._WARNED_SITES.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for _ in range(3):
            ServeConfig(payload_bits=4)  # one site: exactly one warning
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    # a different call site with the same kwarg still gets its own warning
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ServeConfig(payload_bits=4)
    assert sum(issubclass(x.category, DeprecationWarning) for x in w) == 1


def test_flat_attributes_forward_to_subconfigs():
    cfg = ServeConfig()
    cfg.trace = sentinel = object()
    assert cfg.obs.trace is sentinel and cfg.trace is sentinel
    cfg.payload_bits = 4
    assert cfg.ranked.payload_bits == 4
    cfg.ranked.score_kernel = True
    assert cfg.score_kernel is True
    assert isinstance(cfg.obs, ObsConfig) and isinstance(cfg.ranked, RankedConfig)


def test_subconfigs_accept_dicts():
    cfg = ServeConfig(
        obs=dict(trace=None),
        ranked=dict(payload_bits=4),
        sched=dict(n_replicas=2, max_batch=8),
    )
    assert cfg.ranked.payload_bits == 4
    assert cfg.sched.n_replicas == 2 and cfg.sched.max_batch == 8


def test_worker_spec_round_trips_engine_flags():
    cfg = ServeConfig(
        n_shards=4,
        verified=False,
        ranked=dict(payload_bits=4),
        sched=dict(n_replicas=3),
        obs=dict(trace=object()),  # handles must NOT cross the pipe
    )
    spec = cfg.worker_spec()
    clone = ServeConfig(**spec)
    assert clone.verified is False and clone.n_shards == 4
    assert clone.ranked.payload_bits == 4
    assert clone.obs.trace is None  # worker builds its own obs
    assert clone.sched.n_replicas == 0  # workers execute; the session schedules


def test_coalesce_window_lingers_for_stragglers():
    """coalesce_us holds a non-full batch open so near-simultaneous arrivals
    ride the same dispatch."""
    queue = AdmissionQueue(
        SchedConfig(max_batch=8, max_queue=16, coalesce_us=200_000), Registry()
    )
    queue.offer(_pending())

    def late():
        time.sleep(0.03)
        queue.offer(_pending())
        queue.offer(_pending())

    t = threading.Thread(target=late)
    t.start()
    t0 = time.monotonic()
    batch = queue.take_batch(8)
    t.join()
    assert len(batch) == 3  # the stragglers made it into the lingering batch
    assert time.monotonic() - t0 < 1.0


def test_coalesce_window_anchored_to_head_submit_time():
    """The window is measured from the head's submit, not from take_batch:
    a batch that already aged while runners were busy dispatches at once."""
    queue = AdmissionQueue(
        SchedConfig(max_batch=8, max_queue=16, coalesce_us=150_000), Registry()
    )
    p = _pending()
    p.t_submit = time.monotonic() - 1.0  # aged in queue during a busy spell
    queue.offer(p)
    t0 = time.monotonic()
    assert len(queue.take_batch(8)) == 1
    assert time.monotonic() - t0 < 0.05  # no linger added on top of the age


# ------------------------------------------------------- ranked floor fan-in
def test_ranked_floor_forwarding_bit_identical(system):
    """forward_floor shares the running global kth score across the shard
    fan-in; it must only skip work, never change results."""
    _, rq = _queries(system)
    eng_f = _engine(system, n_shards=3, sched=dict(forward_floor=True))
    eng_0 = _engine(system, n_shards=3, sched=dict(forward_floor=False))
    want = eng_0.query_topk(rq, k=3, mode="or")  # engine facade reference
    with Session(eng_f) as sf, Session(eng_0) as s0:
        floors_sent = []
        for g in sf._groups:
            def wrap(msg, _orig=g.call):
                if msg[0] == "topk":
                    floors_sent.append([it[3] for it in msg[1]])
                return _orig(msg)
            g.call = wrap
        got_f = sf.query_topk(rq, k=3)
        got_0 = s0.query_topk(rq, k=3)
    for a, b, c in zip(got_f, got_0, want):
        assert np.array_equal(a.ids, b.ids) and np.array_equal(a.scores, b.scores)
        assert np.array_equal(a.ids, c.ids) and np.array_equal(a.scores, c.scores)
    # later groups in the sequential fan-in actually saw a raised floor
    assert any(f > 0 for fl in floors_sent for f in fl)


# ------------------------------------------------------------- warm snapshot
def test_warm_snapshot_respawn_bit_identical_and_re_jit_free(system, tmp_path):
    """A crashed worker's replacement replays the recorded warm log against
    the persistent compile cache: same jit cache, same shapes, same bits."""
    eng = _engine(
        system,
        n_shards=1,
        ranked=dict(fused_kernel=True),
        sched=dict(n_replicas=1),
    )
    _, rq = _queries(system)
    with Session(eng, store_dir=str(tmp_path)) as s:
        s.warm()
        want = s.query_topk(rq, k=5)
        rep = s._groups[0].replicas[0]
        before = rep.call(("caches",))
        assert before["dense_cache"] > 0 and before["dense_shapes"]
        assert before["arena"]["uploads"] == 1
        with pytest.raises(ReplicaError):
            rep.call(("crash",))
        after = rep.call(("caches",))  # respawn + warm-log replay first
        assert rep.warm_replays > 0 and rep.clock_syncs == 2
        assert after["dense_cache"] == before["dense_cache"]
        assert after["dense_shapes"] == before["dense_shapes"]
        got = s.query_topk(rq, k=5)
        post = rep.call(("caches",))
        # re-jit-free: serving the same shapes compiled nothing new
        assert post["dense_cache"] == after["dense_cache"]
        assert post["dense_shapes"] == after["dense_shapes"]
        for a, b in zip(want, got):
            assert np.array_equal(a.ids, b.ids)
            assert np.array_equal(a.scores, b.scores)
    assert (tmp_path / "warm_snapshot.json").exists()
    assert (tmp_path / "xla-compile-cache").is_dir()
    # a brand-new session over the same store preloads the snapshot, so its
    # first spawn replays the previous run's whole shape coverage
    eng2 = _engine(
        system,
        n_shards=1,
        ranked=dict(fused_kernel=True),
        sched=dict(n_replicas=1),
    )
    with Session(eng2, store_dir=str(tmp_path)) as s2:
        rep2 = s2._groups[0].replicas[0]
        assert len(rep2._warm_log) > 0  # seeded before the first spawn
        rep2.call(("ping",))
        assert rep2.warm_replays > 0
        got2 = s2.query_topk(rq, k=5)
        for a, b in zip(want, got2):
            assert np.array_equal(a.ids, b.ids)
            assert np.array_equal(a.scores, b.scores)
