"""Per-kernel validation: Pallas interpret-mode vs pure-jnp ref oracles,
shape/dtype sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.index.compress import dgaps, optpfd_encode, pack_bits
from repro.kernels.bitset.kernel import W_BLK, bitset_and_popcount
from repro.kernels.bitset.ops import query_block_intersect
from repro.kernels.bitset.ref import bitset_and_ref, popcount_ref
from repro.kernels.membership.kernel import D_BLK, Q_BLK, membership_bitmask
from repro.kernels.membership.ops import score_terms_bitmask
from repro.kernels.membership.ref import membership_bitmask_ref, pack_bool_u32
from repro.kernels.pfor.kernel import unpack_blocks
from repro.kernels.pfor.ops import decode_stream
from repro.kernels.pfor.ref import BLOCK, unpack_block_ref, words_per_block

rng = np.random.default_rng(42)


# ----------------------------------------------------------- membership
@pytest.mark.parametrize("q_tiles,d_tiles", [(1, 1), (2, 1), (1, 2), (3, 2)])
@pytest.mark.parametrize("e", [32, 64, 128])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_membership_kernel_vs_ref(q_tiles, d_tiles, e, dtype):
    q = (rng.standard_normal((Q_BLK * q_tiles, e)) * 0.5).astype(np.float32)
    d = (rng.standard_normal((D_BLK * d_tiles, e)) * 0.5).astype(np.float32)
    tau = rng.standard_normal(Q_BLK * q_tiles).astype(np.float32)
    bias = np.float32(0.05)
    qj = jnp.asarray(q, dtype=dtype)
    dj = jnp.asarray(d, dtype=dtype)
    out = membership_bitmask(qj, dj, jnp.asarray(tau), jnp.asarray(bias))
    ref = membership_bitmask_ref(qj, dj, jnp.asarray(tau), jnp.asarray(bias))
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_membership_ops_ragged():
    params = {
        "term_embed": {"table": jnp.asarray(rng.standard_normal((300, 48)).astype(np.float32))},
        "doc_embed": {"table": jnp.asarray(rng.standard_normal((1111, 48)).astype(np.float32))},
        "bias": jnp.float32(0.0),
    }
    tau = jnp.asarray(rng.standard_normal(300).astype(np.float32))
    terms = jnp.asarray(rng.integers(0, 300, 45).astype(np.int32))
    bm = np.asarray(score_terms_bitmask(params, terms, tau))
    logits = np.asarray(params["term_embed"]["table"])[np.asarray(terms)] @ np.asarray(
        params["doc_embed"]["table"]
    ).T
    hits = logits >= np.asarray(tau)[np.asarray(terms)][:, None]
    for i in range(45):
        for j in rng.integers(0, 1111, 40):
            bit = bool((bm[i, j // 32] >> np.uint32(j % 32)) & 1)
            assert bit == hits[i, j], (i, j)
    # padded tail bits must be zero
    tail_bits = 1111 % 32
    assert (bm[:, -1] >> np.uint32(tail_bits)).max() == 0


def test_pack_bool_u32_roundtrip():
    bits = rng.integers(0, 2, size=(7, 96)).astype(bool)
    packed = np.asarray(pack_bool_u32(jnp.asarray(bits)))
    unpacked = np.unpackbits(packed.view(np.uint8), axis=-1, bitorder="little")[:, :96]
    assert np.array_equal(unpacked.astype(bool), bits)


# ----------------------------------------------------------- bitset
@pytest.mark.parametrize("t", [1, 3, 8])
def test_bitset_kernel_vs_ref(t):
    q, w = 4, W_BLK * 2
    maps = rng.integers(0, 2**32, size=(q, t, w), dtype=np.uint32)
    valid = rng.integers(0, 2, size=(q, t)).astype(bool)
    valid[:, 0] = True
    anded, cnt = bitset_and_popcount(jnp.asarray(maps), jnp.asarray(valid.astype(np.int32)))
    for i in range(q):
        ref = np.asarray(bitset_and_ref(jnp.asarray(maps[i]), jnp.asarray(valid[i])))
        assert np.array_equal(np.asarray(anded[i]), ref)
        assert int(cnt[i]) == int(popcount_ref(jnp.asarray(ref)))


def test_query_block_intersect_matches_numpy():
    bitmaps = rng.integers(0, 2**32, size=(40, 70), dtype=np.uint32)
    queries = np.array([[1, 5, -1, -1], [7, -1, -1, -1], [2, 3, 11, 39]], np.int32)
    anded, cnt = query_block_intersect(jnp.asarray(bitmaps), jnp.asarray(queries))
    for i, qr in enumerate(queries):
        rows = [bitmaps[t] for t in qr if t >= 0]
        exp = rows[0].copy()
        for r in rows[1:]:
            exp &= r
        assert np.array_equal(np.asarray(anded[i]), exp)
        assert int(cnt[i]) == sum(bin(int(x)).count("1") for x in exp)


# ----------------------------------------------------------- pfor
@pytest.mark.parametrize("width", [0, 1, 4, 7, 8, 13, 16, 20, 27, 31, 32])
def test_pfor_kernel_vs_ref_all_widths(width):
    n_blocks = 5
    hi = 2**width if width < 32 else 2**32
    vals = rng.integers(0, max(hi, 1), size=(n_blocks, BLOCK), dtype=np.uint64).astype(np.uint32)
    if width == 0:
        vals[:] = 0
    wpb = words_per_block(width)
    rows = np.zeros((n_blocks, wpb), np.uint32)
    for i in range(n_blocks):
        p = pack_bits(vals[i], width)
        rows[i, : len(p)] = p
    got = np.asarray(unpack_blocks(jnp.asarray(rows), width=width))
    ref = np.asarray(unpack_block_ref(jnp.asarray(rows), width))
    assert np.array_equal(got, vals)
    assert np.array_equal(ref, vals)


@given(st.lists(st.integers(0, 2**26), min_size=2, max_size=600, unique=True))
@settings(max_examples=20, deadline=None)
def test_pfor_stream_decode_property(ids):
    docs = np.sort(np.array(ids, dtype=np.int32))
    stream = optpfd_encode(dgaps(docs))
    out = decode_stream(stream, len(docs))
    assert np.array_equal(out, docs)
