"""Learned-postings subsystem: codec round-trips, hybrid selection, kernel
bit-exactness, and the serve-path regressions (empty lists, overflow)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gain import learned_storage_fractions
from repro.index.build import build_inverted_index
from repro.index.compress import (
    CODECS,
    compressed_size_bits,
    decode_postings,
    dgaps,
    eliasfano_size_bits,
    encode_postings,
    undgaps,
)
from repro.postings import (
    CANDIDATES,
    HybridPostings,
    choose_codec,
    plm_decode,
    plm_encode,
    plm_size_bits,
    rmi_encode,
)
from repro.postings.plm import parse_stream

ALL_CODECS = list(CODECS) + ["hybrid"]


def _random_list(rng, n, universe):
    n = min(n, universe)
    return np.sort(rng.choice(universe, size=n, replace=False)).astype(np.int32)


# ------------------------------------------------------------- round-trips
@pytest.mark.parametrize("codec", ALL_CODECS)
@pytest.mark.parametrize("n", [0, 1, 2, 127, 128, 129, 300])
def test_roundtrip_lengths(codec, n):
    """Every codec is exactly lossless incl. empty, singleton, block edges."""
    rng = np.random.default_rng(n + 17)
    ids = _random_list(rng, n, 1 << 20)
    enc = encode_postings(ids, codec, universe=1 << 20)
    assert np.array_equal(decode_postings(enc, len(ids), codec), ids)


@pytest.mark.parametrize("codec", ALL_CODECS)
def test_roundtrip_adversarial_gaps(codec):
    """Huge first gap + near-int32-max ids survive every codec."""
    ids = np.array([0, 1, 2, 3, 2**31 - 5, 2**31 - 2], dtype=np.int64).astype(np.int32)
    enc = encode_postings(ids, codec, universe=2**31 - 1)
    assert np.array_equal(decode_postings(enc, len(ids), codec), ids)


@given(st.lists(st.integers(0, 2**27), min_size=0, max_size=500, unique=True))
@settings(max_examples=25, deadline=None)
def test_learned_roundtrip_property(ids):
    """Acceptance: plm and rmi are exactly lossless on randomized lists."""
    docs = np.sort(np.array(ids, dtype=np.int64)).astype(np.int32)
    for codec in ("plm", "rmi"):
        enc = encode_postings(docs, codec)
        assert np.array_equal(decode_postings(enc, len(docs), codec), docs), codec


@pytest.mark.parametrize("eps", [0, 1, 7, 63, 1024])
def test_plm_eps_sweep_lossless(eps):
    rng = np.random.default_rng(eps)
    ids = _random_list(rng, 400, 1 << 22)
    assert np.array_equal(plm_decode(plm_encode(ids, eps), len(ids)), ids)


def test_plm_crushes_smooth_lists():
    """The paper's motivation: a near-linear list stores in O(segments) bits."""
    ids = np.arange(0, 3 * 50_000, 3, dtype=np.int32)
    plm_bits = plm_size_bits(ids)
    opt_bits = compressed_size_bits(ids, int(ids[-1]) + 1, "optpfd")
    assert plm_bits < opt_bits / 50


def test_plm_size_model_matches_stream():
    rng = np.random.default_rng(3)
    ids = _random_list(rng, 700, 1 << 24)
    bits = plm_size_bits(ids)
    words = plm_encode(ids)
    # stream pads corrections to a word boundary; size model counts exact bits
    assert bits <= words.size * 32 <= bits + 31 + 1


# ----------------------------------------------------------------- hybrid
@given(st.lists(st.integers(0, 2**26), min_size=0, max_size=400, unique=True))
@settings(max_examples=25, deadline=None)
def test_hybrid_always_picks_min_bits(ids):
    """Acceptance: hybrid never selects a codec larger than the best one."""
    docs = np.sort(np.array(ids, dtype=np.int64)).astype(np.int32)
    universe = 1 << 26
    codec, bits, sizes = choose_codec(docs, universe)
    assert bits == min(sizes.values())
    assert sizes[codec] == bits


def test_hybrid_store_roundtrip_and_accounting():
    from repro.common.config import CorpusConfig
    from repro.data.corpus import synthesize_corpus

    inv = build_inverted_index(
        synthesize_corpus(CorpusConfig(n_docs=400, n_terms=1500, avg_doc_len=40, seed=9))
    )
    store = HybridPostings.from_index(inv)
    for t in range(0, inv.n_terms, 37):
        assert np.array_equal(store.postings(t), inv.postings(t))
    per_term = store.bits[inv.dfs > 0]
    assert (per_term > 0).all()
    assert store.size_bits() == int(store.bits.sum())
    assert sum(store.codec_histogram().values()) == int((inv.dfs > 0).sum())


def test_hybrid_stream_selfdescribing():
    rng = np.random.default_rng(11)
    ids = _random_list(rng, 250, 1 << 18)
    enc = encode_postings(ids, "hybrid", universe=1 << 18)
    assert int(enc[0]) < len(CANDIDATES)  # tag word
    assert np.array_equal(decode_postings(enc, len(ids), "hybrid"), ids)


# ------------------------------------------------------------------ kernel
def test_plm_decode_kernel_matches_ref_bit_exact():
    """Acceptance: Pallas kernel == jnp reference in CPU interpret mode."""
    import jax.numpy as jnp

    from repro.kernels.plm_decode.kernel import decode_batch
    from repro.kernels.plm_decode.ref import SENTINEL, decode_ref

    rng = np.random.default_rng(5)
    lists = [
        _random_list(rng, n, 1 << 24) for n in (1, 5, 127, 128, 129, 700, 2000)
    ]
    parsed = [parse_stream(plm_encode(ids), len(ids)) for ids in lists]
    S = max(len(p[0]) for p in parsed)
    R = 2048
    B = len(parsed)
    starts = np.full((B, S), int(SENTINEL), np.int32)
    bases = np.zeros((B, S), np.int32)
    slopes = np.zeros((B, S), np.float32)
    corr = np.zeros((B, R), np.int32)
    for r, (st_, ba, sl, co) in enumerate(parsed):
        s = len(st_)
        starts[r, :s] = st_.astype(np.int32)
        bases[r, :s] = ba.astype(np.int32)
        slopes[r, :s] = sl
        corr[r, : len(co)] = co.astype(np.int32)
    args = tuple(jnp.asarray(a) for a in (starts, bases, slopes, corr))
    assert np.array_equal(np.asarray(decode_batch(*args)), np.asarray(decode_ref(*args)))


@pytest.mark.parametrize("enc", [plm_encode, rmi_encode])
def test_kernel_batched_decode_exact(enc):
    from repro.kernels.plm_decode.ops import decode_lists

    rng = np.random.default_rng(6)
    lens = [0, 1, 64, 129, 1000]
    lists = [_random_list(rng, n, 1 << 22) for n in lens]
    out = decode_lists([enc(ids) for ids in lists], [len(i) for i in lists])
    for ids, got in zip(lists, out):
        assert np.array_equal(ids, got)


# ------------------------------------------------------- satellite regressions
def test_undgaps_overflow_raises():
    gaps = np.array([2**31 - 1, 10], dtype=np.uint32)
    with pytest.raises(OverflowError):
        undgaps(gaps)


def test_undgaps_near_int32_max_ok():
    ids = np.array([5, 2**31 - 2], dtype=np.int64).astype(np.int32)
    assert np.array_equal(undgaps(dgaps(ids)), ids)


def test_eliasfano_size_dense_branch():
    """universe <= n: l must be 0 and the size model stays sane/positive."""
    ids = np.arange(100, dtype=np.int32)
    bits = eliasfano_size_bits(ids, universe=100)
    assert bits == 2 * 100 + 100 + 2  # l=0: unary high bits only
    assert eliasfano_size_bits(ids, universe=50) >= bits  # clamped to max id + 1


def _bare_engine(inv, cfg):
    """Shard executor with only the verification plumbing (skip the model)."""
    from repro.serve.cache import CostLRU
    from repro.serve.shard import ShardEngine

    from repro.rank.topk import RankedStats

    eng = ShardEngine.__new__(ShardEngine)
    eng.cfg = cfg
    eng.inv = inv
    eng.lo, eng.hi = 0, inv.n_docs
    eng._tier2 = None
    eng._guided = None
    eng._impact_model = None
    eng._ranked = None
    eng.ranked_stats = RankedStats()
    eng._dfs = inv.dfs
    eng._decode_cache = CostLRU(cfg.cache_budget_bytes)
    return eng


def test_verify_empty_postings_regression():
    """ShardEngine._verify must not index p[-1] when a term has no postings."""
    from repro.index.build import InvertedIndex
    from repro.serve.boolean import ServeConfig

    inv = InvertedIndex(
        n_docs=8,
        n_terms=3,
        term_offsets=np.array([0, 4, 4, 6], dtype=np.int64),  # term 1 is empty
        doc_ids=np.array([0, 2, 4, 6, 1, 3], dtype=np.int32),
    )
    eng = _bare_engine(inv, ServeConfig(postings_store="raw"))
    out = eng._verify(np.array([0, 1], dtype=np.int32), np.array([0, 2], dtype=np.int32))
    assert len(out) == 0  # empty term list -> empty conjunction, no crash
    out = eng._verify(np.array([0, 2], dtype=np.int32), np.arange(8, dtype=np.int32))
    assert set(out.tolist()) == {0, 2, 4, 6} & {1, 3}


def test_verify_through_hybrid_store():
    from repro.index.build import InvertedIndex
    from repro.serve.boolean import ServeConfig

    rng = np.random.default_rng(13)
    a = np.sort(rng.choice(500, 200, replace=False)).astype(np.int32)
    b = np.sort(rng.choice(500, 150, replace=False)).astype(np.int32)
    inv = InvertedIndex(
        n_docs=500,
        n_terms=2,
        term_offsets=np.array([0, len(a), len(a) + len(b)], dtype=np.int64),
        doc_ids=np.concatenate([a, b]),
    )
    eng = _bare_engine(inv, ServeConfig(postings_store="hybrid"))
    got = eng._verify(np.array([0, 1], dtype=np.int32), np.arange(500, dtype=np.int32))
    expect = np.intersect1d(a, b)
    assert np.array_equal(np.sort(got), expect)
    assert eng.tier2 is not None and eng.tier2.size_bits() > 0


# ------------------------------------------------------------------- gain
def test_learned_storage_fractions_sane():
    from repro.common.config import CorpusConfig
    from repro.data.corpus import synthesize_corpus

    inv = build_inverted_index(
        synthesize_corpus(CorpusConfig(n_docs=500, n_terms=2000, avg_doc_len=50, seed=21))
    )
    reports = learned_storage_fractions(inv, (7, 63))
    for r in reports:
        assert 0.0 <= r.frac_terms_learned <= 1.0
        # hybrid = per-term min + flags: never (meaningfully) above classical
        assert r.hybrid_bits <= r.classical_bits + inv.n_terms
        assert r.learned_bits > 0 and r.classical_bits > 0
