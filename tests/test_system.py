"""End-to-end behaviour tests for the paper's system: train the membership
model, build the learned-Bloom engine, serve queries exactly; checkpoint
resume mid-training; memory report vs Eq.(2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import CorpusConfig, LearnedIndexConfig, OptimizerConfig
from repro.core import (
    estimate_gain,
    false_negative_rate,
    false_positive_rate,
    fit_thresholds,
    init_membership,
    membership_loss,
)
from repro.data.corpus import synthesize_corpus
from repro.data.loader import membership_batches
from repro.data.queries import brute_force_answers, sample_queries
from repro.index.build import build_inverted_index
from repro.serve import BooleanEngine, ServeConfig
from repro.train import init_train_state, make_train_step


@pytest.fixture(scope="module")
def system():
    corpus = synthesize_corpus(CorpusConfig(n_docs=600, n_terms=2500, avg_doc_len=60, seed=5))
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=32, truncation_k=24, block_size=64)
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    ocfg = OptimizerConfig(lr=0.05, warmup_steps=10, total_steps=150, weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b), ocfg))
    st = init_train_state(params, ocfg)
    for i, batch in zip(range(150), membership_batches(corpus, batch_size=1024, seed=1)):
        params, st, _ = step(params, st, {k: jnp.asarray(v) for k, v in batch.items()})
    lb = fit_thresholds(params, inv)
    return corpus, inv, li_cfg, lb


def test_trained_model_fpr_beats_random(system):
    corpus, inv, li_cfg, lb = system
    fpr_trained = false_positive_rate(lb, inv, sample=4000)
    p_rand, _ = init_membership(jax.random.key(9), li_cfg, corpus.n_terms, corpus.n_docs)
    lb_rand = fit_thresholds(p_rand, inv)
    fpr_rand = false_positive_rate(lb_rand, inv, sample=4000)
    assert false_negative_rate(lb, inv) == 0.0
    assert fpr_trained < fpr_rand  # training must tighten the filter


@pytest.mark.parametrize("algorithm", ["exhaustive", "two_tier", "block"])
def test_engine_verified_mode_is_exact(system, algorithm):
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(algorithm=algorithm, verified=True))
    q = sample_queries(corpus, 16, seed=2)
    results = eng.query_batch(q)
    exact = brute_force_answers(corpus, q)
    if algorithm == "two_tier":
        # exactness guaranteed only for tier-1-guaranteed queries (paper §3.2)
        from repro.core import two_tier_guaranteed
        guar = np.asarray(two_tier_guaranteed(
            jnp.asarray(inv.dfs.astype(np.int32)), jnp.asarray(q),
            li_cfg.truncation_k, with_model=True))
        pairs = [(r, e) for r, e, g in zip(results, exact, guar) if g]
        assert pairs, "no guaranteed queries sampled"
    else:
        pairs = list(zip(results, exact))
    for r, e in pairs:
        assert np.array_equal(r, e)


def test_engine_kernel_path_matches_jnp(system):
    corpus, inv, li_cfg, lb = system
    q = sample_queries(corpus, 8, seed=4)
    e1 = BooleanEngine(lb, inv, li_cfg,
                       ServeConfig(algorithm="exhaustive", verified=False, use_kernel=True))
    e2 = BooleanEngine(lb, inv, li_cfg,
                       ServeConfig(algorithm="exhaustive", verified=False, use_kernel=False))
    r1 = e1.query_batch(q)
    r2 = e2.query_batch(q)
    for a, b in zip(r1, r2):
        assert np.array_equal(a, b)


def test_memory_report_consistent_with_gain(system):
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg)
    rep = eng.memory_report()
    assert rep["model_bits"] > 0 and rep["tier1_bits"] > 0
    g = estimate_gain(inv, li_cfg.truncation_k, s_worst_bits=li_cfg.model_bits_per_pair)
    # Eq.(2)'s worst-case model charge must upper-bound the actual model size
    # attributable to replaced terms (the actual model is shared across terms)
    assert g.s_worst_bits * g.n_replaced * inv.n_docs >= rep["model_bits"] or g.n_replaced == 0


def test_checkpoint_resume_training(tmp_path):
    """Kill-and-resume: training continues from the checkpoint exactly."""
    from repro.checkpoint import CheckpointManager

    corpus = synthesize_corpus(CorpusConfig(n_docs=200, n_terms=800, avg_doc_len=40, seed=6))
    li_cfg = LearnedIndexConfig(embed_dim=16)
    params, _ = init_membership(jax.random.key(0), li_cfg, corpus.n_terms, corpus.n_docs)
    ocfg = OptimizerConfig(lr=0.02, warmup_steps=2, total_steps=60, weight_decay=0.0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b), ocfg))
    st = init_train_state(params, ocfg)
    cm = CheckpointManager(str(tmp_path))
    batches = [
        {k: jnp.asarray(v) for k, v in b.items()}
        for _, b in zip(range(20), membership_batches(corpus, batch_size=256, seed=2))
    ]
    # run 10 steps, checkpoint, continue to 20 (reference trajectory)
    for i in range(10):
        params, st, _ = step(params, st, batches[i])
    cm.save(10, {"params": params, "opt": st})
    ref_p, ref_st = params, st
    for i in range(10, 20):
        ref_p, ref_st, _ = step(ref_p, ref_st, batches[i])
    # resume path must reproduce the reference trajectory bit-for-bit
    s, tree = cm.restore_latest({"params": params, "opt": st})
    assert s == 10
    rp, rst = tree["params"], tree["opt"]
    for i in range(10, 20):
        rp, rst, _ = step(rp, rst, batches[i])
    assert int(rst.step) == 20
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(rp)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
