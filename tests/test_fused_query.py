"""Fused ranked-query kernel: one dispatch from candidates to top-k.

The load-bearing property is the same bit-exactness bar as the multi-phase
ranked path: `ServeConfig.fused_kernel` must reproduce the multi-phase
engine AND the brute-force quantized-BM25 oracle — ids and integer scores,
ties broken by ascending doc id — across shard counts, codec tiers
(learned plm/rmi windows and classical host-resolved lanes in one tile),
k ∈ {1, 10, > candidates}, required-term mixes, and all-pad batches.  The
interpret-mode Pallas kernel is additionally pinned bit-identical to its
numpy reference (`fused_topk_ref`).
"""
import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import fit_thresholds, init_membership
from repro.data.corpus import synthesize_corpus
from repro.data.queries import zipf_disjunctions
from repro.index.build import build_inverted_index
from repro.rank.score import BM25Params, ImpactModel, brute_force_topk
from repro.serve import BooleanEngine, ServeConfig

K = 10
N_TERMS = 3000


# the hypothesis-shim wrapper hides fixture params from pytest, so the
# @given property tests reach the shared system through this module cache;
# the fixtures below delegate to it (everything is built exactly once)
_SHARED: dict = {}


def _shared_system():
    if "system" not in _SHARED:
        corpus = synthesize_corpus(
            CorpusConfig(n_docs=800, n_terms=N_TERMS, avg_doc_len=50, seed=11)
        )
        inv = build_inverted_index(corpus)
        li = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=128)
        params, _ = init_membership(
            jax.random.key(0), li, corpus.n_terms, corpus.n_docs
        )
        lb = fit_thresholds(params, inv)
        im = ImpactModel.build(inv, BM25Params())
        _SHARED["system"] = (corpus, inv, li, lb, im)
    return _SHARED["system"]


def _shared_engines():
    if "engines" not in _SHARED:
        _SHARED["engines"] = {
            (fused, ns): _engine(_shared_system(), fused=fused, n_shards=ns)
            for fused in (False, True)
            for ns in (1, 3)
        }
    return _SHARED["engines"]


@pytest.fixture(scope="module")
def system():
    return _shared_system()


def _engine(system, *, fused, n_shards=1, cutoff=0):
    # cutoff=0 disables the exhaustive shortcut so the peel/kernel path is
    # exercised even on this small corpus
    _, inv, li, lb, _ = system
    cfg = ServeConfig(
        n_shards=n_shards,
        ranked=dict(fused_kernel=fused, topk_exhaustive_cutoff=cutoff),
    )
    return BooleanEngine(lb, inv, li, cfg)


@pytest.fixture(scope="module")
def engines(system):
    return _shared_engines()


def _check(a, b, ctx=""):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert np.array_equal(x.ids, y.ids), ctx
        assert np.array_equal(x.scores, y.scores), ctx


# ------------------------------------------------------------ bit-exactness
@pytest.mark.parametrize("n_shards", [1, 3])
@pytest.mark.parametrize("k", [1, K])
def test_fused_matches_multiphase_and_oracle(system, engines, n_shards, k):
    _, inv, _, _, im = system
    q, _ = zipf_disjunctions(inv.dfs, 24, seed=5)
    fused = engines[(True, n_shards)].query_topk(q, k)
    multi = engines[(False, n_shards)].query_topk(q, k)
    oracle = brute_force_topk(inv, im, q, k)
    _check(fused, multi, f"fused != multiphase at K={n_shards} k={k}")
    _check(fused, oracle, f"fused != oracle at K={n_shards} k={k}")


def test_fused_kernel_actually_ran(system, engines):
    eng = engines[(True, 1)]
    _, inv, *_ = system
    q, _ = zipf_disjunctions(inv.dfs, 24, seed=5)
    eng.reset_stats()
    eng.query_topk(q, K)
    s = eng.metrics.snapshot()["ranked"]
    assert s["fused_queries"] > 0 and s["fused_lanes"] > 0
    assert s["fused_stream_bytes"] > 0 and s["fused_device_bytes"] > 0


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.integers(0, N_TERMS - 1), min_size=1, max_size=6, unique=True),
    st.integers(0, 2),  # k ∈ {1, 10, 2000 > any candidate set}
    st.integers(0, 2),  # required prefix length
)
def test_fused_property_vs_multiphase(terms, k_idx, n_req):
    engines = _shared_engines()
    k = (1, K, 2000)[k_idx]
    row = np.full((1, 6), -1, np.int32)
    row[0, : len(terms)] = terms
    req = np.zeros_like(row, dtype=bool)
    req[0, : min(n_req, len(terms))] = True
    req &= row >= 0
    for ns in (1, 3):
        fused = engines[(True, ns)].query_topk(row, k, required=req)
        multi = engines[(False, ns)].query_topk(row, k, required=req)
        _check(fused, multi, f"terms={terms} k={k} n_req={n_req} K={ns}")


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, N_TERMS - 1), min_size=1, max_size=6, unique=True))
def test_fused_property_vs_oracle(terms):
    engines = _shared_engines()
    _, inv, _, _, im = _shared_system()
    row = np.full((1, 6), -1, np.int32)
    row[0, : len(terms)] = terms
    fused = engines[(True, 1)].query_topk(row, K)
    oracle = brute_force_topk(inv, im, row, K)
    _check(fused, oracle, f"terms={terms}")


def test_fused_k_exceeds_candidates(system, engines):
    _, inv, _, _, im = system
    q, _ = zipf_disjunctions(inv.dfs, 8, seed=6)
    fused = engines[(True, 1)].query_topk(q, 2000)
    oracle = brute_force_topk(inv, im, q, 2000)
    _check(fused, oracle, "k > n_candidates must return every match, ranked")


def test_fused_all_pad_batch(system, engines):
    pad = np.full((4, 5), -1, np.int32)
    for ns in (1, 3):
        res = engines[(True, ns)].query_topk(pad, K)
        assert all(r.ids.size == 0 and r.scores.size == 0 for r in res)


def test_fused_mixed_pad_batch(system, engines):
    _, inv, _, _, im = system
    q, _ = zipf_disjunctions(inv.dfs, 6, seed=7)
    q[1] = -1  # dead rows interleaved with live ones
    q[4] = -1
    fused = engines[(True, 3)].query_topk(q, K)
    oracle = brute_force_topk(inv, im, q, K)
    _check(fused, oracle, "pad rows must stay empty, live rows exact")
    assert fused[1].ids.size == 0 and fused[4].ids.size == 0


# ------------------------------------------------------------- codec tiers
def _tiered_system():
    """Engineered index where codec choice is forced, not hoped for.

    Uniform synthetic corpora never hand a posting list to the learned
    codecs (the id gaps are too irregular), so this builds the inverted
    index directly: smooth strided-with-jitter lists that plm wins with a
    small nonzero ε (real guided-window lanes in the kernel), next to
    random sparse lists that stay classical.
    """
    if "tiered" not in _SHARED:
        from repro.index.build import InvertedIndex

        rng = np.random.default_rng(3)
        universe = 101_000
        lists = [np.arange(2000) * 50 + rng.integers(0, 12, 2000) + s
                 for s in range(6)]
        lists += [np.sort(rng.choice(universe, 900, replace=False))
                  for _ in range(6)]
        offsets = np.zeros(len(lists) + 1, np.int64)
        np.cumsum([len(l) for l in lists], out=offsets[1:])
        inv = InvertedIndex(
            n_docs=universe,
            n_terms=len(lists),
            term_offsets=offsets,
            doc_ids=np.concatenate(lists).astype(np.int32),
            tfs=rng.integers(1, 8, int(offsets[-1])).astype(np.int32),
        )
        li = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=128)
        params, _ = init_membership(jax.random.key(1), li, inv.n_terms, inv.n_docs)
        lb = fit_thresholds(params, inv)
        im = ImpactModel.build(inv, BM25Params())
        engs = {
            fused: BooleanEngine(lb, inv, li, ServeConfig(
                n_shards=1,
                ranked=dict(fused_kernel=fused, topk_exhaustive_cutoff=0),
            ))
            for fused in (False, True)
        }
        _SHARED["tiered"] = (inv, im, engs)
    return _SHARED["tiered"]


def test_fused_across_codec_tiers():
    """One query mixing learned-window and classical host-resolved lanes."""
    inv, im, engs = _tiered_system()
    src = engs[True].shards[0].ranked
    learned, classical = [], []
    for t in range(inv.n_terms):
        tm = src.term_model(t)
        (learned if tm is not None and 0 < tm.width < 32 else classical).append(t)
    assert learned and classical, "index must exercise both lane flavours"
    row = np.full((1, 6), -1, np.int32)
    mix = (learned[:3] + classical[:3])[:6]
    row[0, : len(mix)] = mix
    fused = engs[True].query_topk(row, K)
    _check(fused, engs[False].query_topk(row, K), f"mixed-tier vs multiphase {mix}")
    _check(fused, brute_force_topk(inv, im, row, K), f"mixed-tier query {mix}")
    s = engs[True].metrics.snapshot()["ranked"]
    assert s["fused_queries"] > 0 and s["fused_lanes"] > 0


# ---------------------------------------------------- kernel vs reference
def test_kernel_bit_identical_to_reference(system, engines):
    from repro.kernels.fused_query.ops import fused_topk_batch
    from repro.rank.topk import RankedStats

    _, inv, *_ = system
    src = engines[(True, 1)].shards[0].ranked
    q, _ = zipf_disjunctions(inv.dfs, 16, seed=9)
    items = [(tuple(int(t) for t in row[row >= 0]), K, (), 0) for row in q]
    kern = fused_topk_batch(src, items, exhaustive_cutoff=0, stats=RankedStats())
    ref = fused_topk_batch(
        src, items, exhaustive_cutoff=0, stats=RankedStats(), use_kernel=False
    )
    _check(kern, ref, "Pallas kernel must match the numpy reference bit-for-bit")


# -------------------------------------------------------- serve-path wiring
def test_empty_run_shards_short_circuit(system, engines, monkeypatch):
    """A shard whose every run mask is empty is skipped before heap setup."""
    _, inv, *_ = system
    eng = engines[(False, 3)]
    lo1 = eng.shards[1].lo
    t = next(
        int(t) for t in range(inv.n_terms)
        if 0 < inv.dfs[t] and int(inv.postings(t).max()) < lo1
    )
    calls = {i: 0 for i in range(len(eng.shards))}

    def _wrap(i, orig):
        def counted(*a, **kw):
            calls[i] += 1
            return orig(*a, **kw)
        return counted

    for i, sh in enumerate(eng.shards):
        monkeypatch.setattr(sh, "query_topk_local", _wrap(i, sh.query_topk_local))
    res = eng.query_topk(np.array([[t]], np.int32), K)
    assert res[0].ids.size > 0
    assert calls[0] >= 1 and calls[1] == 0 and calls[2] == 0


def test_scheduler_inline_fused_parity(system):
    from repro.serve.sched import MODE_RANKED, QueryRequest, Session

    _, inv, *_ = system
    eng = _engine(system, fused=True, n_shards=2)
    q, _ = zipf_disjunctions(inv.dfs, 8, seed=13)
    want = eng.query_topk(q, K)
    with Session(eng) as s:
        got = [
            s.submit_async(
                QueryRequest(terms=row, mode=MODE_RANKED, k=K), block=True
            ).result(timeout=30)
            for row in q
        ]
    for g, w in zip(got, want):
        assert g.ok
        assert np.array_equal(g.ids, w.ids) and np.array_equal(g.scores, w.scores)


def test_fused_kernel_flat_kwarg_forwards():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cfg = ServeConfig(fused_kernel=True)  # legacy flat spelling
    assert cfg.ranked.fused_kernel is True and cfg.fused_kernel is True
    # process replicas must inherit the flag through the picklable spec
    spec = ServeConfig(ranked=dict(fused_kernel=True)).worker_spec()
    assert spec["ranked"].fused_kernel is True


# ----------------------------------------------------------- arena residence
def test_arena_residence_zero_reuploads(system, engines):
    """The impact table is uploaded once per shard per process: repeated
    dispatches hit the resident buffers, uploads/upload_bytes never move."""
    eng = engines[(True, 1)]
    _, inv, *_ = system
    q, _ = zipf_disjunctions(inv.dfs, 16, seed=6)
    eng.query_topk(q, K)  # builds the arena lazily on the first fused use
    sh = eng.shards[0]
    snap0 = sh.metrics.snapshot()["arena"]
    assert snap0 is not None
    assert snap0["uploads"] == 1 and snap0["upload_bytes"] > 0
    for _ in range(3):
        eng.query_topk(q, K)
    snap1 = sh.metrics.snapshot()["arena"]
    assert snap1["uploads"] == 1
    assert snap1["upload_bytes"] == snap0["upload_bytes"]
    assert snap1["hits"] > snap0["hits"]


def test_arena_disabled_by_config(system):
    """ranked.device_arena=False routes every item down the legacy peel path
    (no arena is ever built) and stays bit-identical."""
    _, inv, li, lb, _ = system
    cfg = ServeConfig(
        n_shards=1,
        ranked=dict(fused_kernel=True, topk_exhaustive_cutoff=0, device_arena=False),
    )
    eng = BooleanEngine(lb, inv, li, cfg)
    q, _ = zipf_disjunctions(inv.dfs, 12, seed=7)
    want = _shared_engines()[(False, 1)].query_topk(q, K)
    got = eng.query_topk(q, K)
    _check(got, want, "device_arena=False must stay bit-identical")
    assert eng.shards[0].metrics.snapshot().get("arena") is None
