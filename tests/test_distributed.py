"""Distributed pieces need >1 device; jax locks device count at first init,
so these run in subprocesses with XLA_FLAGS set (the same isolation dryrun.py
uses). Each subprocess asserts internally; the test checks the exit code."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, n_dev: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"


def test_compressed_allreduce_subprocess():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed import compressed_allreduce
from repro.common.sharding import concrete_mesh, shard_map
mesh = concrete_mesh((8,), ("data",))
rng = np.random.default_rng(0)
xs = rng.standard_normal((8, 64)).astype(np.float32)
f = lambda x: compressed_allreduce({"g": x}, mesh, "data")["g"]
out = np.asarray(jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data")))(jnp.asarray(xs)))
exact = xs.sum(0)
for r in range(8):
    assert np.array_equal(out[r], out[0]), "bitwise consistency"
rel = np.abs(out[0] - exact).max() / np.abs(exact).max()
assert rel < 5e-2, rel
""")


def test_collective_matmul_subprocess():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed import collective_matmul_ag, matmul_reduce_scatter
from repro.common.sharding import concrete_mesh, shard_map
mesh = concrete_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = rng.standard_normal((16, 64)).astype(np.float32)
w = rng.standard_normal((64, 32)).astype(np.float32)
cm = jax.jit(shard_map(lambda a, b: collective_matmul_ag(a, b, "data"), mesh=mesh,
    in_specs=(P(None, "data"), P(None, "data")), out_specs=P(None, "data")))
assert np.allclose(np.asarray(cm(jnp.asarray(x), jnp.asarray(w))), x @ w, atol=1e-4)
rs = jax.jit(shard_map(lambda a, b: matmul_reduce_scatter(a, b, "data"), mesh=mesh,
    in_specs=(P(None, "data"), P("data", None)), out_specs=P(None, "data")))
assert np.allclose(np.asarray(rs(jnp.asarray(x), jnp.asarray(w))), x @ w, atol=1e-4)
""")


def test_pipeline_subprocess():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import make_pipeline_fn
from repro.common.sharding import concrete_mesh
mesh = concrete_mesh((4,), ("pipe",))
rng = np.random.default_rng(0)
S, M, mb, dim = 4, 8, 4, 16
Ws = (rng.standard_normal((S, dim, dim)).astype(np.float32) * 0.3)
pf = jax.jit(make_pipeline_fn(lambda wp, x: jnp.tanh(x @ wp), mesh, S))
xin = rng.standard_normal((M, mb, dim)).astype(np.float32)
out = np.asarray(pf(jnp.asarray(Ws), jnp.asarray(xin)))
ref = xin
for s in range(S):
    ref = np.tanh(ref @ Ws[s])
assert np.allclose(out, ref, atol=1e-5)
""", n_dev=4)


def test_sharded_train_step_subprocess():
    """A reduced LM train step lowered on an 8-device (2,4) mesh with the
    production sharding rules — the mini version of the multi-pod dry-run."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, reduce_config
from repro.launch.steps import build_cell
from repro.launch.dryrun import shardings_for, _opt_axes_like
from repro.train import init_train_state
from repro.common.config import ShapeSpec
from repro.common.sharding import concrete_mesh, mesh_context
mesh = concrete_mesh((2, 4), ("data", "model"))
cfg, _, _ = get_arch("gemma2-2b")
rc = reduce_config(cfg).replace(d_model=64, n_heads=4, head_dim=16)
cell = build_cell(rc, ShapeSpec(name="t", kind="train", seq_len=32, global_batch=8))
param_sh = shardings_for(cell.param_axes, cell.param_specs, mesh)
input_sh = shardings_for(cell.input_axes, cell.input_specs, mesh)
with mesh_context(mesh):
    params = cell.init_fn(jax.random.key(0))
    params = jax.tree.map(jax.device_put, params, param_sh)
    opt = init_train_state(params, cell.opt_cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 251, (8, 32)).astype(np.int32)),
             "labels": jnp.asarray(rng.integers(0, 251, (8, 32)).astype(np.int32))}
    step = jax.jit(cell.step)
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # and the same step on 1 logical device must agree numerically
""", n_dev=8)


def test_checkpoint_elastic_reshard_subprocess():
    """Save under one sharding, restore under a different mesh layout."""
    _run("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.common.sharding import concrete_mesh
mesh1 = concrete_mesh((8,), ("data",))
mesh2 = concrete_mesh((2, 4), ("data", "model"))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh1, P("data", None)))
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 1, {"x": xs})
    out = restore_checkpoint(d, 1, {"x": x},
                             shardings={"x": NamedSharding(mesh2, P("model", "data"))})
    assert np.array_equal(np.asarray(out["x"]), np.asarray(x))
    assert out["x"].sharding.spec == P("model", "data")
""", n_dev=8)
