"""Observability: span tracer, metrics registry, probe log, engine wiring.

The unit half pins the primitives — span nesting/ordering and Chrome-trace
schema, histogram percentile math against numpy quantiles, probe-log JSONL
round-trips, registry snapshot/reset semantics.  The integration half serves
real batches through a traced engine and checks the contract the rest of the
repo relies on: every query phase shows up as a span, one probe record per
routed (query, term, shard), `serving_stats()` stays bit-compatible with the
pre-registry dict shape, and tracing off records nothing.
"""
import json
import os
import tempfile
import threading
import warnings

import jax
import numpy as np
import pytest

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import fit_thresholds, init_membership
from repro.data.corpus import synthesize_corpus
from repro.data.queries import sample_queries, zipf_disjunctions
from repro.index.build import build_inverted_index
from repro.obs import (
    NULL_SPAN, Counter, Gauge, Histogram, ProbeLog, ProbeRecord, Registry,
    Tracer, trace,
)
from repro.serve import BooleanEngine, ServeConfig


# ---------------------------------------------------------------- tracer
def test_span_nesting_order_and_depth():
    tr = Tracer()
    with tr.activate():
        with trace.span("outer", level=0):
            with trace.span("inner") as sp:
                sp.set(bytes=42)
    # spans record at __exit__, innermost first
    assert [s.name for s in tr.spans] == ["inner", "outer"]
    inner, outer = tr.spans
    assert (inner.depth, outer.depth) == (1, 0)
    assert inner.attrs == {"bytes": 42} and outer.attrs == {"level": 0}
    # wall-clock containment: the outer span brackets the inner one
    assert outer.ts_us <= inner.ts_us
    assert outer.ts_us + outer.dur_us >= inner.ts_us + inner.dur_us


def test_chrome_trace_schema():
    tr = Tracer()
    with tr.activate():
        with trace.span("a", k=1):
            with trace.span("b"):
                pass
    doc = tr.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["n_spans"] == 2
    spans = [ev for ev in doc["traceEvents"] if ev["ph"] == "X"]
    meta = [ev for ev in doc["traceEvents"] if ev["ph"] == "M"]
    assert len(spans) == 2
    for ev in spans:
        assert set(ev) == {"name", "cat", "ph", "ts", "dur", "pid", "tid", "args"}
        assert ev["cat"] == "serve"
        assert ev["dur"] >= 0.0
    # the host lane is prenamed after the tracer
    assert {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": tr.name}} in meta
    json.dumps(doc)  # must be valid JSON end to end
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.trace.json")
        tr.save(path)
        with open(path) as f:
            assert json.load(f) == doc


def test_trace_off_is_the_null_singleton():
    assert trace.current() is None
    h = trace.span("anything", bytes=1)
    assert h is NULL_SPAN  # shared instance: no allocation when tracing is off
    assert h.set(more=2) is NULL_SPAN
    with h:
        pass


def test_activate_none_preserves_outer_tracer():
    tr = Tracer()
    with tr.activate():
        # an engine whose config carries no tracer must not mask the caller's
        with trace.activate(None):
            assert trace.current() is tr
            with trace.span("seen"):
                pass
    assert [s.name for s in tr.spans] == ["seen"]
    assert trace.current() is None


def test_spans_carry_worker_thread_ids():
    tr = Tracer()
    barrier = threading.Barrier(2)  # overlap lifetimes so idents differ

    def worker():
        barrier.wait()
        with trace.activate(tr), trace.span("w"):
            pass
        barrier.wait()

    threads = [threading.Thread(target=worker) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    tids = {s.tid for s in tr.spans}
    assert len(tr.spans) == 2 and len(tids) == 2


def test_tracer_reset_clears_spans_and_epoch():
    tr = Tracer()
    with tr.activate(), trace.span("x"):
        pass
    assert tr.spans
    tr.reset()
    assert tr.spans == []
    with tr.activate(), trace.span("y"):
        pass
    assert tr.spans[0].ts_us >= 0.0  # new epoch: timestamps restart near zero


# ---------------------------------------------------------------- metrics
def test_counter_gauge_basics():
    c, g = Counter(), Gauge()
    c.inc()
    c.inc(4)
    g.set(2.5)
    assert c.snapshot() == 5 and g.snapshot() == 2.5
    c.reset()
    g.reset()
    assert c.snapshot() == 0 and g.snapshot() == 0.0


def test_histogram_percentiles_linear_buckets():
    # controlled edges: interpolation error is bounded by one bucket width
    values = np.arange(1.0, 1001.0)
    h = Histogram(buckets=list(np.arange(0.0, 1001.0, 10.0)))
    for v in np.random.default_rng(0).permutation(values):
        h.observe(v)
    for q in (1, 10, 25, 50, 75, 90, 99):
        assert abs(h.percentile(q) - np.percentile(values, q)) <= 10.5, q
    s = h.snapshot()
    assert s["count"] == 1000 and s["min"] == 1.0 and s["max"] == 1000.0
    assert abs(s["mean"] - values.mean()) < 1e-9


def test_histogram_percentiles_default_log_buckets():
    # default buckets are quarter-decade: estimates stay within ~one bucket
    # (factor 10**0.25) of the numpy quantile on a heavy-tailed sample
    rng = np.random.default_rng(7)
    values = np.clip(rng.lognormal(np.log(500.0), 1.0, size=5000), 1.0, 1e6)
    h = Histogram()
    for v in values:
        h.observe(v)
    for q in (50, 90, 99):
        est, ref = h.percentile(q), float(np.percentile(values, q))
        assert ref / 10**0.3 <= est <= ref * 10**0.3, (q, est, ref)
    # clamped to observed extremes
    assert h.percentile(0) == values.min()
    assert h.percentile(100) == values.max()


def test_histogram_empty_and_reset():
    h = Histogram()
    assert h.snapshot() is None and h.percentile(50) == 0.0
    h.observe(3.0)
    assert h.snapshot()["count"] == 1
    with pytest.raises(ValueError):
        h.percentile(101)
    h.reset()
    assert h.snapshot() is None


def test_registry_dotted_names_collectors_and_reset():
    reg = Registry()
    reg.counter("latency.plan_us")  # histogram name collision must be loud
    with pytest.raises(TypeError):
        reg.histogram("latency.plan_us")
    reg.counter("queries.ranked").inc(3)
    reg.histogram("latency.query_us").observe(100.0)
    section = {"hits": 1}
    resets = []
    reg.register("cache", lambda: section, reset=lambda: resets.append(True))
    reg.register("ranked", lambda: None)  # None -> key omitted
    snap = reg.snapshot()
    assert snap["queries"]["ranked"] == 3
    assert snap["latency"]["query_us"]["count"] == 1
    assert snap["cache"] == {"hits": 1} and "ranked" not in snap
    reg.reset()
    assert resets == [True]
    snap = reg.snapshot()
    assert snap["queries"]["ranked"] == 0 and "query_us" not in snap.get("latency", {})


# ---------------------------------------------------------------- probe log
def test_probelog_jsonl_round_trip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "probes.jsonl")
        log = ProbeLog(path)
        with log.context(query=3, shard=1):
            log.log(17, "guided", n_cands=8, n_found=2, n_postings=100,
                    eps_window=6.5, bytes=96, wall_us=12.25)
        log.log(9, "fallback", n_cands=4, n_found=4, n_postings=4,
                eps_window=0.0, bytes=16, wall_us=3.0)  # outside any context
        log.close()
        back = ProbeLog.read(path)
    assert back == [
        ProbeRecord(query=3, shard=1, term=17, route="guided", n_cands=8,
                    n_found=2, n_postings=100, eps_window=6.5, bytes=96,
                    wall_us=12.25),
        ProbeRecord(query=-1, shard=-1, term=9, route="fallback", n_cands=4,
                    n_found=4, n_postings=4, eps_window=0.0, bytes=16,
                    wall_us=3.0),
    ]


def test_probelog_in_memory_and_context_restore():
    log = ProbeLog()
    with log.context(query=1, shard=0):
        with log.context(query=2, shard=1):
            log.log(5, "guided", n_cands=1, n_found=1, n_postings=9,
                    eps_window=2.0, bytes=8, wall_us=1.0)
        log.log(6, "decode", n_cands=1, n_found=0, n_postings=9,
                eps_window=2.0, bytes=8, wall_us=1.0)
    assert [(r.query, r.shard) for r in log.records] == [(2, 1), (1, 0)]
    assert log.n_records == 2


# ---------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def served():
    """One engine serving boolean + ranked batches with full observability."""
    corpus = synthesize_corpus(
        CorpusConfig(n_docs=600, n_terms=2000, avg_doc_len=40, seed=13)
    )
    inv = build_inverted_index(corpus)
    li = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=64)
    params, _ = init_membership(jax.random.key(0), li, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)
    tracer, plog = Tracer(), ProbeLog()
    cfg = ServeConfig(n_shards=2, obs=dict(trace=tracer, probe_log=plog))
    eng = BooleanEngine(lb, inv, li, cfg)
    bool_q = sample_queries(corpus, 8, seed=3)
    ranked_q, _ = zipf_disjunctions(inv.dfs, 8, seed=5)
    eng.query_batch(bool_q)
    eng.query_topk(ranked_q, 5)
    return eng, tracer, plog, bool_q


def test_traced_batch_covers_every_phase(served):
    _, tracer, _, _ = served
    names = {s.name for s in tracer.spans}
    # boolean path: plan -> per-shard mask -> probe fan-out -> merge
    assert {"serve.batch", "serve.plan", "serve.candidate_mask",
            "serve.probe_phase", "shard.verify", "probe.term",
            "serve.merge"} <= names
    # ranked path: plan -> per-shard topk -> heap merge
    assert {"serve.topk_batch", "shard.topk", "serve.heap_merge"} <= names
    # probe spans carry the route decision + candidate count as attrs
    probes = [s for s in tracer.spans if s.name == "probe.term"]
    assert probes and all(
        {"term", "route", "n_cands"} <= set(s.attrs) for s in probes
    )


def test_one_probe_record_per_routed_probe(served):
    eng, _, plog, _ = served
    g = eng.metrics.snapshot()["guided"]
    recs = plog.records
    # every non-empty probe call bumps exactly one route counter and logs
    # exactly one record
    routed = sum(1 for r in recs if r.route != "empty")
    assert routed == g["guided_terms"] + g["fallback_terms"] + g["routed_terms"]
    assert plog.n_records == len(recs) > 0
    # executor context attributes every record to a live (query, shard)
    assert all(r.query >= 0 and r.shard in (0, 1) for r in recs)
    assert all(r.route in ("empty", "fallback", "decode", "guided") for r in recs)
    assert all(r.wall_us >= 0.0 and r.bytes >= 0 for r in recs)


def test_serving_stats_is_a_deprecated_snapshot_alias(served):
    eng, *_ = served
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = eng.serving_stats()
        eng.serving_stats()  # exactly one warning per call, not per process
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 2
    snap = eng.metrics.snapshot()
    assert legacy.keys() == snap.keys()
    assert legacy["summary"] == snap["summary"]
    # the summary block keeps its pre-registry keys exactly
    assert set(legacy["summary"]) == {
        "n_shards", "cache_hits", "cache_misses", "cache_evictions",
        "probe_bytes", "bytes_ratio", "scored_fraction",
    }
    # facade summary aggregates the per-shard registries
    assert legacy["summary"]["cache_hits"] == sum(
        s["decode_cache"]["hits"] for s in legacy["shards"]
    )
    assert legacy["queries"]["boolean"] == 8 and legacy["queries"]["ranked"] == 8
    for name in ("plan_us", "mask_us", "probe_us", "merge_us", "query_us",
                 "topk_query_us"):
        assert legacy["latency"][name]["count"] > 0, name


def test_trace_off_records_nothing(served):
    eng, tracer, _, bool_q = served
    n = len(tracer.spans)
    saved = eng.cfg.trace
    eng.cfg.trace = None
    try:
        eng.query_batch(bool_q[:2])
    finally:
        eng.cfg.trace = saved
    assert len(tracer.spans) == n


def test_public_reset_clears_every_window(served):
    eng, _, _, bool_q = served
    eng.query_batch(bool_q[:2])
    # per-shard public reset: no caller reaches into sh._guided anymore
    for sh in eng.shards:
        assert hasattr(sh, "reset_stats")
    eng.reset_stats()
    snap = eng.metrics.snapshot()
    assert "ranked" not in snap  # ranked section reappears only after queries
    assert snap["summary"]["cache_hits"] == 0
    assert snap["summary"]["probe_bytes"] == 0
    assert snap["queries"] == {"ranked": 0, "boolean": 0}
    assert "latency" not in snap or all(
        v is None for v in snap["latency"].values()
    )
