"""Sharding-rule unit tests: divisibility fallback, mesh-axis dedup,
fallback chains — the logic every dry-run cell rides on."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.common.sharding import DEFAULT_RULES, abstract_mesh, resolve_axis, spec_for_shape


@pytest.fixture(scope="module")
def mesh():
    # single-device CI mesh still exercises the resolution logic with
    # symbolic axis names via an abstract mesh; abstract_mesh papers over
    # the AbstractMesh signature change across JAX releases
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def pod_mesh():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_basic_resolution(mesh):
    assert spec_for_shape(("batch", None), (256, 4), mesh) == P("data", None)
    assert spec_for_shape(("embed", "mlp"), (2048, 8192), mesh) == P("data", "model")


def test_divisibility_fallback_replicates(mesh):
    # MQA: kv_heads=1 cannot shard 16-way
    assert spec_for_shape(("embed", "kv_heads", None), (2048, 1, 256), mesh) == P(
        "data", None, None
    )
    # 24 heads % 16 != 0 -> replicated
    assert spec_for_shape((None, "heads", None), (2048, 24, 128), mesh) == P(None, None, None)


def test_axis_dedup_first_claim_wins(mesh):
    # experts claims (data, model); embed then finds data used; mlp finds model used
    spec = spec_for_shape(("experts", "embed", "mlp"), (256, 7168, 2048), mesh)
    assert spec == P(("data", "model"), None, None)


def test_fallback_chain_heads_then_seq(mesh):
    # score matrices: heads dim fails (24), seq dim picks up `model`
    spec = spec_for_shape(("batch", "heads", "seq_sharded", None), (16, 24, 4096, 4096), mesh)
    assert spec == P("data", None, "model", None)
    # when heads divide, heads win and seq stays unsharded
    spec = spec_for_shape(("batch", "heads", "seq_sharded", None), (16, 32, 4096, 4096), mesh)
    assert spec == P("data", "model", None, None)


def test_partial_tuple_drop(mesh):
    # edges rule is (pod,data,model); on a pod-less mesh with an edge count
    # divisible by 16 but not 256, only `data` survives
    spec = spec_for_shape(("edges",), (16 * 3,), mesh)
    assert spec == P("data")


def test_multi_pod_batch_folds_pod(pod_mesh):
    spec = spec_for_shape(("batch", None), (256, 4), pod_mesh)
    assert spec == P(("pod", "data"), None)


def test_empty_axes_scalar(mesh):
    assert spec_for_shape((), (), mesh) == P()


def test_resolve_axis_missing_mesh_axis(mesh):
    # 'pod' absent on a single-pod mesh -> rules degrade gracefully
    assert resolve_axis("batch", mesh) == "data"
    assert resolve_axis(None, mesh) is None


def test_rules_cover_all_model_axes():
    used_by_models = {
        "batch", "embed", "vocab", "heads", "kv_heads", "mlp", "experts",
        "seq_sharded", "layers", "nodes", "edges", "table_vocab", "candidates",
        "docs", "terms", "blocks",
    }
    assert used_by_models <= set(k for k in DEFAULT_RULES if k is not None)
