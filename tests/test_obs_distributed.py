"""Distributed tracing + SLO telemetry (obs v2: collate/slo/export).

The unit half pins the new primitives in isolation: min-RTT clock-offset
estimation against a skewed fake clock, wire-span rebasing onto the host
epoch, the per-lane nesting invariant checker, the sliding-window SLO
monitor's hit-rate/burn-rate math, Prometheus text rendering, probe-log
size-capped rotation and drain/ingest forwarding, and the histogram
snapshot/reset race under writer threads.

The integration half runs real process replicas: worker spans must merge
into the host tracer time-aligned (own pid lanes, no partial overlaps,
trace_id threaded through), worker probe records must land in the host
sink, a crashed-then-respawned replica must re-sync its clock offset, and
``QueryResult.autopsy()`` / ``Session.slo_report()`` must decompose where
the latency went.
"""
import json
import os
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import fit_thresholds, init_membership
from repro.data.corpus import synthesize_corpus
from repro.data.queries import sample_queries, zipf_conjunctions
from repro.index.build import build_inverted_index
from repro.obs import (
    Histogram,
    ProbeLog,
    SLOMonitor,
    TraceContext,
    Tracer,
    estimate_clock_offset,
    ingest_worker_spans,
    nesting_violations,
    render_prometheus,
    write_prometheus,
)
from repro.obs.trace import Span
from repro.serve import BooleanEngine, QueryRequest, Rejected, ServeConfig, Session
from repro.serve.sched import MODE_RANKED, WorkerFailure


# ------------------------------------------------------------- clock offset
def test_clock_offset_recovers_known_skew():
    skew_ns = 5_000_000_000  # 5 s: far above any measurement error

    def roundtrip():
        return time.perf_counter_ns() + skew_ns

    offset, rtt = estimate_clock_offset(roundtrip)
    assert rtt >= 0
    # symmetric-delay bound: the estimate is within RTT/2 of the true skew
    assert abs(offset - skew_ns) <= rtt / 2 + 1_000

    with pytest.raises(ValueError):
        estimate_clock_offset(roundtrip, n=0)


def test_clock_offset_keeps_min_rtt_sample():
    # one fast exchange among slow ones: its (accurate) offset must win
    calls = {"n": 0}

    def roundtrip():
        calls["n"] += 1
        if calls["n"] != 3:
            time.sleep(0.005)  # slow ping: midpoint assumption is off
            return time.perf_counter_ns() + 10_000_000
        return time.perf_counter_ns() + 10_000_000

    offset, rtt = estimate_clock_offset(roundtrip, n=5)
    assert calls["n"] == 5
    assert rtt < 5_000_000  # the fast sample's RTT, not a slept one's
    assert abs(offset - 10_000_000) <= rtt / 2 + 1_000


# --------------------------------------------------------------- wire spans
def test_wire_span_round_trip_rebases_onto_host_epoch():
    host, worker = Tracer(name="host"), Tracer(name="w")
    with worker.activate(), worker.span("worker.op", trace_id=7):
        time.sleep(0.001)
    [orig] = worker.spans
    wire = worker.drain_wire()
    assert worker.spans == []  # drained, epoch kept
    assert wire[0]["name"] == "worker.op" and wire[0]["attrs"] == {"trace_id": 7}

    # both tracers run on this process's clock, so the true offset is 0
    n = ingest_worker_spans(host, wire, offset_ns=0, pid=4242, label="replica")
    assert n == 1
    [merged] = host.spans
    assert merged.pid == 4242 and merged.name == "worker.op"
    # rebasing: worker-epoch-relative ts shifted by the epoch gap
    want_ts = (worker.epoch_ns - host.epoch_ns) / 1e3 + orig.ts_us
    assert abs(merged.ts_us - want_ts) < 0.5
    assert abs(merged.dur_us - orig.dur_us) < 1e-9

    doc = host.chrome_trace()
    lanes = {e["pid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert lanes == {4242}
    assert {"name": "process_name", "ph": "M", "pid": 4242, "tid": 0,
            "args": {"name": "replica"}} in doc["traceEvents"]


def _span(name, ts, dur, *, pid=0, tid=0):
    return Span(name=name, ts_us=ts, dur_us=dur, tid=tid, depth=0, attrs={},
                pid=pid)


def test_nesting_violations_flags_partial_overlap_only():
    nested = [_span("a", 0, 100), _span("b", 10, 50), _span("c", 20, 10)]
    disjoint = [_span("d", 200, 50), _span("e", 300, 50)]
    assert nesting_violations(nested + disjoint) == []
    # partial overlap: starts inside `b`, ends beyond it (reported against
    # the innermost still-open span)
    bad = nesting_violations(nested + [_span("x", 50, 100)])
    assert len(bad) == 1 and "'x'" in bad[0] and "'b'" in bad[0]
    # the same intervals on different lanes never interact
    assert nesting_violations(nested + [_span("x", 50, 100, pid=9)]) == []
    assert nesting_violations(nested + [_span("x", 50, 100, tid=9)]) == []
    # sub-slack overhang is tolerated (shared endpoints from float math)
    assert nesting_violations(
        [_span("a", 0, 100), _span("b", 50, 50.3)], slack_us=0.5
    ) == []


# ----------------------------------------------------------------- monitor
def test_slo_monitor_hit_rate_percentiles_and_burn():
    t = {"now": 0.0}
    slo = SLOMonitor(window_s=10.0, target=0.9, clock=lambda: t["now"])
    for i in range(8):
        slo.record("a", latency_us=1000.0 * (i + 1), served=True,
                   deadline_met=True)
    slo.record("a", latency_us=50_000.0, served=True, deadline_met=False)
    slo.record("a", latency_us=0.0, served=False, deadline_met=False)  # shed
    rep = slo.report()["a"]
    assert rep["requests"] == 10 and rep["served"] == 9 and rep["shed"] == 1
    assert rep["deadline_hit_rate"] == pytest.approx(0.8)
    # 20% misses against a 10% budget: burning at 2x sustainable
    assert rep["burn_rate"] == pytest.approx(2.0)
    lat_ms = sorted([1, 2, 3, 4, 5, 6, 7, 8, 50])
    assert rep["p50_ms"] == pytest.approx(float(np.percentile(lat_ms, 50)))
    assert rep["p99_ms"] == pytest.approx(float(np.percentile(lat_ms, 99)))

    # the window slides: everything above ages out
    t["now"] = 11.0
    slo.record("b", latency_us=500.0, served=True, deadline_met=True)
    rep = slo.report()
    assert "a" not in rep and rep["b"]["requests"] == 1

    slo.reset()
    assert slo.report() == {}
    with pytest.raises(ValueError):
        SLOMonitor(target=1.0)


def test_slo_monitor_bounds_memory_per_tenant():
    slo = SLOMonitor(window_s=1e9, max_samples_per_tenant=16)
    for _ in range(100):
        slo.record("hot", latency_us=1.0, served=True, deadline_met=True)
    assert slo.report()["hot"]["requests"] == 16


# ---------------------------------------------------------------- exporter
def test_render_prometheus_text_exposition():
    h = Histogram()
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    text = render_prometheus({
        "sched": {"shed": {"deadline": 2}, "service_us": h.snapshot()},
        "queries": {"boolean": 7},
        "sweep": {"p99": [1.5, 2.5]},
        "meta": {"note": "strings are skipped", "none": None},
    })
    lines = text.splitlines()
    assert "repro_queries_boolean 7" in lines
    assert "repro_sched_shed_deadline 2" in lines
    assert 'repro_sweep_p99{idx="0"} 1.5' in lines
    assert 'repro_sweep_p99{idx="1"} 2.5' in lines
    assert "repro_sched_service_us_count 4" in lines
    assert 'repro_sched_service_us{quantile="0.5"}' in text
    assert "note" not in text and "none" not in text
    # each metric gets exactly one TYPE line, and the doc is sorted/stable
    types = [l for l in lines if l.startswith("# TYPE")]
    assert len(types) == len(set(types))
    assert text == render_prometheus({
        "meta": {"note": "strings are skipped", "none": None},
        "sweep": {"p99": [1.5, 2.5]},
        "queries": {"boolean": 7},
        "sched": {"service_us": h.snapshot(), "shed": {"deadline": 2}},
    })

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.prom")
        write_prometheus({"queries": {"boolean": 7}}, path)
        with open(path) as f:
            assert "repro_queries_boolean 7" in f.read()


# ---------------------------------------------------------------- probe log
def _probe(log, term=1):
    log.log(term, "guided", n_cands=4, n_found=2, n_postings=64,
            eps_window=1.0, bytes=32, wall_us=2.0)


def test_probelog_rotates_at_size_cap():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "probes.jsonl")
        log = ProbeLog(path, max_bytes=2048)
        for i in range(200):
            _probe(log, term=i)
        log.close()
        assert log.n_rotations >= 1
        assert os.path.exists(path) and os.path.exists(path + ".1")
        # disk held at <= ~2x the cap regardless of how much was logged
        assert os.path.getsize(path) <= 2 * 2048
        assert os.path.getsize(path + ".1") <= 2 * 2048
        # both generations stay valid JSONL
        kept = ProbeLog.read(path) + ProbeLog.read(path + ".1")
        assert 0 < len(kept) <= 200
        assert all(r.route == "guided" for r in kept)


def test_probelog_drain_ingest_forwarding():
    worker = ProbeLog()  # in-memory worker-side sink
    with worker.context(query=3, shard=1):
        _probe(worker, term=17)
    wire = worker.drain()
    assert worker.records == []  # buffer drained (n_records stays lifetime)
    assert worker.n_records == 1
    assert isinstance(wire[0], dict) and wire[0]["term"] == 17

    host = ProbeLog()
    host.ingest(wire)
    [rec] = host.records
    assert (rec.query, rec.shard, rec.term) == (3, 1, 17)
    # None inherits the enclosing half: per-query facade context + per-shard
    # executor context compose without clobbering each other
    with host.context(query=9, shard=None), host.context(query=None, shard=4):
        _probe(host, term=5)
    assert (host.records[-1].query, host.records[-1].shard) == (9, 4)


# ---------------------------------------------------------------- histogram
def test_histogram_snapshot_reset_race():
    """Writers hammer observe() while a reader snapshots/resets: every
    snapshot must be internally consistent (one locked view, not a torn
    read across reset)."""
    h = Histogram()
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            h.observe(5.0)

    threads = [threading.Thread(target=writer) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(300):
            s = h.snapshot()
            if s is None:
                continue  # consistent empty view right after a reset
            assert s["count"] >= 1
            assert s["min"] == s["max"] == 5.0
            assert s["mean"] == pytest.approx(5.0)
            assert s["sum"] == pytest.approx(5.0 * s["count"])
            h.reset()
    finally:
        stop.set()
        for t in threads:
            t.join()


# ------------------------------------------------------------- integration
@pytest.fixture(scope="module")
def system():
    corpus = synthesize_corpus(
        CorpusConfig(n_docs=400, n_terms=1600, avg_doc_len=50, seed=31)
    )
    inv = build_inverted_index(corpus)
    li_cfg = LearnedIndexConfig(embed_dim=16, truncation_k=16, block_size=64)
    params, _ = init_membership(jax.random.key(2), li_cfg, corpus.n_terms,
                                corpus.n_docs)
    lb = fit_thresholds(params, inv)
    return corpus, inv, li_cfg, lb


def test_worker_spans_merge_time_aligned(system, tmp_path):
    """The tentpole end to end: a ranked + boolean request through a real
    process replica produces ONE coherent timeline — worker spans on their
    own pid lane, clock-aligned, nested, carrying the request's trace_id."""
    corpus, inv, li_cfg, lb = system
    tracer, plog = Tracer(), ProbeLog()
    cfg = ServeConfig(n_shards=2, sched=dict(n_replicas=1),
                      obs=dict(trace=tracer, probe_log=plog))
    eng = BooleanEngine(lb, inv, li_cfg, cfg)
    q = sample_queries(corpus, 4, max_terms=4, seed=5)
    rq = zipf_conjunctions(inv.dfs, 4, max_terms=4, seed=9)
    with Session(eng, store_dir=str(tmp_path)) as s:
        s.warm()
        tracer.reset()  # only the traced requests below, not warmup
        t0_us = (time.perf_counter_ns() - tracer.epoch_ns) / 1e3
        r = s.submit(QueryRequest(terms=q[0]), timeout=30)
        rr = s.submit(QueryRequest(terms=rq[0], mode=MODE_RANKED, k=5),
                      timeout=30)
        assert r.ok and rr.ok
        t1_us = (time.perf_counter_ns() - tracer.epoch_ns) / 1e3
        pids = {rep.pid for g in s._groups for rep in g.replicas}

    host = [s_ for s_ in tracer.spans if s_.pid == 0]
    worker = [s_ for s_ in tracer.spans if s_.pid != 0]
    assert host and worker
    assert {s_.pid for s_ in worker} <= pids
    wnames = {s_.name for s_ in worker}
    assert "worker.bool" in wnames and "worker.topk" in wnames
    assert "shard.candidate_mask" in wnames  # probe work happened worker-side
    # host side still owns admission + dispatch + merge
    hnames = {s_.name for s_ in host}
    assert {"sched.queue_wait", "sched.batch", "sched.dispatch",
            "sched.merge"} <= hnames

    # time alignment: every merged worker span lands inside the wall window
    # of the two requests as seen on the HOST clock (offset applied), and
    # lanes are stack-consistent after the mapping
    for s_ in worker:
        assert t0_us - 1e3 <= s_.ts_us <= s_.ts_us + s_.dur_us <= t1_us + 1e3
    assert nesting_violations(tracer.spans, slack_us=0.5) == []

    # the request's trace_id threads through to the worker-root spans
    roots = [s_ for s_ in worker if s_.name in ("worker.bool", "worker.topk")]
    assert roots and all(s_.attrs.get("trace_id", 0) > 0 for s_ in roots)

    # worker probe records were forwarded into the host sink
    assert plog.n_records > 0
    assert all(r_.shard in (0, 1) for r_ in plog.records)

    # the exported artifact names each replica lane
    doc = tracer.chrome_trace()
    lane_names = {e["args"]["name"] for e in doc["traceEvents"]
                  if e["ph"] == "M" and e["name"] == "process_name"}
    assert any(n.startswith("shard") for n in lane_names)
    json.dumps(doc)


def test_respawned_replica_resyncs_clock(system, tmp_path):
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg,
                        ServeConfig(n_shards=1, sched=dict(n_replicas=1)))
    with Session(eng, store_dir=str(tmp_path)) as s:
        s.warm()
        [group] = s._groups
        [rep] = group.replicas
        pid0, syncs0 = rep.pid, rep.clock_syncs
        assert syncs0 >= 1 and rep.clock_offset_ns is not None
        assert rep.clock_rtt_ns >= 0
        with pytest.raises(WorkerFailure):
            group.call(("crash",))  # crash + respawned retry crashes again
        assert group.call(("ping",)) == "pong"  # respawns once more
        assert rep.pid not in (None, pid0)
        # every (re)spawn re-ran the ping sync: offset is fresh, not stale
        assert rep.clock_syncs == syncs0 + 2
        assert rep.clock_offset_ns is not None


def test_autopsy_and_slo_report_inline(system):
    corpus, inv, li_cfg, lb = system
    eng = BooleanEngine(lb, inv, li_cfg, ServeConfig(n_shards=1))
    q = sample_queries(corpus, 4, max_terms=4, seed=5)
    with Session(eng) as s:
        r = s.submit(QueryRequest(terms=q[0]), timeout=10)
        assert r.ok and r.phases is not None
        a = r.autopsy()
        assert a["total_us"] == pytest.approx(r.queue_us + r.service_us)
        assert a["execute_us"] > 0.0
        for k in ("queue", "dispatch", "execute", "merge"):
            assert a[f"{k}_us"] >= 0.0
            assert 0.0 <= a[f"{k}_frac"] <= 1.0
        # phase walls are measured inside the service window
        assert (a["dispatch_us"] + a["execute_us"] + a["merge_us"]
                <= r.service_us * 1.01 + 1.0)

        # one shed outcome: an already-expired deadline
        shed = s.submit(QueryRequest(terms=q[1], deadline_ms=-1.0), timeout=10)
        assert isinstance(shed, Rejected)

        rep = s.slo_report()
    assert rep["window_s"] > 0 and 0 < rep["target"] < 1
    ten = rep["tenants"]["default"]
    assert ten["requests"] == 2 and ten["served"] == 1 and ten["shed"] == 1
    assert ten["deadline_hit_rate"] == pytest.approx(0.5)
    assert ten["burn_rate"] > 1.0  # half the window missed: budget burning
    assert {"queue_us", "service_us", "dispatch_us", "execute_us",
            "merge_us"} <= set(rep["sched"])


def test_short_circuit_results_have_autopsy_defaults():
    r_ = __import__("repro.serve.sched.api", fromlist=["QueryResult"])
    qr = r_.QueryResult(ids=np.zeros(0, np.int32), queue_us=0.0,
                        service_us=0.0)
    a = qr.autopsy()  # phases=None: a short-circuit never saw a batch
    assert a["total_us"] == 0.0 and a["execute_frac"] == 0.0


def test_trace_context_pickles_and_defaults():
    import pickle

    ctx = TraceContext(trace_id=5, trace=True, probe=False)
    back = pickle.loads(pickle.dumps(ctx))
    assert back == ctx and back.trace_id == 5
    assert TraceContext() == TraceContext(trace_id=0, trace=False, probe=False)
