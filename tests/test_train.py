"""Training substrate: optimizer, schedules, grad accumulation, int8 moments,
checkpoint manager (atomic commit, gc, restore, reshard)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint, save_checkpoint
from repro.common.config import OptimizerConfig
from repro.train import (
    dequantize_blockwise,
    init_train_state,
    lr_schedule,
    make_train_step,
    quantize_blockwise,
)

rng = np.random.default_rng(7)


@pytest.fixture
def regression():
    X = jnp.asarray(rng.standard_normal((128, 8)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((8,)).astype(np.float32))
    return X, X @ w


def _loss(p, b):
    return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)


def test_adam_converges(regression):
    X, y = regression
    cfg = OptimizerConfig(lr=0.05, warmup_steps=5, total_steps=200, weight_decay=0.0)
    step = jax.jit(make_train_step(_loss, cfg))
    params = {"w": jnp.zeros(8)}
    st = init_train_state(params, cfg)
    first = None
    for _ in range(80):
        params, st, m = step(params, st, {"x": X, "y": y})
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < 1e-2 * first


def test_int8_moments_track_fp32(regression):
    X, y = regression
    base = OptimizerConfig(lr=0.05, warmup_steps=5, total_steps=200, weight_decay=0.0)
    q8 = OptimizerConfig(lr=0.05, warmup_steps=5, total_steps=200, weight_decay=0.0,
                         moment_dtype="int8")
    outs = {}
    for name, cfg in [("fp32", base), ("int8", q8)]:
        step = jax.jit(make_train_step(_loss, cfg))
        params = {"w": jnp.zeros(8)}
        st = init_train_state(params, cfg)
        for _ in range(60):
            params, st, m = step(params, st, {"x": X, "y": y})
        outs[name] = float(m["loss"])
    assert outs["int8"] < 20 * max(outs["fp32"], 1e-4)


def test_quantize_roundtrip_small_error():
    x = jnp.asarray(rng.standard_normal((37, 53)).astype(np.float32))
    q = quantize_blockwise(x)
    assert q["q"].dtype == jnp.int8
    back = dequantize_blockwise(q, x.shape)
    rel = float(jnp.abs(back - x).max() / jnp.abs(x).max())
    assert rel < 0.02


def test_grad_accumulation_matches_full_batch(regression):
    X, y = regression
    cfg = OptimizerConfig(lr=0.05, warmup_steps=0, total_steps=100, weight_decay=0.0)
    s_full = jax.jit(make_train_step(_loss, cfg))
    s_acc = jax.jit(make_train_step(_loss, cfg, n_microbatches=4))
    p1, st1 = {"w": jnp.zeros(8)}, init_train_state({"w": jnp.zeros(8)}, cfg)
    p2, st2 = {"w": jnp.zeros(8)}, init_train_state({"w": jnp.zeros(8)}, cfg)
    p1, _, _ = s_full(p1, st1, {"x": X, "y": y})
    p2, _, _ = s_acc(p2, st2, {"x": X, "y": y})
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=2e-4, atol=2e-5)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr_schedule(cfg, jnp.asarray(100))) < 0.15


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 3, tree)
    assert latest_step(str(tmp_path)) == 3
    out = restore_checkpoint(str(tmp_path), 3, tree)
    assert np.array_equal(np.asarray(out["a"]), np.arange(6).reshape(2, 3))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_gc_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        cm.save(s, tree)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003", "step_00000004"]
    step, out = cm.restore_latest(tree)
    assert step == 4


def test_checkpoint_restores_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(3), "extra": jnp.zeros(1)})


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(7, {"w": jnp.zeros(2)})
    for name in os.listdir(tmp_path):
        assert not name.startswith("tmp.")
