"""The paper's core: membership model, learned Bloom guarantees, Algorithms
1-3 correctness, Eq.(2) gain estimator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import CorpusConfig, LearnedIndexConfig
from repro.core import (
    build_engine,
    estimate_gain,
    exhaustive_query,
    false_negative_rate,
    false_positive_rate,
    fit_thresholds,
    gain_curve,
    init_membership,
    membership_loss,
    pair_logits,
    run_queries,
    storage_fraction_curve,
    term_doc_logits,
    two_tier_guaranteed,
)
from repro.data.corpus import synthesize_corpus
from repro.data.queries import brute_force_answers, sample_queries
from repro.index.build import build_inverted_index

K, BLOCK = 24, 64


@pytest.fixture(scope="module")
def setup():
    corpus = synthesize_corpus(CorpusConfig(n_docs=400, n_terms=1500, avg_doc_len=50, seed=2))
    inv = build_inverted_index(corpus)
    cfg = LearnedIndexConfig(embed_dim=16, truncation_k=K, block_size=BLOCK)
    params, axes = init_membership(jax.random.key(0), cfg, corpus.n_terms, corpus.n_docs)
    lb = fit_thresholds(params, inv)
    eng = build_engine(params, lb.tau, inv, truncation_k=K, block_size=BLOCK)
    queries = sample_queries(corpus, 32, seed=9)
    exact = brute_force_answers(corpus, queries)
    return corpus, inv, params, lb, eng, queries, exact


def test_zero_false_negatives(setup):
    _, inv, _, lb, _, _, _ = setup
    assert false_negative_rate(lb, inv) == 0.0


def test_fpr_below_one(setup):
    _, inv, _, lb, _, _, _ = setup
    assert 0.0 <= false_positive_rate(lb, inv, sample=2000) < 1.0


def test_term_doc_matches_pair_logits(setup):
    corpus, _, params, _, _, _, _ = setup
    terms = jnp.asarray([3, 77, 1200], dtype=jnp.int32)
    full = term_doc_logits(params, terms)
    for i, t in enumerate([3, 77, 1200]):
        docs = jnp.arange(corpus.n_docs, dtype=jnp.int32)
        pl = pair_logits(params, jnp.full((corpus.n_docs,), t, jnp.int32), docs)
        np.testing.assert_allclose(np.asarray(full[i]), np.asarray(pl), rtol=1e-5, atol=1e-5)


def test_exhaustive_is_superset(setup):
    _, _, _, _, eng, queries, exact = setup
    res = run_queries(eng, queries, "exhaustive")
    for i, ans in enumerate(exact):
        assert np.setdiff1d(ans, np.nonzero(res[i])[0]).size == 0


def test_block_is_superset(setup):
    _, _, _, _, eng, queries, exact = setup
    res = run_queries(eng, queries, "block")
    for i, ans in enumerate(exact):
        assert np.setdiff1d(ans, np.nonzero(res[i])[0]).size == 0


def test_block_no_larger_than_exhaustive(setup):
    """Algorithm 3 only restricts the scan — it cannot add results."""
    _, _, _, _, eng, queries, _ = setup
    r_ex = run_queries(eng, queries, "exhaustive")
    r_bl = run_queries(eng, queries, "block")
    assert (r_bl <= r_ex).all()


def test_two_tier_guaranteed_queries_complete(setup):
    _, _, _, _, eng, queries, exact = setup
    res = run_queries(eng, queries, "two_tier")
    guar = np.asarray(two_tier_guaranteed(eng.dfs, jnp.asarray(queries), K, with_model=True))
    assert guar.any()
    for i, ans in enumerate(exact):
        if guar[i]:
            assert np.setdiff1d(ans, np.nonzero(res[i])[0]).size == 0


def test_guarantee_model_dominates_no_model(setup):
    _, _, _, _, eng, queries, _ = setup
    w = np.asarray(two_tier_guaranteed(eng.dfs, jnp.asarray(queries), K, with_model=True))
    wo = np.asarray(two_tier_guaranteed(eng.dfs, jnp.asarray(queries), K, with_model=False))
    assert (w | ~wo).all()  # without-model guarantee implies with-model
    assert w.sum() >= wo.sum()


def test_membership_training_reduces_loss(setup):
    corpus, inv, _, _, _, _, _ = setup
    cfg = LearnedIndexConfig(embed_dim=16)
    params, _ = init_membership(jax.random.key(1), cfg, corpus.n_terms, corpus.n_docs)
    from repro.data.loader import membership_batches
    from repro.common.config import OptimizerConfig
    from repro.train import init_train_state, make_train_step

    it = membership_batches(corpus, batch_size=512, seed=0)
    step = jax.jit(make_train_step(lambda p, b: membership_loss(p, b),
                                   OptimizerConfig(lr=0.05, warmup_steps=5, total_steps=300,
                                                   weight_decay=0.0)))
    st = init_train_state(params, OptimizerConfig(lr=0.05))
    losses = []
    for i, batch in zip(range(60), it):
        params, st, m = step(params, st, {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < 0.7 * np.mean(losses[:10])


def test_gain_estimator_decreases_with_k(setup):
    _, inv, _, _, _, _, _ = setup
    curve = gain_curve(inv, [4, 16, 64], s_worst_bits=0.0)
    # smaller k replaces more terms
    assert curve[0].n_replaced >= curve[1].n_replaced >= curve[2].n_replaced
    for g in curve:
        assert g.gain_upper_bits >= g.gain_lower_bits


def test_gain_upper_bound_positive_at_reasonable_k(setup):
    _, inv, _, _, _, _, _ = setup
    g = estimate_gain(inv, 16)
    assert g.gain_upper_bits > 0  # replacing heavy terms must save space


def test_storage_fraction_skew(setup):
    """Paper Fig 1: few terms occupy a large storage share."""
    _, inv, _, _, _, _, _ = setup
    cum, counts = storage_fraction_curve(inv)
    n_terms_40pct = counts[np.searchsorted(cum, 0.4)]
    # tiny CI corpus is less skewed than Robust/GOV2/ClueWeb; the paper-scale
    # "<1% of terms -> 40% of storage" claim is validated in benchmarks/fig1
    assert n_terms_40pct < 0.15 * (inv.dfs > 0).sum()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_membership_deterministic(seed):
    cfg = LearnedIndexConfig(embed_dim=8)
    p1, _ = init_membership(jax.random.key(seed), cfg, 50, 40)
    p2, _ = init_membership(jax.random.key(seed), cfg, 50, 40)
    t = jnp.asarray([0, 1], jnp.int32)
    d = jnp.asarray([5, 7], jnp.int32)
    assert np.array_equal(np.asarray(pair_logits(p1, t, d)), np.asarray(pair_logits(p2, t, d)))
